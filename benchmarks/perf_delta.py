"""Render the baseline-vs-final dominant-roofline-term comparison for
EXPERIMENTS.md §Perf spillover: the §Perf work shipped as production
defaults, so EVERY pair moved, not just the three hillclimbed ones.

  PYTHONPATH=src python -m benchmarks.perf_delta [--mesh 1pod]

``--serve OLD.json NEW.json`` diffs two serve-bench records instead
(BENCH_serve.json across PRs): fused/sequential throughput, speedup,
and — once both sides carry the ``obs`` section — per-step dispatch
overhead p50/p95 and mean grid occupancy, so a dispatch regression
shows up as a number, not a vibe.

  python -m benchmarks.perf_delta --serve BENCH_serve_old.json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(d: str, mesh: str) -> dict:
    out = {}
    for f in glob.glob(f"{d}/*_{mesh}.json"):
        r = json.load(open(f))
        if r.get("ok"):
            out[(r["arch"], r["shape"])] = r
    return out


def _serve_metric(rec: dict, path: tuple):
    """Walk a key path into a serve record; None when any hop is absent
    (old records predate the obs section).  Integer hops index lists
    (the SLO report's per-instance array)."""
    cur = rec
    for k in path:
        if isinstance(cur, list):
            if not isinstance(k, int) or not -len(cur) <= k < len(cur):
                return None
            cur = cur[k]
            continue
        if not isinstance(cur, dict) or k not in cur or cur[k] is None:
            return None
        cur = cur[k]
    return cur


# (label, key path, higher-is-better) — the serve-record trajectory
_SERVE_METRICS = (
    ("fused tok/s", ("fused", "tok_per_s"), True),
    ("sequential tok/s", ("sequential", "tok_per_s"), True),
    ("speedup (seq/fused wall)", ("speedup",), True),
    ("dispatch amortization", ("dispatch_amortization",), True),
    # multi-step decode (DESIGN.md §6.6) — absent in pre-PR-7 records
    ("fused tokens/device-call", ("fused", "tokens_per_device_call"), True),
    ("decode tok/s @K8 (no mesh)",
     ("decode_horizon", "no_mesh", "per_k", "8", "decode_tok_per_s"), True),
    ("decode tok/s @K1 (no mesh)",
     ("decode_horizon", "no_mesh", "per_k", "1", "decode_tok_per_s"), True),
    ("K8 vs K1 decode speedup", ("k8_vs_k1_decode_speedup",), True),
    ("K8 vs K1 call reduction", ("k8_vs_k1_call_reduction",), True),
    ("K8 vs K1 dispatch/token reduction",
     ("k8_vs_k1_dispatch_per_token_reduction",), True),
    # decode-layer megakernel (ISSUE 8) — absent in pre-PR-8 records
    ("launches/decode-step megakernel (no mesh)",
     ("kernel_launches_per_decode_step", "no_mesh", "megakernel"), False),
    ("launches/decode-step unfused (no mesh)",
     ("kernel_launches_per_decode_step", "no_mesh", "unfused"), False),
    ("megakernel launch reduction (no mesh)",
     ("kernel_launches_per_decode_step", "no_mesh", "reduction"), True),
    ("dispatch overhead/token (ms)",
     ("obs", "dispatch_overhead_per_token_ms"), False),
    ("dispatch overhead p50 (ms)", ("dispatch_overhead_ms", "p50"), False),
    ("dispatch overhead p95 (ms)", ("dispatch_overhead_ms", "p95"), False),
    ("mean grid occupancy", ("mean_grid_occupancy",), True),
    ("idle slot token-steps", ("obs", "idle_slot_token_steps"), False),
    ("tracing overhead (%)", ("obs", "tracing_overhead_pct"), False),
    # tenant accounting + SLO burn (DESIGN.md §6.9) — absent pre-PR-10
    ("attribution conservation rel err",
     ("tenant_attribution", "conservation_rel_err"), False),
    ("attributed settled device-s", ("tenant_attribution", "settled_s"),
     False),
    ("idle-slot device-s (all tenants)",
     ("tenant_attribution", "idle_total_s"), False),
    ("tenant 0 device-s",
     ("tenant_attribution", "per_tenant", "0", "device_s"), False),
    ("tenant 0 queue-wait s",
     ("tenant_attribution", "per_tenant", "0", "queue_wait_s"), False),
    ("SLO ttft burn rate (inst 0)",
     ("load_gen", "slo", "instances", 0, "objectives", "ttft",
      "burn_rate"), False),
    ("SLO ttft budget remaining (inst 0)",
     ("load_gen", "slo", "instances", 0, "objectives", "ttft",
      "budget_remaining"), True),
)


def serve_delta(old_path: str, new_path: str) -> None:
    old = json.load(open(old_path))
    new = json.load(open(new_path))
    print(f"| metric | {Path(old_path).stem} | {Path(new_path).stem} | Δ |")
    print("|---|---|---|---|")
    for label, path, hib in _SERVE_METRICS:
        a, b = _serve_metric(old, path), _serve_metric(new, path)
        if a is None and b is None:
            continue
        fa = f"{a:.3g}" if a is not None else "—"
        fb = f"{b:.3g}" if b is not None else "—"
        if a is None or b is None:
            d = "new" if a is None else "dropped"
        elif a == b:
            d = "="
        else:
            denom = a if hib else b
            ratio = ((b / a) if hib else (a / b)) if denom else float("inf")
            d = f"{ratio:.2f}× {'better' if ratio >= 1 else 'worse'}"
        print(f"| {label} | {fa} | {fb} | {d} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1pod")
    ap.add_argument("--serve", nargs=2, metavar=("OLD.json", "NEW.json"),
                    help="diff two serve-bench records instead of the "
                         "dry-run rooflines")
    args = ap.parse_args()
    if args.serve:
        serve_delta(*args.serve)
        return
    base = load("results/dryrun_baseline", args.mesh)
    final = load("results/dryrun", args.mesh)

    print("| arch | shape | baseline dom. term (s) | final dom. term (s) | Δ | bottleneck b→f |")
    print("|---|---|---|---|---|---|")
    total_b = total_f = 0.0
    for key in sorted(base, key=lambda k: (k[0], SHAPE_ORDER.get(k[1], 9))):
        if key not in final:
            continue
        rb, rf = base[key]["roofline"], final[key]["roofline"]
        tb = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
        tf = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        total_b += tb
        total_f += tf
        d = f"{tb / tf:.1f}×" if tf else "—"
        print(f"| {key[0]} | {key[1]} | {tb:.3g} | {tf:.3g} | {d} "
              f"| {rb['bottleneck']}→{rf['bottleneck']} |")
    print(f"\nsum of dominant terms: {total_b:.1f} s -> {total_f:.1f} s "
          f"({total_b / total_f:.1f}x)")


if __name__ == "__main__":
    main()
