"""Render the baseline-vs-final dominant-roofline-term comparison for
EXPERIMENTS.md §Perf spillover: the §Perf work shipped as production
defaults, so EVERY pair moved, not just the three hillclimbed ones.

  PYTHONPATH=src python -m benchmarks.perf_delta [--mesh 1pod]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(d: str, mesh: str) -> dict:
    out = {}
    for f in glob.glob(f"{d}/*_{mesh}.json"):
        r = json.load(open(f))
        if r.get("ok"):
            out[(r["arch"], r["shape"])] = r
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1pod")
    args = ap.parse_args()
    base = load("results/dryrun_baseline", args.mesh)
    final = load("results/dryrun", args.mesh)

    print("| arch | shape | baseline dom. term (s) | final dom. term (s) | Δ | bottleneck b→f |")
    print("|---|---|---|---|---|---|")
    total_b = total_f = 0.0
    for key in sorted(base, key=lambda k: (k[0], SHAPE_ORDER.get(k[1], 9))):
        if key not in final:
            continue
        rb, rf = base[key]["roofline"], final[key]["roofline"]
        tb = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
        tf = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        total_b += tb
        total_f += tf
        d = f"{tb / tf:.1f}×" if tf else "—"
        print(f"| {key[0]} | {key[1]} | {tb:.3g} | {tf:.3g} | {d} "
              f"| {rb['bottleneck']}→{rf['bottleneck']} |")
    print(f"\nsum of dominant terms: {total_b:.1f} s -> {total_f:.1f} s "
          f"({total_b / total_f:.1f}x)")


if __name__ == "__main__":
    main()
