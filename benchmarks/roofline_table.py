"""Render the §Roofline / §Dry-run tables of EXPERIMENTS.md from
results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh 1pod|2pod] [--tag ""]

``--achieved`` switches to MEASURED mode: instead of rendering saved
dry-run (predicted) rooflines, it times each serving Pallas kernel —
fused_matmul, decode_attn, chunk_prefill_attn, mlstm_chunk, slstm_cell,
plus the fused decode_layer megakernel and the logits_sample
(final-norm + unembed + greedy argmax) kernel —
at ``--arch``'s serving shapes and prints achieved FLOP/s / bytes/s
against the same roofline envelope (repro.serving.obs.kernel_profile).
On non-TPU backends the kernels run in the Pallas interpreter and every
row says so — CPU figures characterize the interpreter, not silicon.

  PYTHONPATH=src python -m benchmarks.roofline_table --achieved \\
      [--arch tinyllama-1.1b] [--slots 4] [--achieved-context 128] \\
      [--achieved-chunk 32] [--repeats 3] [--achieved-json out.json]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh: str, tag: str = "", d: str = "results/dryrun"):
    rows = []
    for f in glob.glob(f"{d}/*_{mesh}{tag}.json"):
        stem = Path(f).stem
        if tag == "" and (stem.count("_m") or "_opt" in stem):
            # skip tagged variants when rendering the baseline table
            if not stem.endswith(mesh):
                continue
        rows.append(json.load(open(f)))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return rows


def fmt(rows, *, show_mem=True) -> str:
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck "
        "| MODEL_FLOPs/chip | useful ratio | HBM GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            why = r.get("skipped", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP ({why}) | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        gb = (mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)) / 1e9
        ur = r.get("useful_compute_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.2e} | {t['t_memory_s']:.2e} "
            f"| {t['t_collective_s']:.2e} | **{t['bottleneck']}** "
            f"| {r['model_flops_per_chip']:.2e} | {ur:.2f} | {gb:.1f} | {r['compile_s']:.0f} |"
        )
    return "\n".join(out)


def achieved(args) -> None:
    """The --achieved mode: time the serving kernels at --arch's shapes
    and print the achieved-vs-roofline table."""
    from repro.configs import registry
    from repro.serving.obs import (
        format_table, profile_serving_kernels, validate_profile,
    )
    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    cfg = cfg.with_(num_instances=args.num_instances)
    rows = profile_serving_kernels(
        cfg, slots=args.slots, max_context=args.achieved_context,
        chunk=args.achieved_chunk, prefill_lanes=args.lanes,
        repeats=args.repeats,
    )
    validate_profile(rows)
    print(format_table(rows))
    if rows and rows[0]["interpret"]:
        print("\n(interpret mode: figures characterize the Pallas "
              "interpreter on this backend, not silicon)")
    if args.achieved_json:
        with open(args.achieved_json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.achieved_json}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1pod", choices=["1pod", "2pod"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--dir", default="results/dryrun",
                    help="results/dryrun_baseline for the pre-§Perf snapshot")
    ap.add_argument("--achieved", action="store_true",
                    help="measure the serving kernels instead of rendering "
                         "saved dry-run predictions")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="published config instead of the smoke config")
    ap.add_argument("--num-instances", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--achieved-context", type=int, default=128)
    ap.add_argument("--achieved-chunk", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--achieved-json", default=None)
    args = ap.parse_args()
    if args.achieved:
        achieved(args)
        return
    rows = load(args.mesh, args.tag, args.dir)
    print(fmt(rows))
    n_ok = sum(1 for r in rows if r.get("ok"))
    n_skip = sum(1 for r in rows if "skipped" in r)
    print(f"\n{n_ok} compiled OK, {n_skip} documented skips, "
          f"{len(rows) - n_ok - n_skip} failures")


if __name__ == "__main__":
    main()
