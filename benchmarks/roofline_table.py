"""Render the §Roofline / §Dry-run tables of EXPERIMENTS.md from
results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh 1pod|2pod] [--tag ""]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh: str, tag: str = "", d: str = "results/dryrun"):
    rows = []
    for f in glob.glob(f"{d}/*_{mesh}{tag}.json"):
        stem = Path(f).stem
        if tag == "" and (stem.count("_m") or "_opt" in stem):
            # skip tagged variants when rendering the baseline table
            if not stem.endswith(mesh):
                continue
        rows.append(json.load(open(f)))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return rows


def fmt(rows, *, show_mem=True) -> str:
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck "
        "| MODEL_FLOPs/chip | useful ratio | HBM GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            why = r.get("skipped", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP ({why}) | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        gb = (mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)) / 1e9
        ur = r.get("useful_compute_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.2e} | {t['t_memory_s']:.2e} "
            f"| {t['t_collective_s']:.2e} | **{t['bottleneck']}** "
            f"| {r['model_flops_per_chip']:.2e} | {ur:.2f} | {gb:.1f} | {r['compile_s']:.0f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1pod", choices=["1pod", "2pod"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--dir", default="results/dryrun",
                    help="results/dryrun_baseline for the pre-§Perf snapshot")
    args = ap.parse_args()
    rows = load(args.mesh, args.tag, args.dir)
    print(fmt(rows))
    n_ok = sum(1 for r in rows if r.get("ok"))
    n_skip = sum(1 for r in rows if "skipped" in r)
    print(f"\n{n_ok} compiled OK, {n_skip} documented skips, "
          f"{len(rows) - n_ok - n_skip} failures")


if __name__ == "__main__":
    main()
