"""Benchmark harness — one function per paper table/figure.

Paper: NetFuse (Jeong et al. 2020).  Figures reproduced (CPU-scaled —
the GPU models are reduced so 1000s of fused forwards stay tractable;
relative speedups, not absolute times, are the claim under test):

  fig5  inference time vs #models, bs=1 (sequential / concurrent / netfuse)
        on resnet / resnext / bert / xlnet
  fig6  BERT batch-size sweep (relative to netfuse): the benefit shrinks
        as per-model batch grows (paper: crossover by bs=8)
  fig7  memory: weights+workspace per strategy (compiled memory_analysis)
  fig8  hybrid strategies (P concurrent groups x M/P sequential)
  tab_merge   offline merge overhead vs #models (paper §4: ~600 ms @ 32)
  tab_exact   merged outputs == per-instance outputs (paper: "does not
              alter the computation results in any way")

Output: ``name,us_per_call,derived`` CSV rows on stdout.
Env: REPRO_BENCH_REPEATS (default 30), REPRO_BENCH_MAX_MODELS (default 16).
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cnn, common, encoder

REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "30"))
MAX_MODELS = int(os.environ.get("REPRO_BENCH_MAX_MODELS", "16"))

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, repeats=None) -> float:
    """Median wall time (us) of fn(), after warmup."""
    repeats = repeats or REPEATS
    jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# CPU-scaled versions of the paper's four eval models
# ---------------------------------------------------------------------------


def _bench_models():
    cnn_cfg = ModelConfig(
        name="resnet-bench", family="cnn", num_layers=0, d_model=0,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
        cnn_stage_blocks=(2, 2), cnn_width=16, cnn_cardinality=1,
        image_size=32, num_classes=16,
        dtype="float32", param_dtype="float32",
    )
    next_cfg = cnn_cfg.with_(name="resnext-bench", cnn_cardinality=4)
    enc_cfg = ModelConfig(
        name="bert-bench", family="encoder", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=1024,
        max_target_positions=128, use_layernorm=True, act="gelu",
        dtype="float32", param_dtype="float32",
    )
    return {
        "resnet50": ("cnn", cnn_cfg),
        "resnext50": ("cnn", next_cfg),
        "bert": ("enc", enc_cfg.with_(name="bert-bench")),
        "xlnet": ("encx", enc_cfg.with_(name="xlnet-bench")),
    }


def _make_apply(kind, cfg):
    if kind == "cnn":
        def apply_fn(params, x):
            return jnp.stack(cnn.forward(cfg, params, x))  # (M, B, classes)
        def init_fn(key, m):
            return [cnn.init(cfg, k) for k in jax.random.split(key, m)], cnn.axes(cfg)
        def inp(key, m, b):
            return jax.random.normal(key, (m, b, cfg.image_size, cfg.image_size, 3))
        return apply_fn, init_fn, inp
    xl = kind == "encx"
    def apply_fn(params, x):
        return encoder.forward(cfg, params, x, xlnet=xl)
    def init_fn(key, m):
        cfg1 = cfg.with_(num_instances=1)
        ps = [encoder.init(cfg1, k, xlnet=xl) for k in jax.random.split(key, m)]
        return ps, encoder.axes(cfg1, xlnet=xl)
    def inp(key, m, b):
        return jax.random.randint(key, (m, b, 128), 0, cfg.vocab_size)
    return apply_fn, init_fn, inp


def _strategies(apply_fn, params_list, axes, x):
    """name -> zero-arg callable running one multi-model inference round."""
    m = len(params_list)
    merged = common.merge_instances(params_list, axes)
    fused = jax.jit(apply_fn)
    single = jax.jit(apply_fn)

    def netfuse():
        return fused(merged, x)

    def sequential():
        return [single(params_list[i], x[i : i + 1]) for i in range(m)]

    @jax.jit
    def _concurrent(ps, xs):
        return [apply_fn(p, xs[i : i + 1]) for i, p in enumerate(ps)]

    def concurrent():
        return _concurrent(params_list, x)

    return {"sequential": sequential, "concurrent": concurrent, "netfuse": netfuse}


def fig5_inference_time():
    """Paper Fig. 5: mean inference time vs number of models (bs=1)."""
    counts = [n for n in (1, 2, 4, 8, 16, 32) if n <= MAX_MODELS]
    for model_name, (kind, cfg) in _bench_models().items():
        apply_fn, init_fn, inp = _make_apply(kind, cfg)
        for m in counts:
            params_list, axes = init_fn(jax.random.PRNGKey(0), m)
            x = inp(jax.random.PRNGKey(1), m, 1)
            strat = _strategies(apply_fn, params_list, axes, x)
            times = {}
            for name, fn in strat.items():
                times[name] = _timeit(fn)
                emit(f"fig5/{model_name}/m{m}/{name}", times[name])
            emit(
                f"fig5/{model_name}/m{m}/speedup_vs_sequential",
                times["netfuse"],
                f"{times['sequential'] / times['netfuse']:.2f}x",
            )


def fig6_batch_sweep():
    """Paper Fig. 6: BERT, batch sizes 1..8, times relative to netfuse."""
    kind, cfg = _bench_models()["bert"]
    apply_fn, init_fn, inp = _make_apply(kind, cfg)
    m = min(8, MAX_MODELS)
    params_list, axes = init_fn(jax.random.PRNGKey(0), m)
    for bs in (1, 2, 4, 8):
        x = inp(jax.random.PRNGKey(1), m, bs)
        strat = _strategies(apply_fn, params_list, axes, x)
        t_fuse = _timeit(strat["netfuse"])
        for name in ("sequential", "concurrent"):
            t = _timeit(strat[name])
            emit(f"fig6/bert/bs{bs}/{name}_rel_netfuse", t, f"{t / t_fuse:.2f}x")
        emit(f"fig6/bert/bs{bs}/netfuse", t_fuse, "1.00x")


def fig7_memory():
    """Paper Fig. 7: weights + workspace per strategy (bytes from the
    compiled executables' memory_analysis; JAX has no per-process base
    cost, so the paper's PyTorch 500MB/process term is absent — see
    DESIGN.md §2.3)."""
    kind, cfg = _bench_models()["bert"]
    apply_fn, init_fn, inp = _make_apply(kind, cfg)
    for m in [n for n in (2, 8, 16) if n <= MAX_MODELS]:
        params_list, axes = init_fn(jax.random.PRNGKey(0), m)
        x = inp(jax.random.PRNGKey(1), m, 1)
        merged = common.merge_instances(params_list, axes)

        def _mem(*args):
            c = jax.jit(apply_fn).lower(*args).compile()
            ma = c.memory_analysis()
            return (ma.temp_size_in_bytes + ma.argument_size_in_bytes) / 1e6

        fused_mb = _mem(merged, x)
        seq_mb = _mem(params_list[0], x[:1])  # one model resident at a time
        conc_mb = seq_mb * m                  # all M resident
        emit(f"fig7/bert/m{m}/netfuse_MB", fused_mb * 1e3, f"{fused_mb:.1f}MB")
        emit(f"fig7/bert/m{m}/sequential_MB", seq_mb * 1e3, f"{seq_mb:.1f}MB")
        emit(f"fig7/bert/m{m}/concurrent_MB", conc_mb * 1e3, f"{conc_mb:.1f}MB")


def fig8_hybrid():
    """Paper Fig. 8: hybrid (P concurrent groups x M/P sequential)."""
    kind, cfg = _bench_models()["resnext50"]
    apply_fn, init_fn, inp = _make_apply(kind, cfg)
    m = min(8, MAX_MODELS)
    params_list, axes = init_fn(jax.random.PRNGKey(0), m)
    x = inp(jax.random.PRNGKey(1), m, 1)
    strat = _strategies(apply_fn, params_list, axes, x)
    t_seq = _timeit(strat["sequential"])
    t_fuse = _timeit(strat["netfuse"])
    emit(f"fig8/resnext/m{m}/sequential", t_seq, f"{t_seq/t_fuse:.2f}x vs netfuse")

    @jax.jit
    def _group(ps, xs):
        return [apply_fn(p, xs[i : i + 1]) for i, p in enumerate(ps)]

    for p_groups in (2, 4):
        per = m // p_groups
        def hybrid(per=per):
            return [
                _group(params_list[g : g + per], x[g : g + per])
                for g in range(0, m, per)
            ]
        t = _timeit(hybrid)
        emit(f"fig8/resnext/m{m}/hybrid_{p_groups}groups", t, f"{t/t_fuse:.2f}x vs netfuse")
    emit(f"fig8/resnext/m{m}/netfuse", t_fuse, "1.00x")


def tab_merge_overhead():
    """Paper §4: merging overhead (offline, amortized). Paper reports
    ~600 ms for 32 ResNeXt-50s; ours is a tree-stack over checkpoints."""
    kind, cfg = _bench_models()["resnext50"]
    _, init_fn, _ = _make_apply(kind, cfg)
    for m in (2, 8, 16, 32):
        params_list, axes = init_fn(jax.random.PRNGKey(0), m)
        t0 = time.perf_counter()
        merged = common.merge_instances(params_list, axes)
        jax.block_until_ready(jax.tree.leaves(merged))
        emit(f"tab_merge/resnext/m{m}", (time.perf_counter() - t0) * 1e6)


def tab_exactness():
    """Merged == per-instance, max |diff| (paper: exact)."""
    for model_name, (kind, cfg) in _bench_models().items():
        apply_fn, init_fn, inp = _make_apply(kind, cfg)
        m = 4
        params_list, axes = init_fn(jax.random.PRNGKey(0), m)
        x = inp(jax.random.PRNGKey(1), m, 2)
        merged = common.merge_instances(params_list, axes)
        fused = apply_fn(merged, x)
        worst = 0.0
        for i in range(m):
            ref = apply_fn(params_list[i], x[i : i + 1])
            worst = max(worst, float(jnp.max(jnp.abs(fused[i] - ref[0]))))
        emit(f"tab_exact/{model_name}/max_abs_diff", 0.0, f"{worst:.2e}")


def main() -> None:
    print("name,us_per_call,derived")
    fig5_inference_time()
    fig6_batch_sweep()
    fig7_memory()
    fig8_hybrid()
    tab_merge_overhead()
    tab_exactness()
    # summary: peak netfuse speedups per model (the paper's headline)
    best: dict[str, float] = {}
    for name, us, derived in ROWS:
        if name.startswith("fig5/") and name.endswith("speedup_vs_sequential"):
            best[name.split("/")[1]] = max(
                best.get(name.split("/")[1], 0.0), float(derived[:-1])
            )
    for model, sp in best.items():
        emit(f"summary/{model}/best_netfuse_speedup", 0.0, f"{sp:.2f}x")


if __name__ == "__main__":
    main()
