"""Serving benchmark: fused (M, B)-grid serving vs M sequential servers,
the tail-folding admission A/B, and an open-loop async load generator.

The paper's headline claim restated at the serving-system level: one
NetFuse-merged `MultiModelServer` over M instances vs M single-model
servers drained one after another (the paper's "sequential" strategy),
same request set, same slot budget per instance.  On top of that, the
record carries a ``tail_folding`` section — the same fused workload
served with the padded-final-chunk admission ON vs OFF — splitting
throughput into prefill vs decode tokens/s and recording
``device_calls_per_admission``, so the admission-latency trajectory is
tracked from this record onward (``BENCH_serve.json``).

Run: PYTHONPATH=src python benchmarks/serve_bench.py \
         [--arch tinyllama-1.1b] [--num-instances 4] [--requests 24] \
         [--devices 8] [--mesh-shape 2x4] [--json-out BENCH_serve.json]

``--devices N`` forces N host-platform devices (consumed before the
first jax init) and serves the fused grid under a mesh (``--mesh-shape
DxT``, default all-data); the JSON record then carries the mesh shape,
per-device throughput, and the tail-folding A/B on BOTH the no-mesh and
the mesh path.  Every throughput field is validated finite before the
record is written — a missing/NaN figure fails the run (CI bench-smoke).

Load generator (``--clients N --arrival-rate R``): an OPEN-loop arrival
process — request arrival times are drawn up front from an exponential
inter-arrival distribution at R req/s and split round-robin over N
async client tasks, each of which fires its submissions at the
scheduled instants regardless of completions (consumers are spawned,
not awaited), so queueing delay shows up in the tails instead of
throttling the offered load.  The run streams through the
``AsyncEngine`` frontend and contributes per-instance TTFT and
inter-token-latency p50/p95/p99 to the record (``load_gen`` section) —
validated finite like every other throughput field.

Decode-horizon sweep (``decode_horizon`` section, DESIGN.md §6.6): the
same fused workload served at K ∈ {1, 2, 4, 8} decode steps per device
call — on BOTH the no-mesh and the mesh path when serving sharded —
recording per-K decode throughput (over the blocks' own settled
dispatch->host wall), decode device calls, tokens per device call,
host dispatch ms per token, and speedup vs the sequential baseline.
Two amortization figures fall out: ``k8_vs_k1_decode_speedup`` (the
end-to-end decode-wall ratio — on CPU hosts the in-scan per-step
compute dominates the ~0.3 ms amortizable dispatch, so expect well
under K; dispatch-bound accelerator backends approach K) and
``k8_vs_k1_dispatch_per_token_reduction`` (the dispatch slice itself,
~K-fold anywhere).  The headline fused pass runs at ``--decode-steps``
(default 8).

Recovery (``recovery`` section, DESIGN.md §6.8, ``--fault-plan``): the
same workload served clean and then under a deterministic fault plan
with a Supervisor recovering the driver — restart count, watchdog
timeouts, time-to-recover, tokens replayed, and the acceptance
invariants validated on every record: ``tokens_lost == 0`` and greedy
streams byte-identical to the fault-free run.

Observability (``obs`` section, DESIGN.md §6.5): a step-traced pass
records per-device-call dispatch overhead p50/p95/p99, mean grid
occupancy, idle-slot token-steps and the tracing on/off throughput A/B;
``dispatch_overhead_ms`` and ``mean_grid_occupancy`` are promoted to
top-level fields so ``perf_delta.py --serve`` can diff the dispatch
trajectory across PRs.  ``--trace-out trace.json`` dumps the pass's
Chrome-trace JSON (Perfetto / chrome://tracing); ``--profile-kernels``
times each serving Pallas kernel at the run's shapes and records
achieved-vs-roofline figures (``kernel_roofline``).

Tenant accounting + SLOs (``tenant_attribution`` + ``load_gen.slo``
sections, DESIGN.md §6.9): the traced pass also runs the per-tenant
device-time ledger — per-tenant decode/prefill/scatter/idle
device-seconds, head-of-line interference, and the conservation
invariant (attributed time re-sums to settled wall; rel err < 1% is an
acceptance check on every record).  The load-gen pass evaluates
TTFT/ITL error budgets (``--slo-ttft-ms``/``--slo-itl-ms``) over its
log-bucketed histograms, recording per-instance burn rate, budget
remaining, and ok/burning/violated state.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time

# --devices must be applied before the first jax backend init (the
# device count locks there; importing jax below is still safe)
from repro.launch.compat import force_host_devices_from_argv, mesh_from_args

force_host_devices_from_argv(sys.argv)

import numpy as np

import jax

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import MultiModelServer, Request


def _mk_requests(rng, m, n, vocab, max_new, pmin=3, pmax=12):
    return [
        Request(
            instance=i % m,
            prompt=rng.integers(1, vocab, size=int(rng.integers(pmin, pmax))).tolist(),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _drain(server, reqs) -> dict:
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    results = server.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    return {
        "requests": len(results),
        "tokens": toks,
        "wall_s": dt,
        "tok_per_s": toks / dt,
        "decode_steps": server.steps,
    }


def _timed_pass(server, reqs) -> dict:
    """Drain ``reqs`` and report the pass's own deltas: prefill vs decode
    throughput split, admission device-call counts, stall."""
    met = server.metrics
    base = (met.prefill_wall_s, met.prefill_tokens, met.prefill_batches,
            met.admitted, met.admission_stall_s, server.steps,
            met.decode_wall_s, met.decode_tokens)
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    results = server.run_until_drained()
    wall = time.perf_counter() - t0
    gen = sum(len(r.tokens) for r in results)
    pw = met.prefill_wall_s - base[0]
    ptok = met.prefill_tokens - base[1]
    calls = met.prefill_batches - base[2]
    admitted = met.admitted - base[3]
    # decode rate over the fused blocks' own settled device wall (the
    # engine times every dispatch->host call) — scatter/scheduler/host
    # time would otherwise dilute the multi-step dispatch amortization
    dw = met.decode_wall_s - base[6]
    dtok = met.decode_tokens - base[7]
    return {
        "requests": len(results),
        "tokens": gen,
        "wall_s": wall,
        "tok_per_s": gen / wall,
        "prefill_tokens": ptok,
        "prefill_wall_s": pw,
        "prefill_tok_per_s": ptok / pw if pw > 0 else 0.0,
        "decode_tok_per_s": (dtok / dw if dw > 0
                             else gen / max(wall - pw, 1e-9)),
        "decode_wall_s": dw,
        "device_calls": calls,
        "device_calls_per_admission": calls / max(admitted, 1),
        "compiled_shapes": server.prefill.compiled_shapes,
        "admission_stall_ms": 1e3 * (met.admission_stall_s - base[4]),
        "decode_steps": server.steps - base[5],
    }


def _fold_ab(cfg, merged, mesh, args, reqs) -> dict:
    """Tail-folding A/B on one mesh setting: the same workload served
    with the padded-final-chunk admission OFF (chunk + per-token tails,
    the pre-change baseline) then ON — fresh servers, compile warmup
    excluded from the timed pass."""
    out = {}
    for key, fold in (("fold_off", False), ("fold_on", True)):
        server = _mk_server(cfg, merged, mesh, args, tail_fold=fold)
        mk = lambda: [Request(r.instance, list(r.prompt), r.max_new_tokens)
                      for r in reqs]
        _timed_pass(server, mk())          # compile warmup
        out[key] = _timed_pass(server, mk())
    off, on = out["fold_off"], out["fold_on"]
    out["prefill_speedup"] = (
        on["prefill_tok_per_s"] / off["prefill_tok_per_s"]
        if off["prefill_tok_per_s"] > 0 else None
    )
    out["device_call_reduction"] = (
        off["device_calls"] / on["device_calls"] if on["device_calls"] else None
    )
    return out


def _mk_server(cfg, merged, mesh, args, **overrides) -> MultiModelServer:
    """The ONE construction point for every benchmark pass (fused,
    fold A/B, decode-horizon sweep, load gen), so admission knobs can't
    silently diverge between the variants under comparison."""
    kw = dict(
        slots_per_instance=args.slots,
        max_context=args.resolved_max_context, temperature=0.0, mesh=mesh,
        prefill_chunk=args.chunk, chunk_budget=args.chunk_budget,
        prefill_lanes=args.lanes, decode_steps=args.decode_steps,
    )
    kw.update(overrides)
    return MultiModelServer(cfg, merged, **kw)


_SWEEP_KS = (1, 2, 4, 8)


def _decode_sweep(cfg, merged, mesh, args, reqs, seq_wall) -> dict:
    """Decode-horizon A/B (DESIGN.md §6.6): the same workload served at
    K ∈ {1, 2, 4, 8} fused decode steps per device call — fresh server
    per K, compile warmup excluded from the timed pass — recording
    decode throughput, decode device calls, tokens per device call, and
    speedup vs the sequential baseline (streams are bit-identical
    across K under this greedy config, so every pass serves the exact
    same tokens)."""
    out = {"ks": list(_SWEEP_KS), "per_k": {}}
    mk = lambda: [Request(r.instance, list(r.prompt), r.max_new_tokens)
                  for r in reqs]
    for K in _SWEEP_KS:
        server = _mk_server(cfg, merged, mesh, args, decode_steps=K)
        _timed_pass(server, mk())          # compile warmup
        met = server.metrics
        base = (met.decode_calls, met.decode_steps, met.decode_tokens,
                met.decode_dispatch_s)
        d = _timed_pass(server, mk())
        calls = met.decode_calls - base[0]
        dtok = met.decode_tokens - base[2]
        out["per_k"][str(K)] = {
            "tok_per_s": d["tok_per_s"],
            "decode_tok_per_s": d["decode_tok_per_s"],
            "wall_s": d["wall_s"],
            "decode_device_calls": calls,
            "decode_scan_steps": met.decode_steps - base[1],
            "tokens_per_device_call": dtok / max(calls, 1),
            "dispatch_ms_per_token": (
                1e3 * (met.decode_dispatch_s - base[3]) / max(dtok, 1)),
            "speedup_vs_sequential": seq_wall / d["wall_s"],
        }
    k1 = out["per_k"]["1"]
    k8 = out["per_k"][str(_SWEEP_KS[-1])]
    # the tentpole acceptance figures.  decode_speedup is the honest
    # settled-decode-wall ratio: on CPU hosts the in-scan per-step
    # compute dominates the ~0.3 ms amortizable dispatch, so it lands
    # well under K; dispatch_per_token_reduction isolates the dispatch
    # slice itself, which drops ~K-fold wherever the block runs (and on
    # dispatch-bound accelerator backends drags the wall ratio with it)
    out["k8_vs_k1_decode_speedup"] = (
        k8["decode_tok_per_s"] / k1["decode_tok_per_s"]
        if k1["decode_tok_per_s"] > 0 else None)
    out["k8_vs_k1_call_reduction"] = (
        k1["decode_device_calls"] / max(k8["decode_device_calls"], 1))
    out["k8_vs_k1_dispatch_per_token_reduction"] = (
        k1["dispatch_ms_per_token"] / k8["dispatch_ms_per_token"]
        if k8["dispatch_ms_per_token"] > 0 else None)
    return out


_LAUNCH_SKIP = {
    # layout/metadata-only primitives XLA never dispatches a kernel for
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "convert_element_type", "copy", "stop_gradient", "slice", "split",
}


def _sub_jaxprs(params: dict):
    """Yield every (closed) sub-jaxpr hiding in an eqn's params."""
    for val in params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if hasattr(v, "eqns"):
                yield v
            elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield v.jaxpr


def _count_launches(jaxpr) -> int:
    """Kernel-launch proxy for one traced decode block: count compute
    primitives, recursing through pjit/shard_map/while/cond and
    multiplying a scan body by its trip count.  A ``pallas_call`` counts
    as ONE launch no matter how much runs inside it — which is exactly
    the megakernel's claim.  (XLA fusion means the absolute numbers
    overstate real launches on both sides; the unfused/megakernel RATIO
    is the figure of merit.)"""
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            total += 1
            continue
        subs = list(_sub_jaxprs(eqn.params))
        if subs:
            inner = sum(_count_launches(s) for s in subs)
            if name == "scan":
                inner *= int(eqn.params.get("length", 1))
            total += inner
            continue
        if name not in _LAUNCH_SKIP:
            total += 1
    return total


def _kernel_launch_ab(cfg, merged, mesh, args) -> dict | None:
    """Megakernel A/B (ISSUE 8): trace ONE greedy decode step unfused vs
    fused-layer megakernel and compare the launch proxy, on the no-mesh
    and (when serving sharded) mesh paths.  Dense/vlm only — the other
    families keep their per-op decode graphs."""
    if cfg.family not in ("dense", "vlm"):
        return None
    out = {}
    for mesh_key, msh in (("no_mesh", None), ("mesh", mesh)):
        if mesh_key == "mesh" and msh is None:
            out[mesh_key] = None
            continue
        sides = {}
        for side, flag in (("unfused", False), ("megakernel", True)):
            srv = _mk_server(cfg.with_(use_pallas_kernels=flag), merged, msh,
                             args, decode_steps=1)
            z = np.zeros((srv.m, srv.b), np.int32)
            alive = np.zeros((srv.m, srv.b), bool)
            with srv._ctx():
                closed = jax.make_jaxpr(srv._make_block(1))(
                    srv.params, srv.cache, z, z, srv._key, alive, z)
            sides[side] = _count_launches(closed.jaxpr)
        sides["reduction"] = sides["unfused"] / max(sides["megakernel"], 1)
        out[mesh_key] = sides
    return out


def _run_load_gen(cfg, merged, mesh, args, reqs) -> dict:
    """Open-loop load generation through the AsyncEngine: pre-drawn
    exponential arrivals at ``--arrival-rate`` req/s, round-robin over
    ``--clients`` concurrent client tasks; consumers are fire-and-forget
    so arrivals never wait on completions."""
    from repro.serving.frontend import AsyncEngine
    from repro.serving.obs import SLOConfig

    slo = (SLOConfig(ttft_ms=args.slo_ttft_ms or None,
                     itl_ms=args.slo_itl_ms or None)
           if (args.slo_ttft_ms > 0 or args.slo_itl_ms > 0) else None)
    server = _mk_server(cfg, merged, mesh, args, slo=slo)
    # compile warmup outside the timed/streamed pass; fresh metrics after,
    # so the recorded percentiles carry no compile-time TTFT outlier
    server.submit(Request(0, list(reqs[0].prompt), reqs[0].max_new_tokens))
    server.run_until_drained()
    server.reset_metrics()

    rng = np.random.default_rng(args.seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                         size=len(reqs)))

    async def run() -> list:
        engine = AsyncEngine(server)
        results: list = []
        consumers: list[asyncio.Task] = []
        t0 = asyncio.get_running_loop().time()

        async def fire(j: int):
            # submit() resolves only when the driver applies the command
            # between steps — keep even that wait off the arrival clock
            # (submit_time is stamped at this call, so the recorded TTFT
            # still counts it)
            stream = await engine.submit(Request(
                reqs[j].instance, list(reqs[j].prompt),
                reqs[j].max_new_tokens,
            ))
            async for _tok in stream:
                pass
            results.append(await stream.result())

        async def client(worker: int):
            # each client owns every worker-th arrival of the shared
            # open-loop schedule and fires it at its scheduled instant
            loop = asyncio.get_running_loop()
            for j in range(worker, len(reqs), args.clients):
                delay = t0 + arrivals[j] - loop.time()
                if delay > 0:
                    await asyncio.sleep(delay)
                consumers.append(asyncio.ensure_future(fire(j)))

        await asyncio.gather(*(client(w) for w in range(args.clients)))
        await asyncio.gather(*consumers)
        await engine.aclose()
        return results

    t0 = time.perf_counter()
    results = asyncio.run(run())
    wall = time.perf_counter() - t0
    gen = sum(len(r.tokens) for r in results if r.status == "ok")
    snap = server.metrics.snapshot()
    return {
        "clients": args.clients,
        "arrival_rate": args.arrival_rate,
        "requests": len(results),
        "completed": sum(1 for r in results if r.status == "ok"),
        "tokens": gen,
        "wall_s": wall,
        "tok_per_s": gen / wall,
        "decode_steps": snap["decode_steps"],
        "ttft_ms": snap["ttft_ms"],
        "itl_ms": snap["itl_ms"],
        "per_instance": [
            {"ttft_ms": inst["ttft_ms"], "itl_ms": inst["itl_ms"],
             "completed": inst["completed"],
             "generated_tokens": inst["generated_tokens"]}
            for inst in snap["instances"]
        ],
        # per-instance error-budget view of the run (§6.9); percentiles
        # above already come from the unbiased log-bucketed histograms
        "slo": snap.get("slo"),
    }


def _run_observed(cfg, merged, mesh, args, reqs) -> tuple[dict, dict]:
    """The observability pass (DESIGN.md §6.5): the fused workload run
    once with step tracing OFF and once ON — the off pass prices the
    disabled tracer (one attribute read per call site), the on pass
    yields per-device-call dispatch gaps, grid occupancy and request
    spans.  Returns (obs section, chrome trace)."""
    server = _mk_server(cfg, merged, mesh, args)
    mk = lambda: [Request(r.instance, list(r.prompt), r.max_new_tokens)
                  for r in reqs]
    _drain(server, mk())               # compile warmup
    off = _drain(server, mk())
    server.tracer.start()
    server.accounting.start()          # tenant attribution rides the
    on = _drain(server, mk())          # same settle points (§6.9)
    server.tracer.stop()
    server.accounting.stop()
    summary = server.tracer.summary()
    chrome = server.tracer.export_chrome()
    acct = server.accounting.snapshot()
    obs = dict(summary)
    obs.update({
        "tok_per_s_untraced": off["tok_per_s"],
        "tok_per_s_traced": on["tok_per_s"],
        # tracing-ON cost (per-chunk settling + event records); the
        # tracing-OFF cost is structurally zero — the guard test in
        # tests/test_serving_obs.py proves no tracer code runs at all
        "tracing_overhead_pct": 100.0 * (
            off["tok_per_s"] / on["tok_per_s"] - 1.0
        ) if on["tok_per_s"] > 0 else None,
        "trace_events": len(chrome["traceEvents"]),
    })
    # the §6.9 attribution ledger for the traced pass: per-tenant
    # device-second accounts + the conservation invariant (CI
    # bench-smoke asserts rel err < 1%)
    attribution = {
        "conservation_rel_err": acct["conservation_rel_err"],
        "settled_s": acct["settled_s"],
        "attributed_s": acct["attributed_s"],
        "idle_total_s": acct["idle_total_s"],
        "device_calls": acct["device_calls"],
        "per_tenant": acct["per_tenant"],
        "interference": acct["interference"],
    }
    return obs, chrome, attribution


def _run_recovery(cfg, merged, mesh, args, reqs) -> dict:
    """Fault-injected recovery pass (DESIGN.md §6.8): the same workload
    served clean (sync baseline) and then under the ``--fault-plan``
    with a Supervisor recovering the driver — recording restart count,
    time-to-recover, tokens replayed, and the acceptance invariants:
    ``tokens_lost == 0`` and byte-identical greedy streams."""
    from repro.serving import AsyncEngine, FaultInjector, Supervisor

    mk = lambda: [Request(r.instance, list(r.prompt), r.max_new_tokens)
                  for r in reqs]

    # baseline: fresh server, warmup pass (burns the same request-id
    # range on both sides so the measured passes' ids align), then the
    # clean streams
    base_server = _mk_server(cfg, merged, mesh, args)
    _drain(base_server, mk())          # compile warmup
    for r in mk():
        base_server.submit(r)
    want = {r.request_id: list(r.tokens)
            for r in base_server.run_until_drained() if r.status == "ok"}

    # faulted: identical server + plan, warmed BEFORE arming (compiles
    # must neither consume fault-site call counts nor trip the watchdog)
    faults = FaultInjector.from_json(args.fault_plan)
    server = _mk_server(cfg, merged, mesh, args, faults=faults)
    _drain(server, mk())
    faults.arm()

    async def run():
        engine = AsyncEngine(server)
        sup = Supervisor(
            engine, seed=args.seed,
            watchdog_s=(args.watchdog_ms / 1e3
                        if args.watchdog_ms > 0 else None),
        )
        sup.start()

        async def client(r):
            stream = await engine.submit(r)
            toks = [t async for t in stream]
            return stream.request_id, toks, await stream.result()

        t0 = time.perf_counter()
        out = await asyncio.gather(*(client(r) for r in mk()))
        wall = time.perf_counter() - t0
        await engine.aclose()
        return out, sup, wall

    out, sup, wall = asyncio.run(run())
    faults.disarm()
    got = {rid: toks for rid, toks, res in out if res.status == "ok"}
    tokens_lost = sum(
        len(toks) - len(got.get(rid, [])) for rid, toks in want.items())
    snap = sup.snapshot()
    return {
        "fault_plan": args.fault_plan,
        "faults_fired": [list(f) for f in faults.fired],
        "requests": len(out),
        "completed": sum(1 for _, _, res in out if res.status == "ok"),
        "wall_s": wall,
        "restarts": snap["driver_restarts"],
        "watchdog_timeouts": snap["watchdog_timeouts"],
        "request_retries": snap["request_retries"],
        "tokens_replayed": snap["tokens_replayed"],
        "retry_budget_exhausted": snap["retry_budget_exhausted"],
        "time_to_recover_s": snap["last_recovery_s"],
        "tokens_lost": tokens_lost,
        "streams_bit_identical": got == want,
    }


_THROUGHPUT_FIELDS = ("tok_per_s", "prefill_tok_per_s", "decode_tok_per_s",
                      "device_calls_per_admission")
_PCT_KEYS = ("p50", "p95", "p99")


def validate_record(record: dict) -> None:
    """Fail on missing or non-finite throughput figures (CI bench-smoke
    runs this on every record before it is written)."""
    import math as _math

    def check(variant: dict, where: str):
        for f in _THROUGHPUT_FIELDS:
            assert f in variant, f"{where}: missing {f}"
            v = variant[f]
            assert isinstance(v, (int, float)) and _math.isfinite(v), (
                f"{where}: {f} is not finite: {v!r}")

    def check_pct(d, where: str):
        assert d is not None, f"{where}: missing percentiles"
        for k in _PCT_KEYS:
            v = d.get(k)
            assert isinstance(v, (int, float)) and _math.isfinite(v), (
                f"{where}: {k} is not finite: {v!r}")

    for side in ("fused", "sequential"):
        v = record[side]
        assert _math.isfinite(v["tok_per_s"]), (side, v["tok_per_s"])
    for mesh_key, ab in record["tail_folding"].items():
        if ab is None:
            continue
        for key in ("fold_off", "fold_on"):
            check(ab[key], f"tail_folding.{mesh_key}.{key}")
    # decode-horizon sweep: every K's throughput and call counts must be
    # present and finite, and the K=8 acceptance figures real numbers —
    # a silent multi-step regression fails the bench (CI bench-smoke)
    for mesh_key, sweep in record["decode_horizon"].items():
        if sweep is None:
            continue
        for k in sweep["ks"]:
            per = sweep["per_k"][str(k)]
            where = f"decode_horizon.{mesh_key}.per_k.{k}"
            for f in ("tok_per_s", "decode_tok_per_s",
                      "tokens_per_device_call", "dispatch_ms_per_token",
                      "speedup_vs_sequential"):
                v = per[f]
                assert isinstance(v, (int, float)) and _math.isfinite(v), (
                    f"{where}: {f} is not finite: {v!r}")
            assert per["decode_device_calls"] > 0, where
            assert per["decode_scan_steps"] >= per["decode_device_calls"], where
        for f in ("k8_vs_k1_decode_speedup", "k8_vs_k1_call_reduction",
                  "k8_vs_k1_dispatch_per_token_reduction"):
            v = sweep[f]
            assert isinstance(v, (int, float)) and _math.isfinite(v), (
                f"decode_horizon.{mesh_key}: {f} is not finite: {v!r}")
    lg = record["load_gen"]
    if lg is not None:
        assert _math.isfinite(lg["tok_per_s"]), lg["tok_per_s"]
        if lg["completed"]:
            check_pct(lg["ttft_ms"], "load_gen.ttft_ms")
            # ITL needs a request with a second token (e.g. --max-new 1
            # legitimately yields no inter-token gaps)
            if lg["tokens"] > lg["completed"]:
                check_pct(lg["itl_ms"], "load_gen.itl_ms")
        for i, inst in enumerate(lg["per_instance"]):
            # every instance the generator touched must carry finite tails
            if inst["completed"]:
                check_pct(inst["ttft_ms"], f"load_gen.per_instance[{i}].ttft_ms")
                if inst["generated_tokens"] > inst["completed"]:
                    check_pct(inst["itl_ms"],
                              f"load_gen.per_instance[{i}].itl_ms")
    # tenant attribution (§6.9): the conservation invariant is part of
    # the record's validity — attributed per-tenant time must re-sum to
    # settled device wall within 1% (CI bench-smoke acceptance)
    ta = record["tenant_attribution"]
    for f in ("conservation_rel_err", "settled_s", "attributed_s",
              "idle_total_s"):
        v = ta[f]
        assert isinstance(v, (int, float)) and _math.isfinite(v), (
            f"tenant_attribution: {f} is not finite: {v!r}")
    assert ta["settled_s"] > 0 and ta["device_calls"] > 0
    assert ta["conservation_rel_err"] < 0.01, (
        f"attribution conservation violated: rel err "
        f"{ta['conservation_rel_err']:.3e} >= 1%")
    assert ta["per_tenant"], "tenant_attribution: empty ledger"
    for i, t in ta["per_tenant"].items():
        assert t["device_s"] >= 0 and _math.isfinite(t["device_s"]), (i, t)
    assert sum(t["device_s"] for t in ta["per_tenant"].values()) > 0
    # load-gen SLO section: when configured, every objective must carry
    # finite budget math and a legal state
    if lg is not None and (lg.get("slo") or {}).get("configured"):
        for i, inst in enumerate(lg["slo"]["instances"]):
            assert inst["state"] in ("ok", "burning", "violated"), (i, inst)
            for name, o in inst["objectives"].items():
                for f in ("bad_frac", "burn_rate", "budget_remaining"):
                    v = o[f]
                    assert isinstance(v, (int, float)) and _math.isfinite(v), (
                        f"load_gen.slo[{i}].{name}: {f} not finite: {v!r}")
    # observability section: dispatch overhead + occupancy must be
    # present and finite — a trace regression fails the bench, not just
    # a dashboard (ISSUE 6 acceptance / CI bench-smoke)
    obs = record["obs"]
    check_pct(obs["dispatch_overhead_ms"], "obs.dispatch_overhead_ms")
    check_pct(record["dispatch_overhead_ms"], "dispatch_overhead_ms")
    for f in ("mean_grid_occupancy", "mean_dispatch_gap_ms",
              "tok_per_s_untraced", "tok_per_s_traced"):
        v = obs[f]
        assert isinstance(v, (int, float)) and _math.isfinite(v), (
            f"obs: {f} is not finite: {v!r}")
    assert 0.0 <= obs["mean_grid_occupancy"] <= 1.0, obs["mean_grid_occupancy"]
    v = record["mean_grid_occupancy"]
    assert isinstance(v, (int, float)) and _math.isfinite(v), v
    assert obs["trace_events"] > 0 and obs["device_calls"] > 0
    # megakernel launch-count A/B: when present (dense/vlm records) the
    # fused-layer path must actually collapse the traced decode graph —
    # a megakernel routing regression fails the bench, not just a test
    kl = record.get("kernel_launches_per_decode_step")
    if kl is not None:
        for mesh_key, sides in kl.items():
            if sides is None:
                continue
            where = f"kernel_launches_per_decode_step.{mesh_key}"
            assert sides["unfused"] > 0 and sides["megakernel"] > 0, where
            assert sides["megakernel"] < sides["unfused"], (
                f"{where}: megakernel path did not reduce launches "
                f"({sides['megakernel']} vs {sides['unfused']})")
            assert sides["reduction"] > 1.0, where
    if record.get("kernel_roofline") is not None:
        from repro.serving.obs import validate_profile
        validate_profile(record["kernel_roofline"])
    # recovery section (--fault-plan runs): the §6.8 acceptance
    # invariants are part of the record's validity — a recovery that
    # lost or duplicated tokens fails the bench, not just a test
    rec = record.get("recovery")
    if rec is not None:
        for f in ("restarts", "watchdog_timeouts", "request_retries",
                  "tokens_replayed", "retry_budget_exhausted",
                  "tokens_lost", "requests", "completed"):
            v = rec.get(f)
            assert isinstance(v, int) and v >= 0, (
                f"recovery: {f} is not a finite count: {v!r}")
        assert rec["tokens_lost"] == 0, (
            f"recovery lost {rec['tokens_lost']} token(s)")
        assert rec["streams_bit_identical"] is True, (
            "recovered streams are not bit-identical to the clean run")
        if rec["restarts"] > 0:
            v = rec["time_to_recover_s"]
            assert (isinstance(v, (int, float)) and _math.isfinite(v)
                    and v >= 0), f"recovery: time_to_recover_s {v!r}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(registry.ASSIGNED))
    ap.add_argument("--full", action="store_true",
                    help="published config instead of the smoke config")
    ap.add_argument("--num-instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--prompt-min", type=int, default=3)
    ap.add_argument("--prompt-max", type=int, default=12,
                    help="prompt lengths ~ U[min, max); raise past --chunk "
                         "to exercise multi-chunk admissions in the "
                         "tail-folding A/B")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (tokens per admission call)")
    ap.add_argument("--chunk-budget", type=int, default=4,
                    help="max prefill chunk calls interleaved per engine step")
    ap.add_argument("--lanes", type=int, default=4,
                    help="concurrent prefill lanes (requests mid-admission)")
    ap.add_argument("--decode-steps", type=int, default=8, metavar="K",
                    help="decode steps fused per device call in the "
                         "headline fused/fold/load-gen/obs passes "
                         "(multi-step decode, DESIGN.md §6.6); the "
                         "decode_horizon section sweeps K ∈ {1,2,4,8} "
                         "regardless")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent async client tasks in the open-loop "
                         "load-generator pass (0 disables the pass)")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="open-loop arrival rate in requests/s (exponential "
                         "inter-arrivals, split over --clients)")
    ap.add_argument("--slo-ttft-ms", type=float, default=1000.0,
                    help="TTFT objective evaluated over the load-gen pass "
                         "(record['load_gen']['slo'], DESIGN.md §6.9); "
                         "0 disables the SLO section")
    ap.add_argument("--slo-itl-ms", type=float, default=500.0,
                    help="inter-token-latency objective for the load-gen "
                         "pass; 0 disables the ITL objective")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices and serve sharded")
    ap.add_argument("--mesh-shape", default=None, metavar="DxT",
                    help="(data, model) mesh shape, e.g. 2x4")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="write the observability pass's Chrome-trace JSON "
                         "here (load in Perfetto / chrome://tracing)")
    ap.add_argument("--profile-kernels", action="store_true",
                    help="time each serving Pallas kernel at this config's "
                         "shapes and record achieved-vs-roofline figures "
                         "(record['kernel_roofline'])")
    ap.add_argument("--fault-plan", default=None, metavar="JSON",
                    help="run a fault-injected recovery pass (path or "
                         "inline JSON plan, DESIGN.md §6.8); the record "
                         "gains a 'recovery' section asserting zero "
                         "token loss and bit-identical streams")
    ap.add_argument("--watchdog-ms", type=float, default=0.0,
                    help="watchdog deadline for the recovery pass "
                         "(0 = crash-recovery only)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    mesh = mesh_from_args(args.devices, args.mesh_shape)

    base = registry.get_config(args.arch) if args.full else registry.get_smoke_config(args.arch)
    m = args.num_instances
    max_context = args.max_context
    if base.family == "hybrid":
        from repro.models import hybrid as H
        max_context = max(max_context, H.min_serving_context(base, args.max_new))
    args.resolved_max_context = max_context
    cfg1 = base.with_(num_instances=1)
    cfg = base.with_(num_instances=m)

    keys = jax.random.split(jax.random.PRNGKey(args.seed), m)
    instances = [api.init(cfg1, k) for k in keys]
    t0 = time.perf_counter()
    merged = C.merge_instances(instances, api.axes(cfg1))
    jax.block_until_ready(jax.tree.leaves(merged)[0])
    merge_ms = (time.perf_counter() - t0) * 1e3

    rng = np.random.default_rng(args.seed)
    reqs = _mk_requests(rng, m, args.requests, cfg.vocab_size, args.max_new,
                        args.prompt_min, args.prompt_max)

    # servers are created ONCE and drained twice (warmup compiles, then
    # the timed pass), so neither side pays compile time in the record —
    # the delta under test is steady-state dispatch/batching, as in the
    # paper's measurement
    fused_server = _mk_server(cfg, merged, mesh, args)

    def fused_run():
        steps0 = fused_server.steps
        met = fused_server.metrics
        base = (met.admission_stall_s, met.decode_calls, met.decode_steps,
                met.decode_tokens)
        d = _drain(fused_server, [Request(r.instance, list(r.prompt), r.max_new_tokens)
                                  for r in reqs])
        d["decode_steps"] = fused_server.steps - steps0
        d["admission_stall_ms"] = 1e3 * (met.admission_stall_s - base[0])
        # multi-step decode (DESIGN.md §6.6): dispatch-amortization view
        calls = met.decode_calls - base[1]
        d["decode_device_calls"] = calls
        d["decode_scan_steps"] = met.decode_steps - base[2]
        d["tokens_per_device_call"] = (
            (met.decode_tokens - base[3]) / max(calls, 1))
        return d

    fused_run()                      # compile warmup
    fused = fused_run()

    # sequential baseline: M single-model servers, drained one at a time
    ax = api.axes(cfg1)
    solo = [
        MultiModelServer(
            cfg1, C.take_instance(merged, ax, i),
            slots_per_instance=args.slots, max_context=max_context,
            temperature=0.0,
        )
        for i in range(m)
    ]

    def sequential_run():
        out = {"requests": 0, "tokens": 0, "wall_s": 0.0, "decode_steps": 0}
        t0 = time.perf_counter()
        for i, server in enumerate(solo):
            steps0 = server.steps
            mine = [Request(0, list(r.prompt), r.max_new_tokens)
                    for r in reqs if r.instance == i]
            d = _drain(server, mine)
            out["requests"] += d["requests"]
            out["tokens"] += d["tokens"]
            out["decode_steps"] += server.steps - steps0
        out["wall_s"] = time.perf_counter() - t0
        out["tok_per_s"] = out["tokens"] / out["wall_s"]
        return out

    sequential_run()                 # compile warmup
    seq = sequential_run()

    # tail-folding A/B: always on the no-mesh path; ALSO on the mesh
    # path when serving sharded, so the record shows the admission
    # improvement on both (acceptance: prefill tok/s fold_on > fold_off)
    tail_folding = {"no_mesh": _fold_ab(cfg, merged, None, args, reqs)}
    tail_folding["mesh"] = (
        _fold_ab(cfg, merged, mesh, args, reqs) if mesh is not None else None
    )

    # decode-horizon sweep: the multi-step tentpole's acceptance
    # figures, on both paths when serving sharded (DESIGN.md §6.6)
    decode_horizon = {
        "no_mesh": _decode_sweep(cfg, merged, None, args, reqs, seq["wall_s"]),
        "mesh": (_decode_sweep(cfg, merged, mesh, args, reqs, seq["wall_s"])
                 if mesh is not None else None),
    }

    # megakernel launch-count A/B (ISSUE 8): the fused decode-layer
    # path's measurable win on this host is the traced-graph collapse
    kernel_launches = _kernel_launch_ab(cfg, merged, mesh, args)

    # open-loop async load generation through the streaming frontend:
    # the section the TTFT/ITL tail-latency trajectory is tracked on
    load_gen = (
        _run_load_gen(cfg, merged, mesh, args, reqs)
        if args.clients > 0 else None
    )

    # step-trace observability pass: per-device-call dispatch overhead,
    # grid occupancy, and the tracing on/off throughput A/B
    obs, chrome, tenant_attribution = _run_observed(cfg, merged, mesh,
                                                    args, reqs)
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            json.dump(chrome, f)
        print(f"wrote {args.trace_out} "
              f"({len(chrome['traceEvents'])} trace events)")

    # fault-injected recovery pass (DESIGN.md §6.8): only when a plan
    # is given — restart count, time-to-recover, zero-token-loss proof
    recovery = (_run_recovery(cfg, merged, mesh, args, reqs)
                if args.fault_plan else None)

    kernel_roofline = None
    if args.profile_kernels:
        from repro.serving.obs import profile_serving_kernels, format_table
        kernel_roofline = profile_serving_kernels(
            cfg, slots=args.slots, max_context=max_context,
            chunk=args.chunk, prefill_lanes=args.lanes,
        )
        print(format_table(kernel_roofline))

    num_devices = fused_server.metrics.num_devices
    record = {
        "bench": "serve_fused_vs_sequential",
        "arch": args.arch,
        "family": cfg.family,
        "smoke": not args.full,
        "num_instances": m,
        "slots_per_instance": args.slots,
        "max_context": max_context,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "devices": num_devices,
        "merge_ms": merge_ms,
        # compile-count trajectory: the chunked runtime's invariant is
        # two shapes (chunk + tail) per family regardless of workload
        "chunk_size": fused_server.prefill.chunk,
        "chunk_budget": fused_server.chunk_budget,
        "prefill_lanes": fused_server.prefill.lanes,
        "compiled_shapes": fused_server.prefill.compiled_shapes,
        "decode_steps_per_call": args.decode_steps,
        "fused": fused,
        "sequential": seq,
        "tail_folding": tail_folding,
        "decode_horizon": decode_horizon,
        "kernel_launches_per_decode_step": kernel_launches,
        "load_gen": load_gen,
        "obs": obs,
        "tenant_attribution": tenant_attribution,
        "recovery": recovery,
        # promoted to top level so perf_delta can diff the dispatch
        # trajectory across PRs without digging into the section
        "dispatch_overhead_ms": obs["dispatch_overhead_ms"],
        "mean_grid_occupancy": obs["mean_grid_occupancy"],
        "kernel_roofline": kernel_roofline,
        # only a measured figure when actually serving sharded
        "fused_tok_per_s_per_device": (
            fused["tok_per_s"] / num_devices if mesh is not None else None
        ),
        "speedup": seq["wall_s"] / fused["wall_s"],
        "dispatch_amortization": seq["decode_steps"] / max(fused["decode_steps"], 1),
        # multi-step acceptance figures, promoted for perf_delta --serve
        "k8_vs_k1_decode_speedup":
            decode_horizon["no_mesh"]["k8_vs_k1_decode_speedup"],
        "k8_vs_k1_call_reduction":
            decode_horizon["no_mesh"]["k8_vs_k1_call_reduction"],
        "k8_vs_k1_dispatch_per_token_reduction":
            decode_horizon["no_mesh"]["k8_vs_k1_dispatch_per_token_reduction"],
    }
    validate_record(record)
    print(json.dumps(record, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
