"""Serving benchmark: fused (M, B)-grid serving vs M sequential servers.

The paper's headline claim restated at the serving-system level: one
NetFuse-merged `MultiModelServer` over M instances vs M single-model
servers drained one after another (the paper's "sequential" strategy),
same request set, same slot budget per instance.  Emits a JSON perf
record on stdout (and optionally to a file) so perf deltas can be
tracked across PRs.

Run: PYTHONPATH=src python benchmarks/serve_bench.py \
         [--arch tinyllama-1.1b] [--num-instances 4] [--requests 24] \
         [--devices 8] [--mesh-shape 2x4] [--json-out serve_bench.json]

``--devices N`` forces N host-platform devices (consumed before the
first jax init) and serves the fused grid under a mesh (``--mesh-shape
DxT``, default all-data); the JSON record then carries the mesh shape
and per-device throughput.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# --devices must be applied before the first jax backend init (the
# device count locks there; importing jax below is still safe)
from repro.launch.compat import force_host_devices_from_argv, mesh_from_args

force_host_devices_from_argv(sys.argv)

import numpy as np

import jax

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import MultiModelServer, Request


def _mk_requests(rng, m, n, vocab, max_new):
    return [
        Request(
            instance=i % m,
            prompt=rng.integers(1, vocab, size=int(rng.integers(3, 12))).tolist(),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _drain(server, reqs) -> dict:
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    results = server.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    return {
        "requests": len(results),
        "tokens": toks,
        "wall_s": dt,
        "tok_per_s": toks / dt,
        "decode_steps": server.steps,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(registry.ASSIGNED))
    ap.add_argument("--full", action="store_true",
                    help="published config instead of the smoke config")
    ap.add_argument("--num-instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (tokens per admission call)")
    ap.add_argument("--chunk-budget", type=int, default=4,
                    help="max prefill chunk calls interleaved per engine step")
    ap.add_argument("--lanes", type=int, default=4,
                    help="concurrent prefill lanes (requests mid-admission)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices and serve sharded")
    ap.add_argument("--mesh-shape", default=None, metavar="DxT",
                    help="(data, model) mesh shape, e.g. 2x4")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    mesh = mesh_from_args(args.devices, args.mesh_shape)

    base = registry.get_config(args.arch) if args.full else registry.get_smoke_config(args.arch)
    m = args.num_instances
    max_context = args.max_context
    if base.family == "hybrid":
        from repro.models import hybrid as H
        max_context = max(max_context, H.min_serving_context(base, args.max_new))
    cfg1 = base.with_(num_instances=1)
    cfg = base.with_(num_instances=m)

    keys = jax.random.split(jax.random.PRNGKey(args.seed), m)
    instances = [api.init(cfg1, k) for k in keys]
    t0 = time.perf_counter()
    merged = C.merge_instances(instances, api.axes(cfg1))
    jax.block_until_ready(jax.tree.leaves(merged)[0])
    merge_ms = (time.perf_counter() - t0) * 1e3

    rng = np.random.default_rng(args.seed)
    reqs = _mk_requests(rng, m, args.requests, cfg.vocab_size, args.max_new)

    # servers are created ONCE and drained twice (warmup compiles, then
    # the timed pass), so neither side pays compile time in the record —
    # the delta under test is steady-state dispatch/batching, as in the
    # paper's measurement
    fused_server = MultiModelServer(
        cfg, merged, slots_per_instance=args.slots,
        max_context=max_context, temperature=0.0, mesh=mesh,
        prefill_chunk=args.chunk, chunk_budget=args.chunk_budget,
        prefill_lanes=args.lanes,
    )

    def fused_run():
        steps0 = fused_server.steps
        stall0 = fused_server.metrics.admission_stall_s
        d = _drain(fused_server, [Request(r.instance, list(r.prompt), r.max_new_tokens)
                                  for r in reqs])
        d["decode_steps"] = fused_server.steps - steps0
        d["admission_stall_ms"] = 1e3 * (
            fused_server.metrics.admission_stall_s - stall0)
        return d

    fused_run()                      # compile warmup
    fused = fused_run()

    # sequential baseline: M single-model servers, drained one at a time
    ax = api.axes(cfg1)
    solo = [
        MultiModelServer(
            cfg1, C.take_instance(merged, ax, i),
            slots_per_instance=args.slots, max_context=max_context,
            temperature=0.0,
        )
        for i in range(m)
    ]

    def sequential_run():
        out = {"requests": 0, "tokens": 0, "wall_s": 0.0, "decode_steps": 0}
        t0 = time.perf_counter()
        for i, server in enumerate(solo):
            steps0 = server.steps
            mine = [Request(0, list(r.prompt), r.max_new_tokens)
                    for r in reqs if r.instance == i]
            d = _drain(server, mine)
            out["requests"] += d["requests"]
            out["tokens"] += d["tokens"]
            out["decode_steps"] += server.steps - steps0
        out["wall_s"] = time.perf_counter() - t0
        out["tok_per_s"] = out["tokens"] / out["wall_s"]
        return out

    sequential_run()                 # compile warmup
    seq = sequential_run()

    num_devices = fused_server.metrics.num_devices
    record = {
        "bench": "serve_fused_vs_sequential",
        "arch": args.arch,
        "family": cfg.family,
        "smoke": not args.full,
        "num_instances": m,
        "slots_per_instance": args.slots,
        "max_context": max_context,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "devices": num_devices,
        "merge_ms": merge_ms,
        # compile-count trajectory: the chunked runtime's invariant is
        # two shapes (chunk + tail) per family regardless of workload
        "chunk_size": fused_server.prefill.chunk,
        "chunk_budget": fused_server.chunk_budget,
        "prefill_lanes": fused_server.prefill.lanes,
        "compiled_shapes": fused_server.prefill.compiled_shapes,
        "fused": fused,
        "sequential": seq,
        # only a measured figure when actually serving sharded
        "fused_tok_per_s_per_device": (
            fused["tok_per_s"] / num_devices if mesh is not None else None
        ),
        "speedup": seq["wall_s"] / fused["wall_s"],
        "dispatch_amortization": seq["decode_steps"] / max(fused["decode_steps"], 1),
    }
    print(json.dumps(record, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
