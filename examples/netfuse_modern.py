"""NetFuse beyond the paper's eval models: merging modern architectures.

The paper evaluates ResNet/ResNeXt/BERT/XLNet (2020).  The same
input-weight-local construction applies to the architectures this repo
ships (DESIGN.md §4); this example demonstrates the two interesting
cases:

1. Mixture-of-Experts (qwen3-family): merging M fine-tuned MoE instances
   yields a *block-diagonal* MoE — M·E experts in M routing groups.
   Instance m's router can only ever select instance m's experts, which
   is exactly the paper's grouped-op rule ("merging G-group ops gives
   M·G groups") applied to expert weights.
2. xLSTM (recurrent): the merged model carries M independent recurrent
   states; prefill->decode handoff stays exact per instance.

Both checks assert exact per-instance isolation: perturbing instance j's
weights never changes instance i's outputs.

Run: PYTHONPATH=src python examples/netfuse_modern.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import registry
from repro.models import common


def _make_batch(cfg1, m: int, b: int, s: int):
    toks = jax.random.randint(jax.random.PRNGKey(1), (m, b, s), 0, cfg1.vocab_size)
    batch = {"tokens": toks}
    if cfg1.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (m, b, cfg1.num_image_patches, cfg1.vision_embed_dim))
    if cfg1.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (m, b, cfg1.num_audio_frames, cfg1.d_model))
    return batch


def merged_forward_equals_solo(arch: str, m: int = 3, b: int = 2, s: int = 16):
    cfg1 = registry.get_smoke_config(arch).with_(
        dtype="float32", param_dtype="float32")
    keys = jax.random.split(jax.random.PRNGKey(0), m)
    params_i = [api.init(cfg1, k) for k in keys]          # M "fine-tuned" models
    merged = common.merge_instances(params_i, api.axes(cfg1))
    cfgM = cfg1.with_(num_instances=m)

    batch = _make_batch(cfg1, m, b, s)

    out = api.train_logits(cfgM, merged, batch, remat=False)
    fused = out[0] if isinstance(out, tuple) else out

    worst = 0.0
    for i in range(m):
        bi = {k: v[i:i + 1] for k, v in batch.items()}
        oi = api.train_logits(cfg1, params_i[i], bi, remat=False)
        oi = oi[0] if isinstance(oi, tuple) else oi
        worst = max(worst, float(jnp.max(jnp.abs(fused[i:i + 1] - oi))))
    return worst


def isolation_check(arch: str, m: int = 3, b: int = 2, s: int = 12):
    """Perturb instance 1's weights; instance 0's output must not move."""
    cfg1 = registry.get_smoke_config(arch).with_(
        dtype="float32", param_dtype="float32")
    keys = jax.random.split(jax.random.PRNGKey(0), m)
    params_i = [api.init(cfg1, k) for k in keys]
    merged = common.merge_instances(params_i, api.axes(cfg1))
    cfgM = cfg1.with_(num_instances=m)
    batch = _make_batch(cfg1, m, b, s)

    def inst0_logits(p):
        out = api.train_logits(cfgM, p, batch, remat=False)
        return (out[0] if isinstance(out, tuple) else out)[0]

    base = inst0_logits(merged)
    axes = api.axes(cfg1)

    def poke(ax, x):
        # the instances axis position comes from the logical axes tree
        # (naively matching shape[0]==m would hit 3-layer stacks at m=3)
        if isinstance(ax, tuple) and "instances" in ax:
            i = ax.index("instances")
            return x.at[(slice(None),) * i + (1,)].mul(3.0)
        return x

    is_leaf = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    poked = jax.tree.map(poke, axes, merged, is_leaf=is_leaf)
    moved = float(jnp.max(jnp.abs(inst0_logits(poked) - base)))
    return moved


def ssm_decode_isolation(m: int = 2, b: int = 2):
    """Merged xLSTM: prefill then decode; states evolve independently."""
    cfg1 = registry.get_smoke_config("xlstm-1.3b").with_(
        dtype="float32", param_dtype="float32")
    cfgM = cfg1.with_(num_instances=m)
    keys = jax.random.split(jax.random.PRNGKey(0), m)
    params_i = [api.init(cfg1, k) for k in keys]
    merged = common.merge_instances(params_i, api.axes(cfg1))

    toks = jax.random.randint(jax.random.PRNGKey(1), (m, b, 8), 0, cfg1.vocab_size)
    logits, state = api.prefill(cfgM, merged, {"tokens": toks})
    nxt = jnp.argmax(logits, -1)[:, :, None].astype(jnp.int32)
    step_logits, _ = api.decode_step(cfgM, merged, state, nxt, jnp.full((m, b), 8, jnp.int32))

    worst = 0.0
    for i in range(m):
        li, si = api.prefill(cfg1, params_i[i], {"tokens": toks[i:i + 1]})
        ni = jnp.argmax(li, -1)[:, :, None].astype(jnp.int32)
        di, _ = api.decode_step(cfg1, params_i[i], si, ni, jnp.full((1, b), 8, jnp.int32))
        worst = max(worst, float(jnp.max(jnp.abs(step_logits[i:i + 1] - di))))
    return worst


def main():
    print("=== NetFuse on modern architectures (smoke-size configs) ===")
    for arch in ("qwen3-moe-30b-a3b", "olmoe-1b-7b", "xlstm-1.3b",
                 "hymba-1.5b", "internvl2-26b", "whisper-small"):
        d = merged_forward_equals_solo(arch)
        iso = isolation_check(arch)
        status = "OK " if d < 2e-4 and iso == 0.0 else "FAIL"
        print(f"[{status}] {arch:<20s} merged==solo max|diff| {d:.2e}   "
              f"cross-instance leak {iso:.1e}")
        assert d < 2e-4 and iso == 0.0, arch

    d = ssm_decode_isolation()
    print(f"[OK ] xlstm prefill->decode merged==solo max|diff| {d:.2e}")
    assert d < 2e-4
    print("\nAll modern-architecture merges are exact and instance-isolated —")
    print("the paper's grouped-op rule generalizes to MoE routing groups and")
    print("recurrent state without modification.")


if __name__ == "__main__":
    main()
