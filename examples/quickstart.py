"""Quickstart: NetFuse in 60 seconds.

1. Paper Algorithm 1 on the op-graph IR — merge two FFNNs with different
   weights into one graph (matmul->batch-matmul, layernorm->groupnorm,
   reshape fix-up inserted), and check exactness.
2. The production path — merge M fine-tuned llama-style checkpoints by
   stacking their param pytrees and run the fusion-aware forward once.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.configs import registry
from repro.models import common, dense


def part1_graph_merging():
    print("=== Part 1: paper Algorithm 1 (graph merging) ===")
    g = G.Graph()
    g.add("x", "input")
    g.add("fc1", "matmul", ["x"])
    g.add("ln", "layernorm", ["fc1"])
    g.add("act", "gelu", ["ln"])
    g.add("fc2", "matmul", ["act"])
    g.outputs = ["fc2"]

    def weights(key):
        ks = jax.random.split(key, 4)
        return {
            "fc1": {"w": jax.random.normal(ks[0], (16, 32)) * 0.1},
            "ln": {"scale": jnp.ones(32), "bias": jnp.zeros(32)},
            "fc2": {"w": jax.random.normal(ks[1], (32, 8)) * 0.1},
        }

    m = 3
    ws = [weights(jax.random.PRNGKey(i)) for i in range(m)]
    inputs = [{"x": jax.random.normal(jax.random.PRNGKey(10 + i), (4, 16))} for i in range(m)]

    merged, mw, dims = G.merge_graph(g, ws)
    print("merged ops:", {n: op.op_type for n, op in merged.ops.items()})
    fused = G.execute_merged(merged, mw, dims, inputs)
    for i in range(m):
        ref = G.execute(g, inputs[i], ws[i])
        np.testing.assert_allclose(
            np.asarray(fused[i]["fc2"]), np.asarray(ref["fc2"]), rtol=1e-4, atol=1e-5
        )
    print(f"OK: merged graph == {m} separate models (exact)\n")


def part2_model_merging():
    print("=== Part 2: production path (param-pytree merging) ===")
    cfg1 = registry.get_smoke_config("tinyllama-1.1b")
    m = 4
    checkpoints = [dense.init(cfg1, jax.random.PRNGKey(i)) for i in range(m)]
    axes = dense.axes(cfg1)

    merged = common.merge_instances(checkpoints, axes)     # <- THE merge
    cfg = cfg1.with_(num_instances=m)

    tokens = jax.random.randint(jax.random.PRNGKey(99), (m, 2, 16), 0, cfg.vocab_size)
    fused_logits = jax.jit(lambda p, t: dense.forward(cfg, p, t))(merged, tokens)
    for i in range(m):
        ref = dense.forward(cfg1, checkpoints[i], tokens[i : i + 1])
        np.testing.assert_allclose(
            np.asarray(fused_logits[i]), np.asarray(ref[0]), rtol=2e-3, atol=2e-3
        )
    print(f"OK: one fused forward == {m} fine-tuned models run separately")
    print("    (each instance's inputs only ever touch its own weights)")


if __name__ == "__main__":
    part1_graph_merging()
    part2_model_merging()
