"""HTTP client/server example: the async streaming frontend end to end.

Boots a tiny NetFuse-merged multi-model server (M=2 instances of the
smoke TinyLlama config), exposes it over HTTP on an ephemeral port
(DESIGN.md §6.4), then plays both sides in one process:

  1. a streaming client POSTs /v1/completions with ``"stream": true``
     and prints each SSE token chunk as the fused engine step lands,
  2. a second client runs the same prompt non-streaming and checks the
     bodies agree (greedy determinism),
  3. a rude client disconnects mid-stream — the server cancels the
     request and frees its slot (visible in the metrics),
  4. POST /debug/trace/start turns on the step tracer, a traced
     completion runs, GET /debug/trace downloads the Chrome-trace JSON
     (open in Perfetto / chrome://tracing), POST /debug/trace/stop
     returns the aggregate summary (DESIGN.md §6.5),
  5. GET /metrics shows per-instance TTFT/ITL p50/p95/p99 — as JSON,
     then again with ``Accept: text/plain`` for the Prometheus
     exposition,
  6. the engine drains gracefully,
  7. kill-and-recover (DESIGN.md §6.8): a SECOND server boots with a
     deterministic fault plan that crashes the driver mid-decode; a
     Supervisor restarts it, requeues the in-flight request with its
     already-delivered token prefix, and the client's stream comes out
     bit-identical to the fault-free run — /healthz shows the restart,
  8. post-mortem (DESIGN.md §6.9): the crashed server was running with
     TTFT/ITL SLOs, per-tenant accounting, and an armed flight
     recorder — GET /v1/slo reports the error budgets, GET
     /debug/flight lists the crash dump the supervisor froze at the
     incident, and the flight-0001.json artifact is recovered from
     disk and inspected.

Everything is stdlib: asyncio server, asyncio TCP clients, token-id
prompts (this repro has no tokenizer).

Run: PYTHONPATH=src python examples/serve_http.py
"""
import asyncio
import json
import tempfile

import jax

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import (AsyncEngine, FaultInjector, FlightRecorder,
                           MultiModelServer, SLOConfig, Supervisor,
                           start_http_server)

M = 2


async def http_roundtrip(port, method, path, payload=None, accept=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode() if payload is not None else b""
    extra = f"Accept: {accept}\r\n" if accept else ""
    writer.write(
        f"{method} {path} HTTP/1.1\r\nHost: example\r\n"
        f"Content-Type: application/json\r\n{extra}"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, rest = raw.partition(b"\r\n\r\n")
    return head.decode("latin-1"), rest


async def main_async(server):
    engine = AsyncEngine(server, max_queue_depth=8)
    http = await start_http_server(engine, port=0)
    port = http.sockets[0].getsockname()[1]
    print(f"serving on 127.0.0.1:{port}\n")

    # 1. streaming client: one SSE chunk per fused engine step
    print("== streaming completion (model-0) ==")
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = {"model": "model-0", "prompt": [11, 12, 13], "max_tokens": 6,
               "stream": True}
    body = json.dumps(payload).encode()
    writer.write(
        f"POST /v1/completions HTTP/1.1\r\nHost: e\r\n"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    streamed = []
    buf = b""
    while True:
        chunk = await reader.read(4096)
        if not chunk:
            break
        buf += chunk
        if b"data: [DONE]" in buf:
            break
    writer.close()
    await writer.wait_closed()
    for line in buf.partition(b"\r\n\r\n")[2].split(b"\n\n"):
        if line.startswith(b"data: ") and line != b"data: [DONE]":
            ev = json.loads(line[len(b"data: "):])["choices"][0]
            if ev["token"] is not None:
                streamed.append(ev["token"])
                print(f"  SSE token: {ev['token']}")
            else:
                print(f"  finish_reason: {ev['finish_reason']}")

    # 2. the same prompt, non-streaming, must match (greedy)
    head, rest = await http_roundtrip(port, "POST", "/v1/completions", {
        "model": "model-0", "prompt": [11, 12, 13], "max_tokens": 6,
    })
    tokens = json.loads(rest)["choices"][0]["tokens"]
    print(f"\n== non-streaming same prompt ==\n  tokens: {tokens}")
    assert tokens == streamed, (tokens, streamed)
    print("  matches the streamed tokens (greedy determinism)")

    # 3. rude client: disconnect mid-stream -> server cancels the request
    print("\n== client disconnect mid-stream ==")
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = {"model": "model-1", "prompt": [7, 8], "max_tokens": 400,
               "stream": True}
    body = json.dumps(payload).encode()
    writer.write(
        f"POST /v1/completions HTTP/1.1\r\nHost: e\r\n"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    buf = b""
    while b"\n\n" not in buf.partition(b"\r\n\r\n")[2]:
        buf += await reader.read(4096)        # wait for the first token...
    writer.close()                            # ...then vanish
    await writer.wait_closed()
    while server.busy():
        await asyncio.sleep(0.02)
    print("  request cancelled, slot freed (engine drained)")

    # 4. step tracing over HTTP: start -> traced completion -> download
    #    the Chrome trace -> stop (returns the aggregate summary)
    print("\n== /debug/trace (DESIGN.md §6.5) ==")
    await http_roundtrip(port, "POST", "/debug/trace/start", {})
    await http_roundtrip(port, "POST", "/v1/completions", {
        "model": "model-1", "prompt": [21, 22, 23, 24], "max_tokens": 5,
    })
    head, rest = await http_roundtrip(port, "GET", "/debug/trace")
    chrome = json.loads(rest)
    with open("trace.json", "w") as f:
        json.dump(chrome, f)
    print(f"  wrote trace.json: {len(chrome['traceEvents'])} events "
          f"(load in Perfetto / chrome://tracing)")
    head, rest = await http_roundtrip(port, "POST", "/debug/trace/stop", {})
    summ = json.loads(rest)["summary"]
    do = summ["dispatch_overhead_ms"]
    print(f"  summary: {summ['device_calls']} device calls, dispatch "
          f"overhead p50/p95 = {do['p50']:.2f}/{do['p95']:.2f} ms, "
          f"grid occupancy {summ['mean_grid_occupancy']:.2f}")

    # 5. metrics: percentile tails per instance — JSON by default,
    #    Prometheus exposition under Accept: text/plain
    head, rest = await http_roundtrip(port, "GET", "/metrics")
    snap = json.loads(rest)
    print("\n== GET /metrics ==")
    print(f"  generated {snap['generated_tokens']} tokens, "
          f"{snap['cancelled']} cancelled")
    for i, inst in enumerate(snap["instances"]):
        t = inst["ttft_ms"]
        print(f"  instance {i}: completed={inst['completed']} "
              f"ttft p50/p95 = "
              + (f"{t['p50']:.1f}/{t['p95']:.1f} ms" if t else "-"))
    head, rest = await http_roundtrip(port, "GET", "/metrics",
                                      accept="text/plain")
    print("  Prometheus exposition (Accept: text/plain), first lines:")
    for line in rest.decode().splitlines()[:4]:
        print(f"    {line}")

    # 6. graceful teardown
    http.close()
    await http.wait_closed()
    await engine.aclose()
    print("\ndrained and closed.")


async def recover_async(server, inj):
    """Act 7: crash the driver mid-decode, watch the Supervisor put the
    stream back together bit-for-bit (DESIGN.md §6.8)."""
    engine = AsyncEngine(server, max_queue_depth=8)
    sup = Supervisor(engine, backoff_base_s=0.01)
    sup.start()
    http = await start_http_server(engine, port=0)
    port = http.sockets[0].getsockname()[1]
    print("\n== kill-and-recover (DESIGN.md §6.8) ==")
    print(f"  supervised server on 127.0.0.1:{port}, fault plan: crash "
          f"the driver on its {inj.plan[0].at_call}rd device step")

    # the fault-free reference answer (injector still disarmed)
    head, rest = await http_roundtrip(port, "POST", "/v1/completions", {
        "model": "model-0", "prompt": [11, 12, 13], "max_tokens": 6,
    })
    want = json.loads(rest)["choices"][0]["tokens"]
    print(f"  fault-free answer: {want}")

    # arm and run the SAME prompt: the driver dies mid-stream, the
    # supervisor restarts it and requeues the request with its
    # delivered prefix — the client just sees tokens keep arriving
    inj.arm()
    head, rest = await http_roundtrip(port, "POST", "/v1/completions", {
        "model": "model-0", "prompt": [11, 12, 13], "max_tokens": 6,
    })
    got = json.loads(rest)["choices"][0]["tokens"]
    print(f"  answer across the crash: {got}")
    assert got == want, (got, want)
    print("  bit-identical to the fault-free run "
          f"(faults fired: {inj.fired})")

    head, rest = await http_roundtrip(port, "GET", "/healthz")
    h = json.loads(rest)
    res = h["resilience"]
    print(f"  /healthz: driver={h['driver']} "
          f"instance_health={h['instance_health']} slo={h['slo']}")
    print(f"  restarts={res['driver_restarts']} "
          f"retries={res['request_retries']} "
          f"tokens_replayed={res['tokens_replayed']} "
          f"recovered in {res['last_recovery_s'] * 1e3:.0f} ms")

    # act 8: the post-mortem surface (DESIGN.md §6.9)
    print("\n== post-mortem: /v1/slo + /debug/flight (DESIGN.md §6.9) ==")
    head, rest = await http_roundtrip(port, "GET", "/v1/slo")
    slo = json.loads(rest)
    cfg = slo["config"]
    print(f"  SLO target {cfg['target']:.0%}, ttft<={cfg['ttft_ms']:g}ms "
          f"itl<={cfg['itl_ms']:g}ms")
    for i, inst in enumerate(slo["instances"]):
        t = inst["objectives"]["ttft"]
        print(f"  instance {i}: state={inst['state']} "
              f"ttft bad={t['bad_frac']:.1%} burn={t['burn_rate']:.2f} "
              f"budget={t['budget_remaining']:.0%}")

    head, rest = await http_roundtrip(port, "GET", "/debug/flight")
    fl = json.loads(rest)
    print(f"  /debug/flight: {fl['count']} dump(s) in {fl['directory']}")
    dump_path = fl["dumps"][0]["path"]

    http.close()
    await http.wait_closed()
    await engine.aclose()
    print("  recovered, drained and closed.")
    return dump_path


def main():
    cfg1 = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=1)
    cfg = cfg1.with_(num_instances=M)
    keys = jax.random.split(jax.random.PRNGKey(0), M)
    merged = C.merge_instances(
        [api.init(cfg1, k) for k in keys], api.axes(cfg1))
    server = MultiModelServer(cfg, merged, slots_per_instance=2,
                              max_context=64)
    asyncio.run(main_async(server))
    print(server.metrics.format_table())

    # acts 7+8 get their own engine: a deterministic driver-crash plan,
    # this time with SLOs, accounting and the flight recorder armed so
    # the crash leaves a post-mortem behind (DESIGN.md §6.9)
    inj = FaultInjector.from_plan(
        {"seed": 0, "faults": [{"site": "driver", "at_call": 3}]})
    flight_dir = tempfile.mkdtemp(prefix="flight-")
    faulted = MultiModelServer(cfg, merged, slots_per_instance=2,
                               max_context=64, faults=inj,
                               slo=SLOConfig(ttft_ms=500.0, itl_ms=250.0),
                               flight=FlightRecorder(flight_dir))
    faulted.accounting.start()
    faulted.tracer.start()       # the dump freezes the trace tail too
    dump_path = asyncio.run(recover_async(faulted, inj))

    # the artifact survives the process: load it back from disk
    with open(dump_path) as f:
        rec = json.load(f)
    print(f"\nflight artifact {dump_path}:")
    print(f"  schema={rec['schema']} reason={rec['reason']!r} "
          f"{len(rec['trace_events'])} trace events, queue depths "
          f"{rec['queue_depths']} at the incident")
    print(faulted.accounting.format_table())


if __name__ == "__main__":
    main()
