"""End-to-end driver: fine-tune M task variants, merge, SERVE with
batched requests (the paper's deployment scenario, §1-2).

Pipeline:
  1. pretrain a small base model on a synthetic corpus,
  2. fine-tune M=4 task variants (different data streams -> different
     weights, same architecture — the transfer-learning setting),
  3. NetFuse-merge the four checkpoints (offline, timed),
  4. serve a mixed request stream through the MultiModelServer's fused
     decode, and verify each response matches its own model's greedy
     decode run in isolation,
  5. compare fused serving throughput against the sequential baseline.

Run: PYTHONPATH=src python examples/serve_multimodel.py
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import registry
from repro.data import pipeline
from repro.models import common, dense
from repro.optim import cosine_with_warmup
from repro.serving import MultiModelServer, Request
from repro.train import loop as train_loop

M = 4
STEPS_PRETRAIN = 60
STEPS_FINETUNE = 25


def main():
    cfg1 = registry.get_smoke_config("tinyllama-1.1b").with_(vocab_size=128)
    print(f"base model: {cfg1.num_layers}L d={cfg1.d_model} vocab={cfg1.vocab_size}")

    # 1. pretrain
    data = pipeline.SyntheticLM(cfg1.vocab_size, 1, seed=0)
    sched = cosine_with_warmup(3e-3, 5, STEPS_PRETRAIN)
    state, losses = train_loop.train_loop(
        cfg1, data, steps=STEPS_PRETRAIN, batch_size=8, seq_len=32,
        lr_schedule=sched, log_every=20,
    )
    print(f"pretrain: loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")

    # 2. fine-tune M task variants on different streams
    checkpoints = []
    for task in range(M):
        tdata = pipeline.SyntheticLM(cfg1.vocab_size, 1, seed=100 + task)
        tstate, tl = train_loop.train_loop(
            cfg1, tdata, steps=STEPS_FINETUNE, batch_size=8, seq_len=32,
            lr_schedule=cosine_with_warmup(1e-3, 2, STEPS_FINETUNE),
            log_every=STEPS_FINETUNE, state=state,
        )
        checkpoints.append(tstate.params)
        print(f"fine-tune task {task}: loss -> {tl[-1][1]:.3f}")

    # 3. merge (paper §4: offline, amortized over serving)
    axes = dense.axes(cfg1)
    t0 = time.perf_counter()
    merged = common.merge_instances(checkpoints, axes)
    jax.block_until_ready(jax.tree.leaves(merged)[0])
    print(f"NetFuse merge of {M} checkpoints: {(time.perf_counter()-t0)*1e3:.1f} ms")

    # 4. serve a mixed stream
    cfg = cfg1.with_(num_instances=M)
    server = MultiModelServer(cfg, merged, slots_per_instance=2,
                              max_context=64, temperature=0.0)
    rng = np.random.default_rng(7)
    reqs = [
        Request(instance=int(rng.integers(M)),
                prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 9))).tolist(),
                max_new_tokens=8)
        for _ in range(12)
    ]
    ids = [server.submit(r) for r in reqs]
    t0 = time.perf_counter()
    results = {r.request_id: r for r in server.run_until_drained()}
    fused_time = time.perf_counter() - t0
    ntok = sum(len(r.tokens) for r in results.values())
    print(f"fused serving: {len(results)} requests / {ntok} tokens "
          f"in {fused_time:.2f}s ({server.steps} fused steps)")

    # verify against isolated per-model greedy decode
    for req, rid in zip(reqs, ids):
        pi = common.take_instance(merged, axes, req.instance)
        toks = list(req.prompt)
        for _ in range(req.max_new_tokens):
            logits = dense.forward(cfg1, pi, jnp.asarray(toks, jnp.int32)[None, None])
            toks.append(int(jnp.argmax(logits[0, 0, -1])))
        assert results[rid].tokens == toks[len(req.prompt):], rid
    print("OK: every fused response == its own model's isolated decode")

    # 5. sequential-baseline comparison: same requests through M separate
    # single-model servers (KV-cached decode, same slot count), drained
    # one model at a time — the paper's "sequential" strategy.
    solo_servers = []
    for i in range(M):
        pi = common.take_instance(merged, axes, i)
        solo_servers.append(MultiModelServer(
            cfg1, pi, slots_per_instance=2, max_context=64, temperature=0.0
        ))
    for req in reqs:
        solo_servers[req.instance].submit(
            Request(instance=0, prompt=req.prompt, max_new_tokens=req.max_new_tokens)
        )
    t0 = time.perf_counter()
    total_steps = 0
    for s in solo_servers:
        s.run_until_drained()
        total_steps += s.steps
    seq_time = time.perf_counter() - t0
    print(f"sequential baseline (cached decode, {total_steps} steps): "
          f"{seq_time:.2f}s -> fused speedup {seq_time / fused_time:.2f}x")


if __name__ == "__main__":
    main()
