"""Paper §6 "Applicability of NETFUSE on training models": train M
models as one merged model.

All merged ops have proper gradients (they're ordinary einsums / norms
with an instance axis), and gradients are instance-local by construction
— so one fused train step advances M models at once, each on its own
data stream.  This script trains M=3 models fused, then checks

  * the fused loss ~ mean of per-instance losses,
  * instance isolation: instance i trained fused reaches (numerically)
    the same weights as instance i trained alone on the same stream.

Run: PYTHONPATH=src python examples/train_merged.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data import pipeline
from repro.models import common, dense
from repro.optim import constant
from repro.train import loop as train_loop

M = 3
STEPS = 30


class PerInstanceData:
    """Each merged instance sees its own stream (different inputs AND
    different weights — the full NetFuse setting)."""

    def __init__(self, cfg, m):
        self.streams = [pipeline.SyntheticLM(cfg.vocab_size, 1, seed=50 + i) for i in range(m)]

    def batch(self, step, batch_size, seq_len):
        bs = [s.batch(step, batch_size, seq_len) for s in self.streams]
        return {
            k: jnp.concatenate([b[k] for b in bs], axis=0) for k in bs[0]
        }


def main():
    cfg1 = registry.get_smoke_config("tinyllama-1.1b").with_(vocab_size=64)
    cfg = cfg1.with_(num_instances=M)
    axes1 = dense.axes(cfg1)

    # identical starting points
    seeds = [jax.random.PRNGKey(i) for i in range(M)]
    checkpoints = [dense.init(cfg1, k) for k in seeds]
    merged0 = common.merge_instances(checkpoints, axes1)

    # --- fused training of M models at once ---
    data = PerInstanceData(cfg, M)
    from repro.train.loop import TrainState
    from repro.optim import adamw_init
    state = TrainState(merged0, adamw_init(merged0))
    state, losses = train_loop.train_loop(
        cfg, data, steps=STEPS, batch_size=4, seq_len=32,
        lr_schedule=constant(1e-3), log_every=10, state=state,
    )
    print(f"fused training of {M} models: loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")

    # --- instance 0 trained alone on the same stream ---
    solo_data = pipeline.SyntheticLM(cfg1.vocab_size, 1, seed=50)
    solo_state = TrainState(checkpoints[0], adamw_init(checkpoints[0]))
    solo_state, solo_losses = train_loop.train_loop(
        cfg1, solo_data, steps=STEPS, batch_size=4, seq_len=32,
        lr_schedule=constant(1e-3), log_every=10, state=solo_state,
    )

    fused_inst0 = common.take_instance(state.params, dense.axes(cfg), 0)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        fused_inst0, solo_state.params,
    )
    worst = max(jax.tree.leaves(diffs))
    print(f"max |fused-instance-0 - solo-trained| over all params: {worst:.2e}")
    # the only coupling is the global grad-clip norm (computed over all M
    # instances when fused) — with clipping rarely active the trajectories
    # coincide to float tolerance.
    assert worst < 5e-2, worst
    print("OK: merged training == per-model training (instance-local gradients)")


if __name__ == "__main__":
    main()
