"""NetFuse reproduction package.

Importing ``repro`` installs the mesh-API compatibility shim
(``launch/compat.py``): JAX releases disagree on how a mesh is made
current (``jax.set_mesh`` / ``jax.sharding.use_mesh`` / the 0.4.x
``with mesh:`` resource env), and the launch + serving layers — as well
as the test-suite — use the modern ``jax.set_mesh`` spelling.
"""
from repro.launch import compat as _compat

_compat.install()
