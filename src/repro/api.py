"""Uniform model API: family dispatch + input specs for every
(architecture × input shape) combination.

Entry points used by the launcher, tests and benchmarks:

  init / abstract_params / axes
  train_logits(cfg, params, batch)   -> (logits, aux) aligned with labels
  prefill(cfg, params, batch)        -> (last logits, cache)
  decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
  make_cache / abstract_cache / cache_axes
  input_specs(cfg, shape)            -> batch of ShapeDtypeStructs

Batch layout per family (see DESIGN.md §5):
  dense/moe/ssm/hybrid: {tokens (M,B,S), labels (M,B,S)}
  vlm:   {tokens (M,B,S-P), image_embeds (M,B,P,Dv), labels (M,B,S-P)}
  audio: {tokens (M,B,S), frames (M,B,F,D), labels (M,B,S)}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import audio, dense, hybrid, moe, ssm, vlm
from repro.models import layers as L

_FAMILY = {
    "dense": dense, "moe": moe, "ssm": ssm, "hybrid": hybrid,
    "vlm": vlm, "audio": audio,
}


def family_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init(cfg, key):
    return family_module(cfg).init(cfg, key)


def abstract_params(cfg):
    return family_module(cfg).abstract_params(cfg)


def axes(cfg):
    return family_module(cfg).axes(cfg)


# ---------------------------------------------------------------------------
# forward entry points
# ---------------------------------------------------------------------------


def train_logits(cfg: ModelConfig, params, batch, *, remat: bool | None = None):
    """Logits aligned with batch['labels'] (next-token labels)."""
    remat = cfg.remat if remat is None else remat
    fam = cfg.family
    if fam in ("dense",):
        return dense.forward(cfg, params, batch["tokens"], remat=remat)
    if fam == "moe":
        logits, aux = moe.forward(cfg, params, batch["tokens"], remat=remat, return_aux=True)
        return logits, aux
    if fam == "ssm":
        return ssm.forward(cfg, params, batch["tokens"], remat=remat)
    if fam == "hybrid":
        return hybrid.forward(cfg, params, batch["tokens"], remat=remat)
    if fam == "vlm":
        return vlm.text_logits(cfg, params, batch["tokens"], batch["image_embeds"], remat=remat)
    if fam == "audio":
        return audio.forward(cfg, params, batch["tokens"], batch["frames"], remat=remat)
    raise ValueError(fam)


def prefill(cfg: ModelConfig, params, batch, *, cache_len: int | None = None):
    fam = cfg.family
    if fam == "dense":
        return dense.prefill(cfg, params, batch["tokens"], cache_len=cache_len)
    if fam == "moe":
        return moe.prefill(cfg, params, batch["tokens"], cache_len=cache_len)
    if fam == "ssm":
        return ssm.prefill(cfg, params, batch["tokens"])
    if fam == "hybrid":
        return hybrid.prefill(cfg, params, batch["tokens"])
    if fam == "vlm":
        return vlm.prefill(cfg, params, batch["tokens"], batch["image_embeds"], cache_len=cache_len)
    if fam == "audio":
        return audio.prefill(cfg, params, batch["tokens"], batch["frames"], cache_len=cache_len)
    raise ValueError(fam)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    return family_module(cfg).decode_step(cfg, params, cache, tokens, pos)


def decode_step_sample(cfg: ModelConfig, params, cache, tokens, pos):
    """Greedy decode step: (next_token (M,B) int32, new cache).

    Families with a fused decode+sample path (dense/vlm megakernel:
    final-norm + logits + argmax in one Pallas call) provide their own;
    everything else is argmax over decode_step logits — token-identical
    to the engine's temperature<=0 sampler either way."""
    mod = family_module(cfg)
    if hasattr(mod, "decode_step_sample"):
        return mod.decode_step_sample(cfg, params, cache, tokens, pos)
    logits, new_cache = mod.decode_step(cfg, params, cache, tokens, pos)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache


# ---------------------------------------------------------------------------
# chunked prefill (chainable cache-carry protocol — DESIGN.md §6.2)
# ---------------------------------------------------------------------------


def prefill_prefix_len(cfg: ModelConfig) -> int:
    """Learned-prefix positions that precede the prompt tokens in the
    prefill position stream (hybrid meta tokens, vlm image patches)."""
    if cfg.family == "hybrid":
        return hybrid.NUM_META_TOKENS
    if cfg.family == "vlm":
        return cfg.num_image_patches
    return 0


def init_chunk_carry(cfg: ModelConfig, m: int, b: int, cache_len: int):
    """Fresh chunk-prefill carry: {"cache": <the family's decode
    cache/state tree>} plus family extras (moe adds per-layer expert
    counts).  The cache leaf shapes match ``make_cache`` at the same
    ``cache_len``, so the serving slot scatter consumes carries
    unchanged."""
    return family_module(cfg).init_chunk_carry(cfg, m, b, cache_len)


def chunk_carry_axes(cfg: ModelConfig):
    """Logical-axes tree matching :func:`init_chunk_carry`'s structure."""
    return family_module(cfg).chunk_carry_axes(cfg)


def prefill_chunk(cfg: ModelConfig, params, batch, carry, offset):
    """Process one prompt chunk, threading the carry.

    batch["tokens"] is (M,B,C) at absolute positions offset..offset+C-1
    (offset: (M,B) int32; positions below ``prefill_prefix_len`` take
    the family's prefix embeddings and ignore the token ids).  vlm/audio
    additionally read batch["image_embeds"]/batch["frames"]; moe reads
    batch["moe_limit"]; batch["valid"] (M,B,C) bool marks the junk
    suffix of a padded final chunk (tail folding — the junk never
    reaches caches, routing or recurrent state).  Returns the advanced
    carry — every family, any prompt length, ONE compiled shape."""
    return family_module(cfg).prefill_chunk(cfg, params, batch, carry, offset)


def make_cache(cfg: ModelConfig, m: int, b: int, context_len: int):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return dense.make_cache(cfg, m, b, context_len)
    if fam == "moe":
        return moe.make_cache(cfg, m, b, context_len)
    if fam == "ssm":
        return ssm.make_state(cfg, m, b)
    if fam == "hybrid":
        return hybrid.make_cache(cfg, m, b, context_len)
    if fam == "audio":
        return audio.make_cache(cfg, m, b, context_len)
    raise ValueError(fam)


def abstract_cache(cfg, m, b, context_len):
    return jax.eval_shape(lambda: make_cache(cfg, m, b, context_len))


def take_state(cfg: ModelConfig, cache, m, b):
    """Slot surgery: slice slot (m, b) out of an (M, B)-grid cache/state
    tree (singleton dims kept).  Works for every family — KV-cache stacks
    and recurrent-state layouts alike; ssm/hybrid provide their own
    helpers, the rest go through the generic axes-driven path."""
    fam = family_module(cfg)
    if hasattr(fam, "take_state"):
        return fam.take_state(cfg, cache, m, b)
    from repro.models.common import tree_take_slot
    return tree_take_slot(cache, cache_axes(cfg), m, b)


def put_state(cfg: ModelConfig, grid, one, m, b):
    """Slot surgery: write a single-slot cache/state tree into grid slot
    (m, b).  Inverse of :func:`take_state`."""
    fam = family_module(cfg)
    if hasattr(fam, "put_state"):
        return fam.put_state(cfg, grid, one, m, b)
    from repro.models.common import tree_put_slot
    return tree_put_slot(grid, cache_axes(cfg), one, m, b)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — nothing allocated)
# ---------------------------------------------------------------------------


def _tok(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for jit(...).lower(**input_specs).

    Returns {"batch": ...} for train/prefill; decode shapes return
    {"cache": ..., "tokens": ..., "pos": ...}."""
    m = cfg.num_instances
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b = shape.global_batch // m
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            p = cfg.num_image_patches
            batch = {
                "tokens": _tok(m, b, s - p),
                "image_embeds": jax.ShapeDtypeStruct((m, b, p, cfg.vision_embed_dim), dt),
            }
            if shape.kind == "train":
                batch["labels"] = _tok(m, b, s - p)
        elif cfg.family == "audio":
            batch = {
                "tokens": _tok(m, b, s),
                "frames": jax.ShapeDtypeStruct((m, b, cfg.num_audio_frames, cfg.d_model), dt),
            }
            if shape.kind == "train":
                batch["labels"] = _tok(m, b, s)
        else:
            batch = {"tokens": _tok(m, b, s)}
            if shape.kind == "train":
                batch["labels"] = _tok(m, b, s)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    return {
        "cache": abstract_cache(cfg, m, b, s),
        "tokens": _tok(m, b, 1),
        "pos": _tok(m, b),
    }


# ---------------------------------------------------------------------------
# loss (used by train_step and smoke tests)
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params, batch):
    """Mean next-token cross entropy (+ MoE aux)."""
    from repro.models.common import constrain

    out = train_logits(cfg, params, batch)
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        out, aux = out
    logits = out.astype(jnp.float32)
    # loss region: batch over data, vocab over model (the (tokens, V)
    # logits tensor is the largest activation in training — see DESIGN.md)
    logits = constrain(logits, "instances", "batch", None, "vocab")
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = nll.mean()
    return loss + cfg.router_aux_loss * aux, {"nll": loss, "aux": aux}


def cache_axes(cfg: ModelConfig):
    """Logical-axis tree matching abstract_cache's structure."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return dense.cache_axes(cfg)
    if fam == "moe":
        return moe.cache_axes(cfg)
    if fam == "ssm":
        return ssm.state_axes(cfg)
    if fam == "hybrid":
        return hybrid.cache_axes(cfg)
    if fam == "audio":
        return audio.cache_axes(cfg)
    raise ValueError(fam)
