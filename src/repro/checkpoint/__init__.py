from repro.checkpoint.store import restore, restore_to_shardings, save
