"""Checkpointing: pytree <-> directory of .npy leaves + JSON manifest.

Leaves are stored host-side as numpy; ``restore_to_shardings`` re-places
each leaf onto its NamedSharding at load (sharding-aware restore: the
checkpoint format is layout-free, the placement comes from the current
mesh/rules).  Structure keys are the jax.tree_util key paths, so any of
the model-zoo pytrees (nested dicts / lists / NamedTuples) round-trip.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fname(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"


def save(path: str | Path, tree: Any, *, extra: dict | None = None) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"leaves": {}, "extra": extra or {}}
    for keypath, leaf in leaves:
        key = _key_str(keypath)
        arr = np.asarray(jax.device_get(leaf))
        np.save(path / _fname(key), arr)
        manifest["leaves"][key] = {
            "file": _fname(key), "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def restore(path: str | Path, like: Any, *, faults=None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``faults`` is an optional armed
    :class:`~repro.serving.resilience.faults.FaultInjector`; the
    ``checkpoint`` site fires before the manifest read (deterministic
    checkpoint-read failure for the chaos suite)."""
    if faults is not None and faults.armed:
        faults.on_call("checkpoint")
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves_info = manifest["leaves"]

    def load(keypath, leaf):
        key = _key_str(keypath)
        if key not in leaves_info:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(path / leaves_info[key]["file"])
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {want}")
        return jnp.asarray(arr, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(load, like)


def restore_to_shardings(path: str | Path, like: Any, shardings: Any,
                         *, faults=None) -> Any:
    """Restore and device_put each leaf to its sharding (pytree of
    jax.sharding.Sharding matching ``like``)."""
    host = restore(path, like, faults=faults)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host, shardings)
