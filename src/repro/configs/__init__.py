"""Architecture configs: one module per assigned arch (+ the paper's own
evaluation models). See repro.configs.registry for the --arch map."""
from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K, ModelConfig, ShapeConfig,
)
from repro.configs import registry
