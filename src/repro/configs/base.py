"""Model/config schema shared by all architectures.

Every assigned architecture gets a module ``repro/configs/<id>.py``
exporting ``CONFIG`` (the exact published shape) and ``smoke_config()``
(a reduced same-family variant for CPU tests: <=2 layers, d_model<=512,
<=4 experts).  ``repro.configs.registry`` maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "cnn", "encoder"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM / xLSTM / Mamba ---
    ssm_state: int = 0                # mamba d_state
    conv_kernel: int = 4
    slstm_every: int = 0              # xlstm: layer i is sLSTM if i % slstm_every == slstm_offset
    slstm_offset: int = 3
    mlstm_proj_factor: float = 2.0
    mlstm_chunk: int = 64             # chunkwise-parallel mLSTM chunk length (§Perf knob)
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0           # 0 = full attention
    global_layer_every: int = 0       # hybrid: 0 = none; else layers 0, mid, last are global
    # --- norm / misc ---
    norm_eps: float = 1e-5
    use_layernorm: bool = False       # False -> RMSNorm (llama family)
    tie_embeddings: bool = False
    act: str = "silu"                 # mlp activation (silu -> SwiGLU, gelu -> GELU MLP)
    # --- enc-dec / multimodal stubs (frontends are stubs per spec) ---
    encoder_layers: int = 0           # whisper encoder depth
    num_audio_frames: int = 0         # whisper: encoder positions (post-conv)
    num_image_patches: int = 0        # vlm: stub patch-embedding positions
    vision_embed_dim: int = 0         # vlm/audio stub embedding dim (pre-projector)
    max_target_positions: int = 0     # enc-dec learned positions (0 -> RoPE decoder)
    # --- cnn (paper's own eval models) ---
    cnn_stage_blocks: tuple[int, ...] = ()
    cnn_width: int = 64
    cnn_cardinality: int = 1          # resnext groups
    image_size: int = 224
    num_classes: int = 1000
    # --- NetFuse ---
    num_instances: int = 1            # M merged fine-tuned instances
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True                # activation checkpointing in train_step
    # route supported blocks through the Pallas kernels (interpret=True on
    # CPU, Mosaic on TPU) — forward/serving paths; training keeps the XLA
    # scan (pallas_call has no registered VJP).  Off by default: the
    # dry-run rooflines stay pure-XLA so §Perf deltas are attributable.
    use_pallas_kernels: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
