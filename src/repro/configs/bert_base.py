"""BERT-base — the paper's own NLP eval model [Devlin et al. 2018]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base", family="encoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=30522, max_target_positions=512,
    use_layernorm=True, act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-smoke", family="encoder",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=257, max_target_positions=128,
        use_layernorm=True, act="gelu",
        dtype="float32", param_dtype="float32",
    )
