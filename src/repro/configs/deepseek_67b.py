"""deepseek-67b — llama-arch dense [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=257,
        dtype="float32", param_dtype="float32",
    )
