"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, sliding_window=1024, head_dim=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=257, ssm_state=8, sliding_window=32,
        dtype="float32", param_dtype="float32",
    )
