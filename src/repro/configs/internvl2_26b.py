"""internvl2-26b — InternViT (stub) + InternLM2 20B backbone [arXiv:2404.16821].

The vision encoder is a STUB per the assignment: input_specs supplies
patch embeddings (num_image_patches x vision_embed_dim = InternViT-6B
hidden size); this config is the language decoder + MLP projector.
long_500k uses the sliding-window attention variant (see registry).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    num_image_patches=256, vision_embed_dim=3200,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=257, num_image_patches=8, vision_embed_dim=96,
        dtype="float32", param_dtype="float32",
    )
