"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936,
    num_experts=128, num_experts_per_tok=8,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=257, num_experts=4, num_experts_per_tok=2,
        dtype="float32", param_dtype="float32",
    )
