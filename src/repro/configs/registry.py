"""``--arch`` registry: maps architecture ids to configs and families.

``config_for_shape`` applies the per-shape adaptations from DESIGN.md §4:
the long_500k decode shape switches full-attention families (dense, moe,
vlm) to the sliding-window variant (window 8192); ssm/hybrid run it
natively; whisper skips it (enc-dec) — ``supported`` returns False.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

# arch id -> config module name (under repro.configs)
ASSIGNED = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-26b": "internvl2_26b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "deepseek-67b": "deepseek_67b",
    "whisper-small": "whisper_small",
    "granite-3-2b": "granite_3_2b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}

# the paper's own evaluation models (benchmarks + tests, not dry-run pairs)
PAPER_MODELS = {
    "resnet50": "resnet50",
    "resnext50": "resnext50",
    "bert-base": "bert_base",
    "xlnet-base": "xlnet_base",
}

ALL = {**ASSIGNED, **PAPER_MODELS}

LONG_CONTEXT_WINDOW = 8192  # sliding window used by full-attention archs at 500k


def _module(arch: str):
    if arch not in ALL:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALL)}")
    return importlib.import_module(f"repro.configs.{ALL[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def supported(arch: str, shape: ShapeConfig | str) -> bool:
    shape = SHAPES[shape] if isinstance(shape, str) else shape
    cfg = get_config(arch)
    if cfg.family in ("cnn", "encoder"):
        return False  # paper eval models: benchmark-only
    if shape.name == "long_500k" and cfg.family == "audio":
        return False  # enc-dec decoder horizon (DESIGN.md §4)
    return True


def config_for_shape(arch: str, shape: ShapeConfig | str, *, num_instances: int = 1) -> ModelConfig:
    shape = SHAPES[shape] if isinstance(shape, str) else shape
    cfg = get_config(arch)
    if not supported(arch, shape):
        raise ValueError(f"{arch} does not run shape {shape.name} (see DESIGN.md §4)")
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        cfg = cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    if shape.kind in ("prefill", "decode"):
        # inference deployments carry bf16 weights (f32 masters are a
        # training-only concern)
        cfg = cfg.with_(param_dtype="bfloat16")
    if num_instances != 1:
        cfg = cfg.with_(num_instances=num_instances)
    return cfg
