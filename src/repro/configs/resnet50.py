"""ResNet-50 — the paper's own CNN eval model [He et al. 2016]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet50", family="cnn",
    num_layers=16, d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=0, cnn_stage_blocks=(3, 4, 6, 3), cnn_width=64,
    cnn_cardinality=1, image_size=224, num_classes=1000,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="resnet-smoke", family="cnn",
        num_layers=0, d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
        vocab_size=0, cnn_stage_blocks=(1, 1), cnn_width=8,
        cnn_cardinality=1, image_size=32, num_classes=10,
        dtype="float32", param_dtype="float32",
    )
