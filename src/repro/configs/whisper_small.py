"""whisper-small — enc-dec, conv frontend stubbed [arXiv:2212.04356].

input_specs supplies post-conv frame embeddings (1500 x d_model).
Decoder learned positions are extended to cover the assigned train_4k
shape (4096 > the published 448; noted in DESIGN.md).  long_500k is
skipped for this arch (enc-dec; see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, num_audio_frames=1500, max_target_positions=33024,
    use_layernorm=True, act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=257, encoder_layers=2, num_audio_frames=16,
        max_target_positions=128, use_layernorm=True, act="gelu",
        dtype="float32", param_dtype="float32",
    )
