"""XLNet-base (Transformer-XL rel-attention) — paper eval model
[Yang et al. 2019]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlnet-base", family="encoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=32000, max_target_positions=512,
    use_layernorm=True, act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlnet-smoke", family="encoder",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=257, max_target_positions=128,
        use_layernorm=True, act="gelu",
        dtype="float32", param_dtype="float32",
    )
