"""xlstm-1.3b — sLSTM + mLSTM blocks, xLSTM[7:1] [arXiv:2405.04517].

The spec gives d_ff=0: xLSTM blocks have no separate FFN; mLSTM blocks
up-project by mlstm_proj_factor=2 internally and sLSTM blocks carry a
4/3-factor gated FFN (paper defaults).  With the paper's block-diagonal
per-head q/k/v this lands at ~1.6B params (the published model rounds
to "1.3b"; see DESIGN.md §4 notes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=8, slstm_offset=3, mlstm_proj_factor=2.0,
    mlstm_chunk=128,  # §Perf xlstm iteration 5: halves chunk-boundary state stacking
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=257, slstm_every=2, slstm_offset=1,
        dtype="float32", param_dtype="float32",
    )
