"""NetFuse core: merged op counterparts, graph merging (paper Alg. 1),
parameter merging, and serving-strategy baselines."""
from repro.core import baselines, fused_ops, graph, merge
from repro.core.fused_ops import (
    batch_matmul,
    batch_matmul_concat,
    batch_to_channel,
    channel_to_batch,
    group_norm,
    grouped_conv2d,
    merged_batch_norm,
    merged_embedding,
    merged_layer_norm,
)
from repro.core.graph import Graph, MergeDim, execute, execute_merged, merge_graph
from repro.core.merge import (
    add_instance_axis,
    concat_instances,
    num_instances,
    stack_instances,
    unstack_instances,
)
