"""Serving-strategy baselines from the paper's evaluation (§5.1).

The paper compares NetFuse against three multi-model execution
strategies on a single GPU.  Their TPU/JAX analogues (see DESIGN.md §2.3
for the mapping rationale):

* ``sequential``  — one jitted executable, dispatched M times
  back-to-back with different weights (paper: round-robin, one by one).
* ``concurrent``  — ONE jitted program containing M independent
  sub-graphs; XLA is free to overlap them (the JAX analogue of M CUDA
  processes/streams — single-process runtimes have no 500 MB-per-process
  base cost, so the paper's OOM failure mode maps to compile-time
  working-set growth instead).
* ``hybrid(P)``   — ceil(M/P) sequential rounds of P-way concurrent
  groups (paper: P processes × M/P sequential models each).
* ``netfuse``     — stack the M param pytrees and run the fusion-aware
  forward once (the paper's technique).

All strategies return per-instance outputs in the same order, so tests
can assert bit-equal results across strategies.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import merge as merge_lib

Pytree = Any
ApplyFn = Callable[..., jax.Array]  # apply(params_with_M_axis, x_with_M_axis)


def _single(apply_fn: ApplyFn, params: Pytree, x: jax.Array) -> jax.Array:
    """Run one instance through the fusion-aware apply (M=1) and drop M."""
    out = apply_fn(merge_lib.add_instance_axis(params), x[None])
    return out[0]


def sequential(
    apply_fn: ApplyFn, params_list: Sequence[Pytree], inputs: Sequence[jax.Array]
) -> list[jax.Array]:
    """M separate dispatches of one compiled executable."""
    f = jax.jit(functools.partial(_single, apply_fn))
    return [f(p, x) for p, x in zip(params_list, inputs)]


def concurrent(
    apply_fn: ApplyFn, params_list: Sequence[Pytree], inputs: Sequence[jax.Array]
) -> list[jax.Array]:
    """One program with M independent sub-graphs (XLA may overlap)."""

    @jax.jit
    def run_all(ps, xs):
        return [_single(apply_fn, p, x) for p, x in zip(ps, xs)]

    return run_all(list(params_list), list(inputs))


def hybrid(
    apply_fn: ApplyFn,
    params_list: Sequence[Pytree],
    inputs: Sequence[jax.Array],
    *,
    num_concurrent: int,
) -> list[jax.Array]:
    """P-way concurrent groups, dispatched sequentially (paper §5.3)."""
    out: list[jax.Array] = []
    p = num_concurrent
    for i in range(0, len(params_list), p):
        out.extend(concurrent(apply_fn, params_list[i : i + p], inputs[i : i + p]))
    return out


def netfuse(
    apply_fn: ApplyFn, params_list: Sequence[Pytree], inputs: Sequence[jax.Array]
) -> list[jax.Array]:
    """The paper's technique: merge once, run one fused program."""
    merged = merge_lib.stack_instances(list(params_list))
    x = jnp.stack(list(inputs))
    out = jax.jit(apply_fn)(merged, x)
    return [out[i] for i in range(len(params_list))]


def netfuse_premerged(
    apply_fn: ApplyFn, merged_params: Pytree, x: jax.Array
) -> jax.Array:
    """Steady-state fused call (merging is offline/amortized, paper §4)."""
    return jax.jit(apply_fn)(merged_params, x)


STRATEGIES = {
    "sequential": sequential,
    "concurrent": concurrent,
    "netfuse": netfuse,
}
