"""NetFuse merged ("grouped") op counterparts.

Every weighted DNN op has a more general counterpart that supports
*input-weight local computation* (paper Table 1):

    matmul        -> batch matmul          (concat dim: Batch)
    convolution   -> grouped convolution   (concat dim: Channel)
    layer norm    -> group norm            (concat dim: Channel)
    batch norm    -> batch norm            (concat dim: Channel)
    elementwise / pooling / activations    (DontCare)

Two equivalent representations of the merged tensors are used throughout
the codebase:

* **instance-axis form** — merged tensors carry an explicit leading
  instance axis ``M`` (e.g. activations ``(M, B, S, D)``, weights
  ``(M, D, F)``).  This is the production path used by the fusion-aware
  model zoo: XLA sees one batched op per layer instead of M small ones.
* **concat form** — tensors are concatenated flat along Batch/Channel as
  in the paper's figures (e.g. ``(M*B, D)`` or ``(..., M*C)``).  This is
  what the graph-IR merger (:mod:`repro.core.graph`, paper Algorithm 1)
  produces, matching the paper bit-for-bit.

The functions here implement both forms; converting between the two is a
reshape (the very reshape Algorithm 1 inserts between Batch-merged and
Channel-merged ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Matrix multiplication -> batch matrix multiplication  (merge dim: Batch)
# ---------------------------------------------------------------------------


def batch_matmul(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Merged matmul in instance-axis form.

    x: (M, ..., D) — per-instance inputs, w: (M, D, F) — per-instance
    weights, b: optional (M, F).  Each instance's inputs only ever touch
    that instance's weights (input-weight local computation).
    """
    y = jnp.einsum("m...d,mdf->m...f", x, w)
    if b is not None:
        y = y + b.reshape(b.shape[0], *([1] * (y.ndim - 2)), b.shape[-1])
    return y


def batch_matmul_concat(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Merged matmul in concat (paper) form.

    x: (M*B, D) inputs concatenated along the batch dim, w: (M, D, F).
    Returns (M*B, F).
    """
    m = w.shape[0]
    xb = x.reshape(m, -1, x.shape[-1])          # (M, B, D)
    y = jnp.einsum("mbd,mdf->mbf", xb, w)
    if b is not None:
        y = y + b[:, None, :]
    return y.reshape(-1, y.shape[-1])


# ---------------------------------------------------------------------------
# Convolution -> grouped convolution  (merge dim: Channel)
# ---------------------------------------------------------------------------


def grouped_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    groups: int,
    stride: int | tuple[int, int] = 1,
    padding: str | tuple = "SAME",
) -> jax.Array:
    """Grouped 2-D convolution, NHWC / HWIO layout.

    x: (B, H, W, Cin*G), w: (K, K, Cin, Cout*G).  ``groups`` is the total
    number of input-weight local groups.  Merging M convs that already
    use G groups each yields an ``M*G``-group conv (paper §3.1: "merging
    4 grouped convolutions of 2 groups each -> 8 groups").
    """
    if isinstance(stride, int):
        stride = (stride, stride)
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def merge_conv_weights(ws: list[jax.Array]) -> jax.Array:
    """Concatenate M conv weights (K,K,Cin,Cout) along Cout -> grouped form."""
    return jnp.concatenate(ws, axis=-1)


# ---------------------------------------------------------------------------
# Layer norm -> group norm  (merge dim: Channel)
# ---------------------------------------------------------------------------


def group_norm(
    x: jax.Array,
    scale: jax.Array,
    bias: jax.Array,
    *,
    num_groups: int,
    eps: float = 1e-5,
) -> jax.Array:
    """Group normalization over the last (channel) axis.

    x: (..., G*C).  Each group of C channels is normalized independently —
    exactly the semantics needed to merge M layer norms (G = M): instance
    m's channels are normalized using only instance m's statistics.
    scale/bias: (G*C,).
    """
    *lead, ch = x.shape
    c = ch // num_groups
    xg = x.reshape(*lead, num_groups, c)
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    return xg.reshape(*lead, ch) * scale + bias


def merged_layer_norm(
    x: jax.Array,
    scale: jax.Array,
    bias: jax.Array | None,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    """Instance-axis form of the layer-norm merge.

    x: (M, ..., D), scale/bias: (M, D).  Equivalent to group_norm with
    G=M on the concat form; each instance normalized with its own stats
    and its own affine params.
    """
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * lax.rsqrt(var + eps)
    m, d = scale.shape
    bshape = (m,) + (1,) * (x.ndim - 2) + (d,)
    y = y * scale.reshape(bshape)
    if bias is not None:
        y = y + bias.reshape(bshape)
    return y


# ---------------------------------------------------------------------------
# Batch norm (inference) — channels concatenate directly
# ---------------------------------------------------------------------------


def merged_batch_norm(
    x: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    scale: jax.Array,
    bias: jax.Array,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    """Inference-mode batch norm; per-channel, so merged weights are just
    the concatenation of per-instance weights along the channel dim.

    x: (..., C_total); stats/affine: (C_total,).
    """
    inv = lax.rsqrt(var + eps) * scale
    return x * inv + (bias - mean * inv)


# ---------------------------------------------------------------------------
# Embedding lookup (instance-axis form)
# ---------------------------------------------------------------------------


def merged_embedding(ids: jax.Array, table: jax.Array) -> jax.Array:
    """ids: (M, ...), table: (M, V, D) -> (M, ..., D).

    Each instance's ids index only that instance's table.
    """
    return jnp.take_along_axis(
        table[(slice(None),) + (None,) * (ids.ndim - 1)],  # (M, 1.., V, D)
        ids[..., None, None],
        axis=-2,
    ).squeeze(-2)


# ---------------------------------------------------------------------------
# Form conversion — the reshape Algorithm 1 inserts
# ---------------------------------------------------------------------------


def batch_to_channel(x: jax.Array, m: int) -> jax.Array:
    """(M*B, ..., D) concat-along-Batch -> (B, ..., M*D) concat-along-Channel."""
    xb = x.reshape(m, -1, *x.shape[1:])           # (M, B, ..., D)
    xb = jnp.moveaxis(xb, 0, -2)                  # (B, ..., M, D)
    return xb.reshape(*xb.shape[:-2], m * x.shape[-1])


def channel_to_batch(x: jax.Array, m: int) -> jax.Array:
    """(B, ..., M*D) concat-along-Channel -> (M*B, ..., D) concat-along-Batch."""
    d = x.shape[-1] // m
    xb = x.reshape(*x.shape[:-1], m, d)           # (B, ..., M, D)
    xb = jnp.moveaxis(xb, -2, 0)                  # (M, B, ..., D)
    return xb.reshape(-1, *xb.shape[2:])
