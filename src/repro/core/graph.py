"""Op-graph IR + the paper's Algorithm 1 (end-to-end DNN merging).

This module is the faithful implementation of NetFuse's formal
contribution: a BFS traversal over a DNN op graph that

  1. merges each op with its M per-instance weight sets into the op's
     *input-weight-local* counterpart (matmul -> batch matmul,
     conv -> grouped conv, layer norm -> group norm, ...),
  2. tracks the concat dimension each merged op requires
     (Batch / Channel / DontCare),
  3. inserts reshape+transpose fix-up ops on edges whose producer and
     consumer disagree (paper Alg. 1 lines 29-36), and
  4. resolves DontCare ops to the majority dim of their parents
     (lines 23-27).

A small interpreter (:func:`execute`) runs both the original and the
merged graphs with jnp ops so tests can assert the merge is *exact*
(paper: "NETFUSE does not alter the computation results in any way").

The production model zoo does not go through this IR — it uses the
instance-axis fusion-aware layers directly (see DESIGN.md §2.1) — but the
semantics are identical and are cross-checked in tests.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import Counter, deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fused_ops


class MergeDim(enum.Enum):
    BATCH = "Batch"
    CHANNEL = "Channel"
    DONTCARE = "DontCare"


# Concat dim demanded by each (merged) op type — paper Alg. 1 lines 12-16.
_REQUIRED_DIM: dict[str, MergeDim] = {
    "matmul": MergeDim.BATCH,
    "bmm": MergeDim.BATCH,
    "flatten": MergeDim.BATCH,
    "conv2d": MergeDim.CHANNEL,
    "layernorm": MergeDim.CHANNEL,
    "groupnorm": MergeDim.CHANNEL,
    "batchnorm": MergeDim.CHANNEL,
}
# everything else (relu/gelu/tanh/add/mul/maxpool2d/avgpool2d/
# global_avgpool/input) is DontCare.


@dataclasses.dataclass
class OpNode:
    name: str
    op_type: str
    inputs: list[str] = dataclasses.field(default_factory=list)
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Graph:
    """A DAG of named ops. ``ops`` is insertion-ordered; edges are implied
    by ``OpNode.inputs``. Ops of type ``input`` are graph inputs."""

    ops: dict[str, OpNode] = dataclasses.field(default_factory=dict)
    outputs: list[str] = dataclasses.field(default_factory=list)

    def add(self, name: str, op_type: str, inputs: list[str] | None = None, **attrs) -> str:
        assert name not in self.ops, f"duplicate op {name}"
        self.ops[name] = OpNode(name, op_type, list(inputs or []), attrs)
        return name

    def consumers(self, name: str) -> list[OpNode]:
        return [op for op in self.ops.values() if name in op.inputs]


# ---------------------------------------------------------------------------
# Merge() for a single op — paper §3.1
# ---------------------------------------------------------------------------


def _merge_op(
    op: OpNode, weights: list[dict[str, jax.Array]] | None, num_instances: int
) -> tuple[OpNode, dict[str, jax.Array] | None, MergeDim]:
    """Merge one op with its M per-instance weight dicts.

    Returns (merged op node, merged weights, required concat dim).
    """
    m = num_instances
    t = op.op_type
    dim = _REQUIRED_DIM.get(t, MergeDim.DONTCARE)
    attrs = dict(op.attrs)

    if t == "matmul":
        # matmul -> batch matmul; weights stacked along a new leading axis.
        w = jnp.stack([wi["w"] for wi in weights])
        merged = {"w": w}
        if "b" in weights[0]:
            merged["b"] = jnp.stack([wi["b"] for wi in weights])
        return OpNode(op.name, "bmm", list(op.inputs), {"num_groups": m}), merged, dim
    if t == "bmm":
        # already input-weight local: stack the group axes -> M*G groups.
        g = op.attrs.get("num_groups", weights[0]["w"].shape[0])
        w = jnp.concatenate([wi["w"] for wi in weights], axis=0)
        merged = {"w": w}
        if "b" in weights[0]:
            merged["b"] = jnp.concatenate([wi["b"] for wi in weights], axis=0)
        attrs["num_groups"] = m * g
        return OpNode(op.name, "bmm", list(op.inputs), attrs), merged, dim
    if t == "conv2d":
        # conv (groups=g) -> grouped conv (groups = M*g); Cout concat.
        g = op.attrs.get("groups", 1)
        merged = {"w": jnp.concatenate([wi["w"] for wi in weights], axis=-1)}
        if "b" in weights[0]:
            merged["b"] = jnp.concatenate([wi["b"] for wi in weights], axis=-1)
        attrs["groups"] = m * g
        return OpNode(op.name, "conv2d", list(op.inputs), attrs), merged, dim
    if t in ("layernorm", "groupnorm"):
        # layer norm -> group norm (G = M * previous G); channel concat.
        g = op.attrs.get("num_groups", 1) if t == "groupnorm" else 1
        merged = {
            "scale": jnp.concatenate([wi["scale"] for wi in weights], axis=-1),
            "bias": jnp.concatenate([wi["bias"] for wi in weights], axis=-1),
        }
        attrs = {"num_groups": m * g, "eps": op.attrs.get("eps", 1e-5)}
        return OpNode(op.name, "groupnorm", list(op.inputs), attrs), merged, dim
    if t == "batchnorm":
        merged = {
            k: jnp.concatenate([wi[k] for wi in weights], axis=-1)
            for k in ("mean", "var", "scale", "bias")
        }
        return OpNode(op.name, "batchnorm", list(op.inputs), attrs), merged, dim
    # non-trainable ops merge as-is (paper §3.1 "Non-trainable operations").
    return OpNode(op.name, t, list(op.inputs), attrs), None, dim


# ---------------------------------------------------------------------------
# Algorithm 1 — DNN merging
# ---------------------------------------------------------------------------


def merge_graph(
    graph: Graph,
    weights: list[dict[str, dict[str, jax.Array]]],
) -> tuple[Graph, dict[str, dict[str, jax.Array]], dict[str, MergeDim]]:
    """Merge M instances of ``graph`` (same architecture, different
    weights) into one merged graph.  ``weights[m][op_name]`` holds
    instance m's params for ``op_name``.

    Returns (merged graph, merged weights, concat-dim per op) — the dim
    map is what the executor uses to split merged outputs back into
    per-instance results.
    """
    m = len(weights)
    merged = Graph(outputs=list(graph.outputs))
    merged_weights: dict[str, dict[str, jax.Array]] = {}
    dims: dict[str, MergeDim] = {}

    # BFS from root ops (no incoming edges) — Alg. 1 lines 5-6.  We keep
    # Kahn-style indegree tracking so an op is visited only after all its
    # parents (needed to read parents' dims).
    indeg = {name: len(op.inputs) for name, op in graph.ops.items()}
    q: deque[str] = deque(name for name, d in indeg.items() if d == 0)
    visited: set[str] = set()
    n_reshapes = 0

    while q:
        name = q.popleft()
        if name in visited:
            continue
        visited.add(name)
        op = graph.ops[name]

        per_instance = [w.get(name, {}) for w in weights]
        has_w = any(per_instance)
        m_op, m_w, d_i = _merge_op(op, per_instance if has_w else None, m)
        if m_w is not None:
            merged_weights[name] = m_w

        # DontCare ops inherit the majority dim of their parents
        # (Alg. 1 lines 23-27).
        if d_i is MergeDim.DONTCARE:
            parent_dims = [dims[p] for p in op.inputs if dims.get(p) is not None]
            parent_dims = [d for d in parent_dims if d is not MergeDim.DONTCARE]
            if parent_dims:
                d_i = Counter(parent_dims).most_common(1)[0][0]
            elif op.op_type == "input":
                d_i = MergeDim.BATCH  # root inputs default to batch concat
        dims[name] = d_i

        # Insert reshape fix-ups on mismatched edges (lines 29-36).
        new_inputs = []
        for parent in m_op.inputs:
            d_j = dims[parent]
            if (
                d_i is not MergeDim.DONTCARE
                and d_j is not MergeDim.DONTCARE
                and d_j != d_i
            ):
                r = f"_reshape{n_reshapes}_{parent}_to_{name}"
                n_reshapes += 1
                merged.add(
                    r,
                    "merge_reshape",
                    [parent],
                    from_dim=d_j.value,
                    to_dim=d_i.value,
                    num_instances=m,
                )
                dims[r] = d_i
                new_inputs.append(r)
            else:
                new_inputs.append(parent)
        m_op.inputs = new_inputs
        merged.ops[name] = m_op

        for child in graph.consumers(name):
            indeg[child.name] -= 1
            if indeg[child.name] == 0 and child.name not in visited:
                q.append(child.name)

    assert len(visited) == len(graph.ops), "graph has a cycle or dangling op"
    return merged, merged_weights, dims


# ---------------------------------------------------------------------------
# Interpreter
# ---------------------------------------------------------------------------


def _run_op(op: OpNode, args: list[jax.Array], w: dict[str, jax.Array] | None) -> jax.Array:
    t, a = op.op_type, op.attrs
    if t == "matmul":
        y = args[0] @ w["w"]
        return y + w["b"] if "b" in w else y
    if t == "bmm":
        return fused_ops.batch_matmul_concat(args[0], w["w"], w.get("b"))
    if t == "conv2d":
        y = fused_ops.grouped_conv2d(
            args[0], w["w"], groups=a.get("groups", 1),
            stride=a.get("stride", 1), padding=a.get("padding", "SAME"),
        )
        return y + w["b"] if "b" in w else y
    if t == "layernorm":
        return fused_ops.group_norm(
            args[0], w["scale"], w["bias"], num_groups=1, eps=a.get("eps", 1e-5)
        )
    if t == "groupnorm":
        return fused_ops.group_norm(
            args[0], w["scale"], w["bias"],
            num_groups=a.get("num_groups", 1), eps=a.get("eps", 1e-5),
        )
    if t == "batchnorm":
        return fused_ops.merged_batch_norm(
            args[0], w["mean"], w["var"], w["scale"], w["bias"], eps=a.get("eps", 1e-5)
        )
    if t == "merge_reshape":
        m = a["num_instances"]
        if a["from_dim"] == "Batch":
            return fused_ops.batch_to_channel(args[0], m)
        return fused_ops.channel_to_batch(args[0], m)
    if t == "relu":
        return jax.nn.relu(args[0])
    if t == "gelu":
        return jax.nn.gelu(args[0])
    if t == "tanh":
        return jnp.tanh(args[0])
    if t == "add":
        return args[0] + args[1]
    if t == "mul":
        return args[0] * args[1]
    if t == "maxpool2d":
        k = a.get("kernel", 2)
        s = a.get("stride", k)
        return jax.lax.reduce_window(
            args[0], -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "SAME"
        )
    if t == "global_avgpool":
        return jnp.mean(args[0], axis=(1, 2))
    if t == "flatten":
        return args[0].reshape(args[0].shape[0], -1)
    raise NotImplementedError(f"op type {t}")


def execute(
    graph: Graph,
    inputs: dict[str, jax.Array],
    weights: dict[str, dict[str, jax.Array]],
) -> dict[str, jax.Array]:
    """Run ``graph`` with jnp ops; returns {output name: value}."""
    values: dict[str, jax.Array] = {}
    indeg = {n: len(op.inputs) for n, op in graph.ops.items()}
    q = deque(n for n, d in indeg.items() if d == 0)
    while q:
        name = q.popleft()
        op = graph.ops[name]
        if op.op_type == "input":
            values[name] = inputs[name]
        else:
            args = [values[p] for p in op.inputs]
            values[name] = _run_op(op, args, weights.get(name))
        for child in graph.consumers(name):
            indeg[child.name] -= 1
            if indeg[child.name] == 0:
                q.append(child.name)
    return {o: values[o] for o in graph.outputs}


def execute_merged(
    merged: Graph,
    merged_weights: dict[str, dict[str, jax.Array]],
    dims: dict[str, MergeDim],
    per_instance_inputs: list[dict[str, jax.Array]],
) -> list[dict[str, jax.Array]]:
    """Concatenate per-instance inputs per each input node's concat dim,
    run the merged graph once, and split outputs back per instance."""
    m = len(per_instance_inputs)
    inputs: dict[str, jax.Array] = {}
    for name, op in merged.ops.items():
        if op.op_type != "input":
            continue
        xs = [pi[name] for pi in per_instance_inputs]
        if dims[name] is MergeDim.CHANNEL:
            inputs[name] = jnp.concatenate(xs, axis=-1)
        else:
            inputs[name] = jnp.concatenate(xs, axis=0)
    outs = execute(merged, inputs, merged_weights)
    result: list[dict[str, jax.Array]] = [{} for _ in range(m)]
    for oname, val in outs.items():
        d = dims[oname]
        split = jnp.split(val, m, axis=-1 if d is MergeDim.CHANNEL else 0)
        for i in range(m):
            result[i][oname] = split[i]
    return result
