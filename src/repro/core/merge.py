"""Parameter-pytree merging — the production NetFuse path.

In JAX a model is a pure function ``apply(params, *inputs)``.  Merging M
fine-tuned instances of the same architecture therefore reduces to

  1. stacking the M parameter pytrees along a new leading ``instances``
     axis (``stack_instances``), and
  2. running a *fusion-aware* forward in which every weighted op is the
     input-weight-local counterpart (einsum with a leading ``m`` index,
     grouped conv, group norm, ...).

The model zoo (:mod:`repro.models`) is written fusion-aware from the
start: every apply function takes params with a leading ``M`` axis and
activations shaped ``(M, B, ...)``; ``M=1`` is the plain un-merged model.
So NetFuse-merging M checkpoints is literally ``stack_instances`` — the
same trick the paper implements with Torchscript graph surgery.

Also implements the paper §6 *common backbone* case: merge the shared
backbone, keep per-task heads separate.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def stack_instances(params_list: Sequence[Pytree]) -> Pytree:
    """Stack M per-instance param pytrees along a new leading axis.

    All pytrees must share treedef and leaf shapes (same architecture,
    different weights — the NetFuse precondition)."""
    if len(params_list) == 1:
        return jax.tree.map(lambda x: x[None], params_list[0])
    treedefs = {jax.tree.structure(p) for p in params_list}
    if len(treedefs) != 1:
        raise ValueError(
            "cannot merge models with different architectures "
            f"(got {len(treedefs)} distinct param structures)"
        )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_instances(merged: Pytree) -> list[Pytree]:
    """Inverse of :func:`stack_instances`."""
    m = jax.tree.leaves(merged)[0].shape[0]
    return [jax.tree.map(lambda x, i=i: x[i], merged) for i in range(m)]


def concat_instances(merged_a: Pytree, merged_b: Pytree) -> Pytree:
    """Merge two already-merged models (M_a + M_b instances) — grouped
    ops compose, per paper §3.1."""
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), merged_a, merged_b)


def add_instance_axis(params: Pytree) -> Pytree:
    """Lift a plain (un-merged) pytree to the M=1 instance-axis form."""
    return jax.tree.map(lambda x: x[None], params)


def num_instances(merged: Pytree) -> int:
    return jax.tree.leaves(merged)[0].shape[0]


# ---------------------------------------------------------------------------
# Common-backbone merging (paper §6): shared backbone merged, per-task
# heads kept separate.
# ---------------------------------------------------------------------------


def merge_backbone_with_heads(
    backbone_params_list: Sequence[Pytree],
    head_apply_list: Sequence[Callable[..., jax.Array]],
    head_params_list: Sequence[Pytree],
):
    """Returns (merged backbone params, per_task_heads fn).

    ``per_task_heads(features)`` applies task m's head to features[m]
    (features: (M, B, ...)).  Heads may have *different* architectures —
    e.g. different output class counts — which is exactly why they are
    not merged (paper: "we merge the backbones, but leave the customized
    layers as-is")."""
    merged_backbone = stack_instances(list(backbone_params_list))

    def per_task_heads(features: jax.Array) -> list[jax.Array]:
        return [
            head_apply_list[m](head_params_list[m], features[m])
            for m in range(len(head_apply_list))
        ]

    return merged_backbone, per_task_heads
