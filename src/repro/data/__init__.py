from repro.data.pipeline import (
    SyntheticLM, MemmapLM, make_vlm_batch, make_audio_batch, write_token_file,
)
