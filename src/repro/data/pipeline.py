"""Data pipeline: synthetic and file-backed token streams.

* :class:`SyntheticLM` — deterministic Zipf-ish synthetic tokens; each
  NetFuse instance gets its own stream (different inputs per merged
  model, the paper's setting).
* :class:`MemmapLM`   — file-backed token shards (uint32 memmap) with
  sequence packing and epoch shuffling; ``write_token_file`` produces
  shards.

Batches follow the layout in repro.api: tokens (M, B, S) int32, labels =
next-token shifted.  Frontend stubs for VLM/audio produce deterministic
pseudo-embeddings (the spec's carve-out: no real ViT / mel codec).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream; instance m draws from a shifted
    Zipf distribution so merged instances see genuinely different inputs."""
    vocab_size: int
    num_instances: int = 1
    seed: int = 0

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        m = self.num_instances
        toks = np.empty((m, batch_size, seq_len + 1), np.int32)
        for i in range(m):
            g = _rng(self.seed * 1_000_003 + step * 131 + i)
            # Zipf-flavored: mix of low-id-heavy and uniform tokens
            z = g.zipf(1.3, size=(batch_size, seq_len + 1))
            u = g.integers(0, self.vocab_size, size=(batch_size, seq_len + 1))
            pick = g.random((batch_size, seq_len + 1)) < 0.5
            toks[i] = np.where(pick, np.minimum(z, self.vocab_size - 1), u)
        return {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }


def write_token_file(path: str | Path, tokens: np.ndarray) -> None:
    """Write a uint32 token shard."""
    np.asarray(tokens, np.uint32).tofile(str(path))


@dataclasses.dataclass
class MemmapLM:
    """File-backed packed-token stream.  Documents are already
    concatenated in the shard; we slice (seq_len+1)-token windows with a
    per-epoch deterministic shuffle of window offsets."""
    paths: list[str]
    num_instances: int = 1
    seed: int = 0

    def __post_init__(self):
        self._shards = [np.memmap(p, dtype=np.uint32, mode="r") for p in self.paths]
        self._sizes = [len(s) for s in self._shards]

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        m = self.num_instances
        need = seq_len + 1
        toks = np.empty((m, batch_size, need), np.int32)
        for i in range(m):
            shard = self._shards[(step + i) % len(self._shards)]
            n_windows = max(1, (len(shard) - need) // need)
            g = _rng(self.seed * 7_919 + i)
            perm = g.permutation(n_windows)
            for b in range(batch_size):
                w = perm[(step * batch_size + b) % n_windows]
                toks[i, b] = shard[w * need : w * need + need].astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }


# ---------------------------------------------------------------------------
# modality frontend stubs (per assignment spec: precomputed embeddings)
# ---------------------------------------------------------------------------


def make_vlm_batch(cfg, step: int, batch_size: int, seq_len: int, seed: int = 0) -> dict:
    """tokens + stub ViT patch embeddings; seq_len counts total positions."""
    m, p = cfg.num_instances, cfg.num_image_patches
    s_text = seq_len - p
    lm = SyntheticLM(cfg.vocab_size, m, seed)
    b = lm.batch(step, batch_size, s_text)
    g = _rng(seed * 97 + step)
    img = g.standard_normal((m, batch_size, p, cfg.vision_embed_dim), np.float32) * 0.5
    b["image_embeds"] = jnp.asarray(img, jnp.dtype(cfg.dtype))
    return b


def make_audio_batch(cfg, step: int, batch_size: int, seq_len: int, seed: int = 0) -> dict:
    """decoder tokens + stub post-conv frame embeddings."""
    m = cfg.num_instances
    lm = SyntheticLM(cfg.vocab_size, m, seed)
    b = lm.batch(step, batch_size, seq_len)
    g = _rng(seed * 89 + step)
    fr = g.standard_normal((m, batch_size, cfg.num_audio_frames, cfg.d_model), np.float32) * 0.5
    b["frames"] = jnp.asarray(fr, jnp.dtype(cfg.dtype))
    return b


def make_batch(cfg, step: int, batch_size: int, seq_len: int, seed: int = 0) -> dict:
    if cfg.family == "vlm":
        return make_vlm_batch(cfg, step, batch_size, seq_len, seed)
    if cfg.family == "audio":
        return make_audio_batch(cfg, step, batch_size, seq_len, seed)
    return SyntheticLM(cfg.vocab_size, cfg.num_instances, seed).batch(step, batch_size, seq_len)
