"""Pallas TPU kernels for the NetFuse hot spots (validated with
interpret=True on CPU; see ops.py for dispatch)."""
from repro.kernels import ops, ref
from repro.kernels.chunk_prefill_attn import (
    chunk_prefill_attention,
    chunk_prefill_attention_sharded,
)
from repro.kernels.decode_layer import (
    decode_layer,
    decode_layer_sharded,
    logits_sample,
    logits_sample_sharded,
    tp_head_plan,
)
