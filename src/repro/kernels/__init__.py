"""Pallas TPU kernels for the NetFuse hot spots (validated with
interpret=True on CPU; see ops.py for dispatch)."""
from repro.kernels import ops, ref
from repro.kernels.chunk_prefill_attn import (
    chunk_prefill_attention,
    chunk_prefill_attention_sharded,
)
