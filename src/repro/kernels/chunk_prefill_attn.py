"""Pallas TPU kernel: chunked-prefill GQA flash attention over a KV cache.

The serving admission hot spot after tail folding: every chunk call
attends a C-token query block over ``[cache-before-chunk, chunk]``.
This extends ``decode_attn.py`` from q-len 1 to q-len C — the cache's S
axis streams through VMEM in blocks as the innermost grid axis, online-
softmax running (max, sum, acc) state lives in VMEM scratch across
S-steps (grid revisiting pattern), and the per-instance q tile
(C·G x hd) is resident the whole time.

Masking is ARITHMETIC, driven by the scalar-prefetched per-lane offsets
(the absolute position of each lane's first chunk token): slot j of a
pinned-prefix ring cache holds position j forever when j < pin (Hymba
meta tokens), else rings over positions >= pin — exactly
``layers.cache_positions_after(offset-1, S, pin)``; the appended chunk
rows (slots >= S_cache) sit at offset + (slot - S_cache).  One rule
covers causality, the sliding window, ring validity and the attention
sink, so the dense O((S+C)·C) position/mask tensors the XLA path
materializes per layer never exist here.

Grid: (M, B, KVH, T/bs) with T = S_cache + C.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            ns: int, bs: int, c: int, g: int, hd: int, s_cache: int,
            pin: int, window: int, sink: int, causal: bool):
    mi, bi, si = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    cg = c * g

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, 0].astype(jnp.float32).reshape(cg, hd)   # (C·G, hd)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)                   # (bs, hd)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T) / math.sqrt(hd)                         # (C·G, bs)

    # positions from the lane offset alone (rows are C-major over G)
    off = off_ref[mi, bi]
    ci = jax.lax.broadcasted_iota(jnp.int32, (cg, bs), 0) // g
    slot = si * bs + jax.lax.broadcasted_iota(jnp.int32, (cg, bs), 1)
    q_pos = off + ci
    # cache slots: pinned prefix + ring over positions >= pin
    # (== layers.cache_positions_after(off - 1, s_cache, pin))
    last = off - 1
    pinned = jnp.where(slot <= last, slot, -1)
    w = s_cache - pin
    if w > 0:
        qq = last - pin
        cur = qq % w
        base = qq - cur
        i2 = slot - pin
        ring = jnp.where(i2 <= cur, base + i2, base - w + i2) + pin
        ring = jnp.where((qq >= 0) & (ring >= pin), ring, -1)
        cache_pos = jnp.where(slot < pin, pinned, ring)
    else:
        cache_pos = pinned
    # appended chunk rows ride at their own absolute positions
    p = jnp.where(slot < s_cache, cache_pos, off + slot - s_cache)

    valid = p >= 0
    if causal:
        valid = valid & (p <= q_pos)
    if window > 0:
        in_win = q_pos - p < window
        if sink > 0:
            in_win = in_win | (p < sink)
        valid = valid & in_win
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                         # (C·G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(pexp, v)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _done():
        o_ref[0, 0, :, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).reshape(c, g, hd).astype(o_ref.dtype)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


@functools.partial(jax.jit, static_argnames=(
    "s_cache", "pin", "window", "sink", "causal", "block_s", "interpret"))
def chunk_prefill_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    offset: jax.Array,
    *,
    s_cache: int,
    pin: int = 0,
    window: int = 0,
    sink: int = 0,
    causal: bool = True,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """q: (M,B,C,H,hd); k,v: (M,B,T,KVH,hd) with T = s_cache + C — the
    pre-chunk cache concatenated with the chunk's own k/v; offset: (M,B)
    int32 absolute position of each lane's first chunk token.
    Returns (M,B,C,H,hd)."""
    m, b, c, h, hd = q.shape
    t, kvh = k.shape[2], k.shape[3]
    assert t == s_cache + c, (t, s_cache, c)
    g = h // kvh
    bs = _clamp(block_s, t)
    ns = t // bs
    grid = (m, b, kvh, ns)

    from jax.experimental.pallas import tpu as pltpu

    qg = q.reshape(m, b, c, kvh, g, hd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, 1, g, hd),
                         lambda mi, bi, ki, si, off: (mi, bi, 0, ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, 1, hd),
                         lambda mi, bi, ki, si, off: (mi, bi, si, ki, 0)),
            pl.BlockSpec((1, 1, bs, 1, hd),
                         lambda mi, bi, ki, si, off: (mi, bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, 1, g, hd),
                               lambda mi, bi, ki, si, off: (mi, bi, 0, ki, 0, 0)),
        scratch_shapes=[
            _vmem((c * g, 1), jnp.float32),
            _vmem((c * g, 1), jnp.float32),
            _vmem((c * g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel, ns=ns, bs=bs, c=c, g=g, hd=hd, s_cache=s_cache,
            pin=pin, window=window, sink=sink, causal=causal,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, b, c, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(offset.astype(jnp.int32), qg, k, v)
    return out.reshape(m, b, c, h, hd)


def chunk_prefill_attention_sharded(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    offset: jax.Array,
    *,
    rules,
    **kw,
) -> jax.Array:
    """``chunk_prefill_attention`` under ``shard_map`` on the rules' mesh.

    Serving layout mirrors ``decode_attention_sharded``: (M, B) lanes
    ride the data axes and KV-head groups ride "model" — q heads are
    kvh-major, so a contiguous H-split of KVH/n groups matches a
    contiguous KVH-split; each rank runs the kernel on its local block
    with the (replicated) lane offsets and writes its output shard.
    Exact with no collectives; interpret-mode fallback intact.  Falls
    back to the plain (GSPMD-partitioned) call when KVH doesn't divide
    the model axis.
    """
    from repro.launch.compat import shard_map

    m, b, c, h, hd = q.shape
    t, kvh = k.shape[2], k.shape[3]
    n_model = rules._axis_size(rules.mapping.get("kv_heads"))
    if n_model <= 1 or kvh % n_model or h % n_model:
        return chunk_prefill_attention(q, k, v, offset, **kw)

    q_spec = rules.spec(("instances", "batch", None, "kv_heads", None),
                        (m, b, c, h, hd))
    kv_spec = rules.spec(("instances", "batch", None, "kv_heads", None),
                         (m, b, t, kvh, hd))
    off_spec = rules.spec(("instances", "batch"), (m, b))
    return shard_map(
        lambda ql, kl, vl, ol: chunk_prefill_attention(ql, kl, vl, ol, **kw),
        mesh=rules.mesh,
        in_specs=(q_spec, kv_spec, kv_spec, off_spec),
        out_specs=q_spec,
        check_vma=False,
    )(q, k, v, offset)
