"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

The serving hot spot after NetFuse merging: every fused decode step reads
each instance's KV cache once.  TPU adaptation of flash-decoding: the
cache's S axis is streamed through VMEM in blocks as the innermost grid
axis; online-softmax running (max, sum, acc) state lives in VMEM scratch
across S-steps (grid revisiting pattern), and the per-instance q tile
(KVH*G x hd — e.g. 32x64) is resident the whole time.

Grid: (M, B, KVH, S/bs).  Masking: prefix-valid cache of length
kv_len[m, b] (scalar-prefetch operand), block positions via iota.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            ns: int, bs: int, hd: int):
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)           # (G, hd)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)        # (bs, hd)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)        # (bs, hd)

    s = jnp.dot(q, k.T) / math.sqrt(hd)              # (G, bs)
    kv_len = len_ref[0, 0]
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _done():
        o_ref[0, 0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_len: jax.Array,
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """q: (M,B,H,hd); k,v: (M,B,S,KVH,hd); kv_len: (M,B) int32.
    Returns (M,B,H,hd)."""
    m, b, h, hd = q.shape
    s, kvh = k.shape[2], k.shape[3]
    g = h // kvh
    bs = _clamp(block_s, s)
    ns = s // bs
    grid = (m, b, kvh, ns)

    qg = q.reshape(m, b, kvh, g, hd)
    kv_len = kv_len.reshape(m, b, 1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, ns=ns, bs=bs, hd=hd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1), lambda mi, bi, ki, si: (mi, bi, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, hd), lambda mi, bi, ki, si: (mi, bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, 1, hd), lambda mi, bi, ki, si: (mi, bi, si, ki, 0)),
            pl.BlockSpec((1, 1, bs, 1, hd), lambda mi, bi, ki, si: (mi, bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, g, hd), lambda mi, bi, ki, si: (mi, bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b, kvh, g, hd), q.dtype),
        scratch_shapes=[
            _vmem((g, 1), jnp.float32),
            _vmem((g, 1), jnp.float32),
            _vmem((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(m, b, h, hd)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def decode_attention_sharded(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_len: jax.Array,
    *,
    rules,
    **kw,
) -> jax.Array:
    """``decode_attention`` under ``shard_map`` on the rules' mesh.

    Serving layout: (M, B) rides the data axes and KV-head groups ride
    "model" — the head-grouping recipe is ``tp_head_plan`` (shared with
    the decode-layer megakernel's shard_map variant).  "kv": each rank
    owns KVH/n kv heads plus their grouped q heads end-to-end (q heads
    are laid out kvh-major, so a contiguous H-split of KVH/n groups
    matches a contiguous KVH-split).  "expand" (GQA/MQA where the kv
    heads don't split): KV is expanded to one head per q head, so any
    H-split works — per-rank KV bytes go kvh*hd -> (h/n)*hd, still a
    strict reduction whenever n > g.  Exact with no collectives; falls
    back to the plain (GSPMD-partitioned) call only when the q heads
    themselves can't split.
    """
    from repro.kernels.decode_layer import tp_head_plan
    from repro.launch.compat import shard_map

    m, b, h, hd = q.shape
    s, kvh = k.shape[2], k.shape[3]
    n_model = rules._axis_size(rules.mapping.get("kv_heads"))
    plan = tp_head_plan(h, kvh, n_model)
    if plan is None:
        # q heads can't split — run data-local (heads replicated over
        # "model").  A bare pallas_call under GSPMD is not safe here:
        # the partitioner splits the (M, B) grid while the kernel
        # indexes the scalar-prefetched kv_len with global program ids
        q_rep = rules.spec(("instances", "batch", None, None), q.shape)
        kv_rep = rules.spec(("instances", "batch", None, None, None), k.shape)
        len_spec = rules.spec(("instances", "batch"), (m, b))
        return shard_map(
            lambda ql, kl, vl, ll: decode_attention(ql, kl, vl, ll, **kw),
            mesh=rules.mesh,
            in_specs=(q_rep, kv_rep, kv_rep, len_spec),
            out_specs=q_rep,
            check_vma=False,
        )(q, k, v, kv_len)
    if plan == "expand":
        g = h // kvh
        k = jnp.repeat(k, g, axis=3)
        v = jnp.repeat(v, g, axis=3)

    q_spec = rules.spec(("instances", "batch", "kv_heads", None), (m, b, h, hd))
    kv_spec = rules.spec(
        ("instances", "batch", None, "kv_heads", None), k.shape
    )
    len_spec = rules.spec(("instances", "batch"), (m, b))
    return shard_map(
        lambda ql, kl, vl, ll: decode_attention(ql, kl, vl, ll, **kw),
        mesh=rules.mesh,
        in_specs=(q_spec, kv_spec, kv_spec, len_spec),
        out_specs=q_spec,
        check_vma=False,
    )(q, k, v, kv_len)
