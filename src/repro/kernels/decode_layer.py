"""Pallas TPU megakernel: ONE ``pallas_call`` per dense decode layer.

The serving decode step previously lowered every layer to ~8 separate
kernels (3 QKV matmuls, rope, flash attention, out-proj, 2 norms +
SwiGLU) plus a cache scatter.  Here the whole layer runs fused over the
(M, B) grid — one grid cell per lane, the lane's layer weights resident
in VMEM for the duration:

  rms(attn_norm) -> QKV (+bias) -> RoPE -> in-kernel ring append
  -> flash decode attention over the ring cache -> out-proj -> residual
  -> rms(mlp_norm) -> SwiGLU -> residual

Positions are scalar-prefetched per lane (the ``chunk_prefill_attn``
offset machinery): RoPE angles, the ring slot (pos % S) and the
slot-validity mask are all derived in-kernel from ``pos[m, b]`` alone —
mirroring ``layers.cache_slot_positions`` — so no position arrays are
staged.  The KV append happens in-kernel via ``input_output_aliases``
on the cache operands: the (S, KVH, hd) block is already in VMEM for
attention, so the append is a vector select into the aliased output and
the separate per-step cache scatter disappears.

A second small kernel (``logits_sample``) fuses final-norm + logits
projection + greedy argmax, blocked over the vocab with a running
(max, argmax) carried in VMEM scratch — a steady-state decode scan step
is ~``num_layers + 1`` launches.

Sharded variants (``*_sharded``) run under ``shard_map`` consistent with
``decode_attention_sharded``: (M, B) lanes ride the data axes, kv-head
groups / mlp slices ride "model" (the shared ``tp_head_plan`` recipe).
The mid-layer reduction (out-proj over sharded heads, down-proj over the
sharded ffn) cannot live inside one kernel, so the layer splits into an
attention-phase kernel and an FFN-phase kernel with a psum after each —
2 launches + 2 collectives per layer per rank.

Everything is validated with ``interpret=True`` on CPU (see ops.py); at
smoke/serving shapes the per-lane weights fit VMEM outright — see
DESIGN.md §6.7 for the VMEM budget per block shape and the ff/V blocking
a full-size TPU variant needs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def tp_head_plan(h: int, kvh: int, n_model: int) -> str | None:
    """ONE tensor-parallel head-grouping recipe, shared by
    ``decode_attention_sharded`` and the megakernel's shard_map variant.

    q heads are laid out kvh-major, so a contiguous H-split into
    ``n_model`` groups always keeps a q head on the same rank as its kv
    head.  Returns ``"kv"`` when the kv heads split evenly over the
    model axis, ``"expand"`` when they don't (GQA/MQA with kvh <
    n_model or non-dividing: expand KV to q heads — per-rank bytes go
    kvh*hd -> h_l*hd, a win since h_l*n_model = h = g*kvh >= kvh), or
    ``None`` when the q heads themselves can't split.
    """
    if n_model <= 1 or h % n_model:
        return None
    return "kv" if kvh % n_model == 0 else "expand"


# ---------------------------------------------------------------------------
# in-kernel subroutines (shared between the phase variants)
# ---------------------------------------------------------------------------


def _rms(x, scale, eps):
    """rms_norm on a (1, D) row — f32 stats, result cast back, exactly
    ``layers.rms_norm``."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _rope_rows(x, pos, theta):
    """RoPE on (H, hd) head rows at one scalar position — mirrors
    ``layers.rope`` (f32 angles, cos/sin cast to x.dtype)."""
    hd = x.shape[-1]
    half = hd // 2
    i = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
    freqs = jnp.exp(-math.log(theta) * i / half)
    ang = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = x[:, :half], x[:, half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _ring_valid(pos, s_cache, window):
    """(1, S) bool mask of ring slots valid AFTER writing ``pos`` at slot
    ``pos % S`` — the in-kernel form of ``cache_slot_positions`` plus the
    flash mask (validity + sliding window; causality is implied, every
    live slot position is <= pos)."""
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, s_cache), 1)
    cur = pos % s_cache
    base = pos - cur
    p = jnp.where(slots <= cur, base + slots, base - s_cache + slots)
    valid = p >= 0
    if window > 0:
        valid = valid & (pos - p < window)
    return valid


def _attend(qh, k_cache, v_cache, valid, *, kvh, g, hd, out_dtype):
    """Flash decode attention over the full in-VMEM cache block: one
    softmax per kv head — the einsum contraction structure mirrors the
    unfused Sq=1 ``_flash_body`` (single KV block, kvh as a batch dim)
    so the f32 reduction order matches op-for-op."""
    scale = 1.0 / math.sqrt(hd)
    # dummy (m=1, b=1, q=1) dims so the einsum SPECS — and with them
    # XLA's degenerate-dim lowering and f32 reduction order — are the
    # unfused path's, character for character (g=1 einsums otherwise
    # lower to a gemv with a different accumulation order)
    qg = qh.reshape(1, 1, 1, kvh, g, hd)
    kb = k_cache[None, None]                                # (1,1,S,KVH,hd)
    vb = v_cache[None, None]
    s = jnp.einsum("mbqkgd,mbckd->mbkgqc", qg, kb,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None], s, NEG_INF)
    mx = s.max(axis=-1)                                     # (1,1,KVH,G,1)
    p = jnp.exp(s - mx[..., None])
    l = p.sum(axis=-1)
    pv = jnp.einsum("mbkgqc,mbckd->mbkgqd", p.astype(vb.dtype), vb,
                    preferred_element_type=jnp.float32)
    o = (pv / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)
    return o.reshape(1, kvh * g * hd)                       # kvh-major


# ---------------------------------------------------------------------------
# the decode-layer kernel (phases: "full" = whole layer, "attn" = the
# pre-psum half used by the sharded variant)
# ---------------------------------------------------------------------------


def _layer_kernel(pos_ref, *refs, h, kvh, hd, eps, theta, window, has_bias,
                  phase):
    refs = list(refs)
    x_ref, an_ref, wq_ref, wk_ref, wv_ref = refs[:5]
    del refs[:5]
    if has_bias:
        bq_ref, bk_ref, bv_ref = refs[:3]
        del refs[:3]
    wo_ref = refs.pop(0)
    if phase == "full":
        mn_ref, wg_ref, wu_ref, wd_ref = refs[:4]
        del refs[:4]
    ck_ref, cv_ref, out_ref, ko_ref, vo_ref = refs

    mi, bi = pl.program_id(0), pl.program_id(1)
    pos = pos_ref[mi, bi]
    s_cache = ck_ref.shape[2]
    g = h // kvh
    x = x_ref[0]                                            # (1, D)

    n = _rms(x, an_ref[...], eps)
    q = jnp.dot(n, wq_ref[0])                               # (1, H*hd)
    k = jnp.dot(n, wk_ref[0])
    v = jnp.dot(n, wv_ref[0])
    if has_bias:
        q = q + bq_ref[...].astype(q.dtype)
        k = k + bk_ref[...].astype(k.dtype)
        v = v + bv_ref[...].astype(v.dtype)
    qh = q.reshape(h, hd)
    kh = k.reshape(kvh, hd)
    vh = v.reshape(kvh, hd)
    if theta > 0:
        qh = _rope_rows(qh, pos, theta)
        kh = _rope_rows(kh, pos, theta)

    # in-kernel ring append: the cache block is in VMEM (aliased with the
    # output) so the slot write is a vector select, not a scatter
    slot = pos % s_cache
    sl = jax.lax.broadcasted_iota(jnp.int32, (s_cache, 1, 1), 0)
    k_cache = jnp.where(sl == slot, kh[None].astype(ck_ref.dtype), ck_ref[0, 0])
    v_cache = jnp.where(sl == slot, vh[None].astype(cv_ref.dtype), cv_ref[0, 0])
    ko_ref[0, 0] = k_cache
    vo_ref[0, 0] = v_cache

    valid = _ring_valid(pos, s_cache, window)
    o = _attend(qh, k_cache, v_cache, valid, kvh=kvh, g=g, hd=hd,
                out_dtype=x.dtype)
    attn = jnp.dot(o, wo_ref[0])                            # (1, D)
    if phase == "attn":
        out_ref[0] = attn                                   # pre-psum partial
        return
    x2 = x + attn
    n2 = _rms(x2, mn_ref[...], eps)
    hm = jax.nn.silu(jnp.dot(n2, wg_ref[0])) * jnp.dot(n2, wu_ref[0])
    out_ref[0] = x2 + jnp.dot(hm, wd_ref[0])


def _ffn_kernel(x_ref, mn_ref, wg_ref, wu_ref, wd_ref, o_ref, *, eps):
    """FFN phase of the sharded variant: rms(mlp_norm) + SwiGLU over the
    rank-local ff slice; the down-proj output is a pre-psum partial."""
    x = x_ref[0]
    n2 = _rms(x, mn_ref[...], eps)
    hm = jax.nn.silu(jnp.dot(n2, wg_ref[0])) * jnp.dot(n2, wu_ref[0])
    o_ref[0] = jnp.dot(hm, wd_ref[0])


def _layer_call(lp, x, ck, cv, pos, *, num_heads, head_dim, rope_theta,
                window, eps, interpret, phase):
    m, b, d = x.shape
    s_cache, kvh = ck.shape[2], ck.shape[3]
    h, hd = num_heads, head_dim
    has_bias = "bq" in lp

    row = lambda mi, bi, pr: (mi, 0)
    mat = lambda mi, bi, pr: (mi, 0, 0)
    lane3 = lambda mi, bi, pr: (mi, bi, 0)
    lane5 = lambda mi, bi, pr: (mi, bi, 0, 0, 0)
    cache_spec = pl.BlockSpec((1, 1, s_cache, kvh, hd), lane5)

    in_specs = [
        pl.BlockSpec((1, 1, d), lane3),
        pl.BlockSpec((1, d), row),
        pl.BlockSpec((1, d, h * hd), mat),
        pl.BlockSpec((1, d, kvh * hd), mat),
        pl.BlockSpec((1, d, kvh * hd), mat),
    ]
    ops = [x, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"]]
    if has_bias:
        in_specs += [
            pl.BlockSpec((1, h * hd), row),
            pl.BlockSpec((1, kvh * hd), row),
            pl.BlockSpec((1, kvh * hd), row),
        ]
        ops += [lp["bq"], lp["bk"], lp["bv"]]
    in_specs.append(pl.BlockSpec((1, h * hd, d), mat))
    ops.append(lp["wo"])
    if phase == "full":
        ff = lp["w_gate"].shape[2]
        in_specs += [
            pl.BlockSpec((1, d), row),
            pl.BlockSpec((1, d, ff), mat),
            pl.BlockSpec((1, d, ff), mat),
            pl.BlockSpec((1, ff, d), mat),
        ]
        ops += [lp["mlp_norm"], lp["w_gate"], lp["w_up"], lp["w_down"]]
    in_specs += [cache_spec, cache_spec]
    ops += [ck, cv]

    # alias the cache operands with the cache outputs (indices count the
    # scalar-prefetch operand): the append is in place, no HBM round-trip
    n_in = 1 + len(ops)
    out, k_out, v_out = pl.pallas_call(
        functools.partial(
            _layer_kernel, h=h, kvh=kvh, hd=hd, eps=eps, theta=rope_theta,
            window=window, has_bias=has_bias, phase=phase,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m, b),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, d), lane3),
                cache_spec,
                cache_spec,
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((m, b, d), x.dtype),
            jax.ShapeDtypeStruct(ck.shape, ck.dtype),
            jax.ShapeDtypeStruct(cv.shape, cv.dtype),
        ],
        input_output_aliases={n_in - 2: 1, n_in - 1: 2},
        interpret=interpret,
    )(pos.astype(jnp.int32), *ops)
    return out, k_out, v_out


@functools.partial(jax.jit, static_argnames=(
    "num_heads", "head_dim", "rope_theta", "window", "eps", "interpret"))
def decode_layer(lp, x, ck, cv, pos, *, num_heads, head_dim, rope_theta,
                 window: int = 0, eps: float = 1e-5, interpret: bool = True):
    """One fused dense decode layer for the whole (M, B) grid.

    lp: the dense layer param dict (attn_norm, wq/wk/wv[+bq/bk/bv], wo,
    mlp_norm, w_gate/w_up/w_down — leading M axis).  x: (M, B, D)
    residual stream for the single decode position; ck/cv:
    (M, B, S, KVH, hd) ring cache BEFORE this token; pos: (M, B) int32
    absolute positions.  Returns (x_out, k_out, v_out) with the new
    token's K/V appended at slot ``pos % S``.
    """
    return _layer_call(
        lp, x, ck, cv, pos, num_heads=num_heads, head_dim=head_dim,
        rope_theta=rope_theta, window=window, eps=eps, interpret=interpret,
        phase="full",
    )


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _ffn_call(x, mlp_norm, w_gate, w_up, w_down, *, eps, interpret):
    m, b, d = x.shape
    ff = w_gate.shape[2]
    row = lambda mi, bi: (mi, 0)
    mat = lambda mi, bi: (mi, 0, 0)
    lane3 = lambda mi, bi: (mi, bi, 0)
    return pl.pallas_call(
        functools.partial(_ffn_kernel, eps=eps),
        grid=(m, b),
        in_specs=[
            pl.BlockSpec((1, 1, d), lane3),
            pl.BlockSpec((1, d), row),
            pl.BlockSpec((1, d, ff), mat),
            pl.BlockSpec((1, d, ff), mat),
            pl.BlockSpec((1, ff, d), mat),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lane3),
        out_shape=jax.ShapeDtypeStruct((m, b, d), x.dtype),
        interpret=interpret,
    )(x, mlp_norm, w_gate, w_up, w_down)


def decode_layer_sharded(lp, x, ck, cv, pos, *, rules, num_heads, head_dim,
                         rope_theta, window: int = 0, eps: float = 1e-5,
                         **kw):
    """``decode_layer`` under ``shard_map``, consistent with
    ``decode_attention_sharded``: (M, B) lanes ride the data axes,
    kv-head groups and the ffn slice ride "model" (``tp_head_plan``).

    The out-proj contracts sharded heads and the down-proj contracts the
    sharded ffn, so the layer splits into the attention-phase kernel and
    the FFN-phase kernel with a psum after each — 2 launches + 2
    collectives per layer per rank.  MQA head expansion would change the
    cache-out shape, so non-dividing kv heads (and a non-dividing ffn)
    fall back to the unsharded megakernel.
    """
    from repro.launch.compat import shard_map

    m, b, d = x.shape
    kvh = ck.shape[3]
    h, hd = num_heads, head_dim
    ff = lp["w_gate"].shape[2]
    n_model = rules._axis_size(rules.mapping.get("kv_heads"))
    plan = tp_head_plan(h, kvh, n_model)
    x_spec = rules.spec(("instances", "batch", None), x.shape)
    pos_spec = rules.spec(("instances", "batch"), pos.shape)
    if plan != "kv" or ff % n_model:
        # no tensor-parallel split — but a bare pallas_call under GSPMD
        # is NOT safe: the partitioner splits the (M, B) grid while the
        # kernel indexes the scalar-prefetched pos with global program
        # ids.  Run data-local instead: lanes shard over the data axes,
        # weights/caches replicated over "model"
        rep = lambda a: rules.spec(
            ("instances",) + (None,) * (a.ndim - 1), a.shape)
        lp_specs = {kk: rep(a) for kk, a in lp.items()}
        return shard_map(
            lambda lp_l, x_l, ck_l, cv_l, pos_l: decode_layer(
                lp_l, x_l, ck_l, cv_l, pos_l, num_heads=num_heads,
                head_dim=hd, rope_theta=rope_theta, window=window, eps=eps,
                **kw),
            mesh=rules.mesh,
            in_specs=(lp_specs, x_spec, rep(ck), rep(cv), pos_spec),
            out_specs=(x_spec, rep(ck), rep(cv)),
            check_vma=False,
        )(dict(lp), x, ck, cv, pos)

    model_ax = rules.mapping.get("kv_heads")
    cache_spec = rules.spec(
        ("instances", "batch", None, "kv_heads", None), ck.shape)
    specs = {
        "attn_norm": rules.spec(("instances", None), lp["attn_norm"].shape),
        "wq": rules.spec(("instances", None, "heads_flat"), lp["wq"].shape),
        "wk": rules.spec(("instances", None, "kv_flat"), lp["wk"].shape),
        "wv": rules.spec(("instances", None, "kv_flat"), lp["wv"].shape),
        "wo": rules.spec(("instances", "heads_flat", None), lp["wo"].shape),
        "mlp_norm": rules.spec(("instances", None), lp["mlp_norm"].shape),
        "w_gate": rules.spec(("instances", None, "mlp"), lp["w_gate"].shape),
        "w_up": rules.spec(("instances", None, "mlp"), lp["w_up"].shape),
        "w_down": rules.spec(("instances", "mlp", None), lp["w_down"].shape),
    }
    if "bq" in lp:
        specs["bq"] = rules.spec(("instances", "heads_flat"), lp["bq"].shape)
        specs["bk"] = rules.spec(("instances", "kv_flat"), lp["bk"].shape)
        specs["bv"] = rules.spec(("instances", "kv_flat"), lp["bv"].shape)
    lp_in = {kk: lp[kk] for kk in specs}

    def body(lp_l, x_l, ck_l, cv_l, pos_l):
        h_l = lp_l["wq"].shape[2] // hd
        attn_part, nk, nv = _layer_call(
            lp_l, x_l, ck_l, cv_l, pos_l, num_heads=h_l, head_dim=hd,
            rope_theta=rope_theta, window=window, eps=eps, phase="attn",
            **kw)
        x2 = x_l + jax.lax.psum(attn_part, model_ax)
        down = _ffn_call(
            x2, lp_l["mlp_norm"], lp_l["w_gate"], lp_l["w_up"],
            lp_l["w_down"], eps=eps, **kw)
        return x2 + jax.lax.psum(down, model_ax), nk, nv

    return shard_map(
        body, mesh=rules.mesh,
        in_specs=({kk: specs[kk] for kk in lp_in}, x_spec, cache_spec,
                  cache_spec, pos_spec),
        out_specs=(x_spec, cache_spec, cache_spec),
        check_vma=False,
    )(lp_in, x, ck, cv, pos)


# ---------------------------------------------------------------------------
# fused final-norm + logits + greedy sampling
# ---------------------------------------------------------------------------


def _logits_kernel(x_ref, sc_ref, hd_ref, tok_ref, val_out_ref, val_ref,
                   idx_ref, *, eps, nv, bv):
    vi = pl.program_id(2)

    @pl.when(vi == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    n = _rms(x_ref[0], sc_ref[...], eps)                    # (1, D)
    logits = jnp.dot(n.astype(jnp.float32), hd_ref[0].astype(jnp.float32))
    bm = logits.max(axis=-1, keepdims=True)                 # (1, 1)
    ii = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    first = jnp.where(logits == bm, ii, jnp.int32(2**31 - 1)).min(
        axis=-1, keepdims=True)
    # strict > keeps the earliest block's max; within a block ``first``
    # is the earliest index — together exactly jnp.argmax tie-breaking
    take = bm > val_ref[...]
    idx_ref[...] = jnp.where(take, vi * bv + first, idx_ref[...])
    val_ref[...] = jnp.where(take, bm, val_ref[...])

    @pl.when(vi == nv - 1)
    def _done():
        tok_ref[0, 0] = idx_ref[0, 0]
        val_out_ref[0, 0] = val_ref[0, 0]


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "block_v", "interpret"))
def _logits_argmax_parts(x, scale, head, *, eps: float = 1e-5,
                         block_v: int = 2048, interpret: bool = True):
    """Returns (tok (M,B) int32, val (M,B) f32): the greedy argmax and
    its logit value (the value feeds the sharded cross-rank combine)."""
    m, b, d = x.shape
    v = head.shape[2]
    bv = _clamp(block_v, v)
    nv = v // bv
    tok, val = pl.pallas_call(
        functools.partial(_logits_kernel, eps=eps, nv=nv, bv=bv),
        grid=(m, b, nv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda mi, bi, vi: (mi, bi, 0)),
            pl.BlockSpec((1, d), lambda mi, bi, vi: (mi, 0)),
            pl.BlockSpec((1, d, bv), lambda mi, bi, vi: (mi, 0, vi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda mi, bi, vi: (mi, bi)),
            pl.BlockSpec((1, 1), lambda mi, bi, vi: (mi, bi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, b), jnp.int32),
            jax.ShapeDtypeStruct((m, b), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, scale, head)
    return tok, val


def logits_sample(x, scale, head, *, eps: float = 1e-5, **kw):
    """Fused final-norm + logits projection + greedy argmax.

    x: (M, B, D) post-layers residual; scale: (M, D) final-norm scale;
    head: (M, D, V) unembedding.  Returns (M, B) int32 greedy tokens —
    bit-identical tie-breaking with ``jnp.argmax`` over the f32 logits
    (greedy == top-1, so the temperature<=0 top-k sampler reduces to
    this; stochastic sampling stays on the XLA path).
    """
    tok, _ = _logits_argmax_parts(x, scale, head, eps=eps, **kw)
    return tok


def logits_sample_sharded(x, scale, head, *, rules, eps: float = 1e-5, **kw):
    """``logits_sample`` under shard_map: vocab slices ride "model", each
    rank computes its local (max, argmax) in the kernel, and a tiny
    all-gather picks the global first-occurrence argmax."""
    from repro.launch.compat import shard_map

    m, b, d = x.shape
    v = head.shape[2]
    ax = rules.mapping.get("vocab")
    n_model = rules._axis_size(ax)
    x_spec = rules.spec(("instances", "batch", None), x.shape)
    sc_spec = rules.spec(("instances", None), scale.shape)
    out_spec = rules.spec(("instances", "batch"), (m, b))
    if n_model <= 1 or v % n_model:
        # data-local fallback — a bare pallas_call under GSPMD splits
        # the grid out from under the kernel's program-id indexing
        head_rep = rules.spec(("instances", None, None), head.shape)
        return shard_map(
            lambda x_l, sc_l, hd_l: logits_sample(x_l, sc_l, hd_l, eps=eps,
                                                  **kw),
            mesh=rules.mesh,
            in_specs=(x_spec, sc_spec, head_rep),
            out_specs=out_spec, check_vma=False,
        )(x, scale, head)

    head_spec = rules.spec(("instances", None, "vocab"), head.shape)

    def body(x_l, sc_l, hd_l):
        tok_l, val_l = _logits_argmax_parts(x_l, sc_l, hd_l, eps=eps, **kw)
        base = jax.lax.axis_index(ax) * hd_l.shape[2]
        vals = jax.lax.all_gather(val_l, ax)                # (n, m_l, b_l)
        toks = jax.lax.all_gather(tok_l + base, ax)
        best = vals.max(axis=0)
        cand = jnp.where(vals == best, toks, jnp.int32(2**31 - 1))
        return cand.min(axis=0).astype(jnp.int32)

    return shard_map(
        body, mesh=rules.mesh,
        in_specs=(x_spec, sc_spec, head_spec),
        out_specs=out_spec, check_vma=False,
    )(x, scale, head)
