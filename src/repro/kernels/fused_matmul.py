"""Pallas TPU kernel: NetFuse merged (instance-batched) matmul.

The paper's hot spot: M fine-tuned instances each multiply their own
(B, D) activations with their own (D, F) weights.  At small per-instance
batch (the paper's serving regime, bs=1-8) a plain matmul wastes the
128x128 MXU; batching the instance dim into the grid keeps the systolic
array fed while preserving input-weight locality (instance m's tile only
ever meets instance m's weight tile).

Grid: (M, T/bt, F/bf, D/bd) — the K (=D) dimension is the innermost
grid axis and accumulates into a VMEM f32 scratch, written back once on
the last K step (standard Pallas matmul revisiting pattern).  Block
shapes default to MXU-aligned 128s and clamp to the problem size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] + b_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "block_d", "interpret")
)
def fused_matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    block_t: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """x: (M,T,D) @ w: (M,D,F) [+ b: (M,F)] -> (M,T,F).

    ``interpret=None`` auto-detects: compiled Mosaic on TPU, Pallas
    interpreter elsewhere (kernel bodies execute on CPU for tests)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, t, d = x.shape
    f = w.shape[2]
    bt, bf, bd = _clamp(block_t, t), _clamp(block_f, f), _clamp(block_d, d)
    nk = d // bd
    grid = (m, t // bt, f // bf, nk)

    x_spec = pl.BlockSpec((1, bt, bd), lambda mi, ti, fi, ki: (mi, ti, ki))
    w_spec = pl.BlockSpec((1, bd, bf), lambda mi, ti, fi, ki: (mi, ki, fi))
    o_spec = pl.BlockSpec((1, bt, bf), lambda mi, ti, fi, ki: (mi, ti, fi))

    if b is None:
        return pl.pallas_call(
            functools.partial(_kernel, nk=nk),
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, t, f), x.dtype),
            scratch_shapes=[pltpu_scratch(bt, bf)],
            interpret=interpret,
        )(x, w)
    b_spec = pl.BlockSpec((1, bf), lambda mi, ti, fi, ki: (mi, fi))
    return pl.pallas_call(
        functools.partial(_bias_kernel, nk=nk),
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, t, f), x.dtype),
        scratch_shapes=[pltpu_scratch(bt, bf)],
        interpret=interpret,
    )(x, w, b)


def pltpu_scratch(bt: int, bf: int):
    """f32 VMEM accumulator scratch (TPU memory space)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((bt, bf), jnp.float32)


def fused_matmul_sharded(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    rules,
    **kw,
) -> jax.Array:
    """``fused_matmul`` under ``shard_map`` on the rules' mesh.

    The (M, T, F) problem is embarrassingly parallel under the serving
    layout: instances (M) ride the data axes and output features (F —
    logical ``mlp``) ride "model", so each rank runs the Pallas kernel
    on its local (M_l, T, D) x (M_l, D, F_l) block — no collectives, and
    the interpret-mode fallback inside :func:`fused_matmul` is intact
    (the per-rank body is an ordinary pallas_call).  Dims that don't
    divide their mesh axes replicate via the rules' divisibility guard,
    so any shape is accepted.
    """
    from repro.launch.compat import shard_map

    m, t, d = x.shape
    f = w.shape[2]
    x_spec = rules.spec(("instances", None, None), (m, t, d))
    w_spec = rules.spec(("instances", None, "mlp"), (m, d, f))
    o_spec = rules.spec(("instances", None, "mlp"), (m, t, f))

    if b is None:
        return shard_map(
            lambda xl, wl: fused_matmul(xl, wl, **kw),
            mesh=rules.mesh, in_specs=(x_spec, w_spec), out_specs=o_spec,
            check_vma=False,
        )(x, w)
    b_spec = rules.spec(("instances", "mlp"), b.shape)
    return shard_map(
        lambda xl, wl, bl: fused_matmul(xl, wl, bl, **kw),
        mesh=rules.mesh, in_specs=(x_spec, w_spec, b_spec), out_specs=o_spec,
        check_vma=False,
    )(x, w, b)
