"""Pallas TPU kernel: merged per-instance RMS norm (the layer-norm ->
group-norm rule of the paper, instance-axis form).

Each grid step owns (1 instance, bt rows, full D): the normalization
reduction runs entirely in VMEM/VREGs (one row's D fits easily — D <=
8192 -> 32 KB f32), stats in f32, cast on write.  Grid: (M, T/bt).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)                 # (bt, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[0].astype(jnp.float32)[None, :]
    o_ref[0] = y.astype(o_ref.dtype)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "block_t", "interpret"))
def group_rms_norm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-5,
    block_t: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """x: (M,T,D), scale: (M,D) -> (M,T,D)."""
    m, t, d = x.shape
    bt = _clamp(block_t, t)
    grid = (m, t // bt)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda mi, ti: (mi, ti, 0)),
            pl.BlockSpec((1, d), lambda mi, ti: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda mi, ti: (mi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t, d), x.dtype),
        interpret=interpret,
    )(x, scale)
