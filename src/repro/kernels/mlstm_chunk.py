"""Pallas TPU kernel: chunkwise-parallel mLSTM sequence (xLSTM's matrix
memory) with the (C, n, m) state resident in VMEM across chunks.

Companion to kernels/slstm_cell.py (§Perf xlstm pair B): the lax.scan
formulation writes the (hd, hd) matrix memory to HBM at every chunk
boundary; here each grid program owns one (instance, head), carries the
state in VMEM scratch across the sequence-chunk grid axis (the
revisiting pattern), and streams q/k/v/gates in, h out.  The intra-chunk
part is the same masked-matmul form as repro.models.ssm._mlstm_chunk:

    b_t   = cumsum(lf);  g = cummax(li - b);  m_t = b + max(m0, g)
    D     = tril(exp(li_s + b_t - b_s - m_t))
    h     = [ (q k^T/√d · D) v + exp(b + m0 - m_t)·(q C0/√d) ] / denom
    C'    = exp(b_S + m0 - m_S)·C0 + (exp(li + b_S - b - m_S)·k)^T v

Grid: (M, H, S/cs).  Batch rides inside the block so every matmul is
(B·cs)-row MXU work.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, lf_ref, li_ref,
            hs_ref, cf_ref, nf_ref, mf_ref,
            c_s, n_s, m_s, *, cs: int, ns: int, hd: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        c_s[...] = jnp.zeros_like(c_s)
        n_s[...] = jnp.zeros_like(n_s)
        m_s[...] = jnp.full_like(m_s, -1e30)

    f32 = jnp.float32
    q = q_ref[0, :, 0].astype(f32)                   # (B, cs, hd)
    k = k_ref[0, :, 0].astype(f32)
    v = v_ref[0, :, 0].astype(f32)
    lf = lf_ref[0, :, 0].astype(f32)                 # (B, cs)
    li = li_ref[0, :, 0].astype(f32)

    C0 = c_s[...]                                    # (B, hd, hd) f32
    n0 = n_s[...]                                    # (B, hd)
    m0 = m_s[:, 0]                                   # (B,)

    b = jnp.cumsum(lf, axis=-1)                      # (B, cs)
    g = jax.lax.cummax(li - b, axis=1)
    mt = b + jnp.maximum(m0[:, None], g)             # (B, cs)
    a_inter = jnp.exp(b + m0[:, None] - mt)

    logD = li[:, None, :] - b[:, None, :] + b[:, :, None] - mt[:, :, None]
    tri = jnp.tril(jnp.ones((cs, cs), jnp.bool_))
    D = jnp.where(tri[None], jnp.exp(logD), 0.0)     # (B, cs_t, cs_s)

    scale = 1.0 / math.sqrt(hd)
    s_qk = jnp.einsum("btd,bsd->bts", q, k, preferred_element_type=f32) * scale
    w = s_qk * D
    num = jnp.einsum("bts,bsd->btd", w, v, preferred_element_type=f32)
    num = num + a_inter[..., None] * jnp.einsum(
        "btd,bde->bte", q, C0, preferred_element_type=f32) * scale
    den = w.sum(-1) + a_inter * jnp.einsum(
        "btd,bd->bt", q, n0, preferred_element_type=f32) * scale
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mt))[..., None]
    hs_ref[0, :, 0] = h.astype(hs_ref.dtype)         # (B, cs, hd)

    m_end = mt[:, -1]                                # (B,)
    w_end = jnp.exp(li + b[:, -1:] - b - m_end[:, None])   # (B, cs)
    decay0 = jnp.exp(b[:, -1] + m0 - m_end)
    c_s[...] = decay0[:, None, None] * C0 + jnp.einsum(
        "bs,bsd,bse->bde", w_end, k, v, preferred_element_type=f32)
    n_s[...] = decay0[:, None] * n0 + jnp.einsum(
        "bs,bsd->bd", w_end, k, preferred_element_type=f32)
    m_s[...] = m_end[:, None]

    @pl.when(si == ns - 1)
    def _done():
        cf_ref[0, :, 0] = c_s[...]
        nf_ref[0, :, 0] = n_s[...]
        mf_ref[0, :, 0] = m_s[:, 0]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise(
    q: jax.Array, k: jax.Array, v: jax.Array,
    lf: jax.Array, li: jax.Array,
    *, chunk: int = 128, interpret: bool = True,
):
    """Chunkwise mLSTM from zero state.

    q,k,v: (M,B,H,S,hd); lf,li: (M,B,H,S) f32 (log-forget / input-gate
    pre-activations).  Returns (h (M,B,H,S,hd) in q.dtype, final state
    (C (M,B,H,hd,hd) f32, n (M,B,H,hd) f32, m (M,B,H) f32)) — the same
    contract as repro.models.ssm.mlstm_sequence with state=None.
    """
    m, bb, hh, s, hd = q.shape
    cs = _clamp(chunk, s)
    ns = s // cs
    grid = (m, hh, ns)

    seq_spec = pl.BlockSpec((1, bb, 1, cs, hd), lambda mi, hi, si: (mi, 0, hi, si, 0))
    gate_spec = pl.BlockSpec((1, bb, 1, cs), lambda mi, hi, si: (mi, 0, hi, si))
    st_spec = lambda *tail: pl.BlockSpec(
        (1, bb, 1) + tail, lambda mi, hi, si: (mi, 0, hi) + (0,) * len(tail))

    out_shape = (
        jax.ShapeDtypeStruct((m, bb, hh, s, hd), q.dtype),
        jax.ShapeDtypeStruct((m, bb, hh, hd, hd), jnp.float32),
        jax.ShapeDtypeStruct((m, bb, hh, hd), jnp.float32),
        jax.ShapeDtypeStruct((m, bb, hh), jnp.float32),
    )
    hs, cf, nf, mf = pl.pallas_call(
        functools.partial(_kernel, cs=cs, ns=ns, hd=hd),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=[
            seq_spec,
            st_spec(hd, hd),
            st_spec(hd),
            pl.BlockSpec((1, bb, 1), lambda mi, hi, si: (mi, 0, hi)),
        ],
        out_shape=out_shape,
        scratch_shapes=[_vmem((bb, hd, hd)), _vmem((bb, hd)), _vmem((bb, 1))],
        interpret=interpret,
    )(q, k, v, lf.astype(jnp.float32), li.astype(jnp.float32))
    return hs, (cf, nf, mf)
