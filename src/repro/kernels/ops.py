"""jit'd dispatch wrappers for the Pallas kernels.

On this container (CPU) kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid step, validating BlockSpec indexing
and in-kernel math; on TPU (the target) set ``REPRO_PALLAS_INTERPRET=0``
(or pass interpret=False) to compile real Mosaic kernels.  ``use_pallas``
gates whether the model zoo routes through the kernels or the plain-XLA
reference path (default: reference — kernels are validated/benched
explicitly, and the dry-run rooflines stay pure-XLA so the §Perf kernel
deltas are attributable).
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.chunk_prefill_attn import (
    chunk_prefill_attention as _chunk_prefill_pl,
    chunk_prefill_attention_sharded as _chunk_prefill_sh,
)
from repro.kernels.decode_attn import decode_attention as _decode_attention_pl
from repro.kernels.decode_attn import decode_attention_sharded as _decode_attention_sh
from repro.kernels.decode_layer import decode_layer as _decode_layer_pl
from repro.kernels.decode_layer import decode_layer_sharded as _decode_layer_sh
from repro.kernels.decode_layer import logits_sample as _logits_sample_pl
from repro.kernels.decode_layer import logits_sample_sharded as _logits_sample_sh
from repro.kernels.fused_matmul import fused_matmul as _fused_matmul_pl
from repro.kernels.fused_matmul import fused_matmul_sharded as _fused_matmul_sh
from repro.kernels.group_norm import group_rms_norm as _group_rms_norm_pl
from repro.kernels.slstm_cell import slstm_cell as _slstm_cell_pl


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def fused_matmul(x, w, b=None, *, use_pallas: bool = True, rules=None, **kw):
    """``rules=`` (a models.common.Rules) runs the kernel under
    shard_map on the rules' mesh — instances data-parallel, output
    features tensor-parallel; see fused_matmul_sharded."""
    if not use_pallas:
        return ref.fused_matmul(x, w, b)
    if rules is not None:
        return _fused_matmul_sh(x, w, b, rules=rules, interpret=_interpret(), **kw)
    return _fused_matmul_pl(x, w, b, interpret=_interpret(), **kw)


def group_rms_norm(x, scale, *, eps: float = 1e-5, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.group_rms_norm(x, scale, eps)
    return _group_rms_norm_pl(x, scale, eps=eps, interpret=_interpret(), **kw)


def decode_attention(q, k, v, kv_len, *, use_pallas: bool = True, rules=None, **kw):
    """``rules=`` runs the kernel under shard_map — (M, B) data-parallel,
    kv-head groups tensor-parallel; see decode_attention_sharded."""
    if not use_pallas:
        return ref.decode_attention(q, k, v, kv_len)
    if rules is not None:
        return _decode_attention_sh(q, k, v, kv_len, rules=rules,
                                    interpret=_interpret(), **kw)
    return _decode_attention_pl(q, k, v, kv_len, interpret=_interpret(), **kw)


def decode_layer(lp, x, ck, cv, pos, *, num_heads, head_dim, rope_theta,
                 window: int = 0, eps: float = 1e-5, use_pallas: bool = True,
                 rules=None, **kw):
    """Fused dense decode layer — ONE pallas_call per layer over the
    (M, B) grid, KV append in-kernel (kernels/decode_layer.py).
    ``rules=`` runs the attention/FFN phase pair under shard_map —
    (M, B) data-parallel, head/ffn slices tensor-parallel."""
    if not use_pallas:
        return ref.decode_layer(
            lp, x, ck, cv, pos, num_heads=num_heads, head_dim=head_dim,
            rope_theta=rope_theta, window=window, eps=eps)
    if rules is not None:
        return _decode_layer_sh(
            lp, x, ck, cv, pos, rules=rules, num_heads=num_heads,
            head_dim=head_dim, rope_theta=rope_theta, window=window, eps=eps,
            interpret=_interpret(), **kw)
    return _decode_layer_pl(
        lp, x, ck, cv, pos, num_heads=num_heads, head_dim=head_dim,
        rope_theta=rope_theta, window=window, eps=eps,
        interpret=_interpret(), **kw)


def logits_sample(x, scale, head, *, eps: float = 1e-5,
                  use_pallas: bool = True, rules=None, **kw):
    """Fused final-norm + logits projection + greedy argmax
    (kernels/decode_layer.py).  ``rules=`` shards the vocab over "model"
    with a cross-rank argmax combine."""
    if not use_pallas:
        return ref.logits_sample(x, scale, head, eps=eps)
    if rules is not None:
        return _logits_sample_sh(x, scale, head, rules=rules, eps=eps,
                                 interpret=_interpret(), **kw)
    return _logits_sample_pl(x, scale, head, eps=eps,
                             interpret=_interpret(), **kw)


def chunk_prefill_attention(q, k, v, offset, *, s_cache: int, pin: int = 0,
                            window: int = 0, sink: int = 0,
                            use_pallas: bool = True, rules=None, **kw):
    """Chunked-prefill flash attention over [cache-before, chunk]
    (kernels/chunk_prefill_attn.py).  ``rules=`` runs the kernel under
    shard_map — (M, B) lanes data-parallel, kv-head groups
    tensor-parallel; see chunk_prefill_attention_sharded."""
    if not use_pallas:
        return ref.chunk_prefill_attention(
            q, k, v, offset, s_cache=s_cache, pin=pin, window=window, sink=sink)
    if rules is not None:
        return _chunk_prefill_sh(
            q, k, v, offset, rules=rules, s_cache=s_cache, pin=pin,
            window=window, sink=sink, interpret=_interpret(), **kw)
    return _chunk_prefill_pl(
        q, k, v, offset, s_cache=s_cache, pin=pin, window=window, sink=sink,
        interpret=_interpret(), **kw)


def slstm_cell(pre, r, state, *, num_heads: int, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.slstm_cell(pre, r, state, num_heads=num_heads)
    return _slstm_cell_pl(pre, r, state, num_heads=num_heads,
                          interpret=_interpret(), **kw)


def mlstm_chunkwise(q, k, v, lf, li, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.mlstm_chunkwise(q, k, v, lf, li, **kw)
    from repro.kernels.mlstm_chunk import mlstm_chunkwise as _pl
    return _pl(q, k, v, lf, li, interpret=_interpret(), **kw)
