"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth for the shape/dtype sweep tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import math


def fused_matmul(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """NetFuse merged matmul: x (M,T,D) @ w (M,D,F) [+ b (M,F)] -> (M,T,F).

    Accumulation in f32, result cast back to x.dtype."""
    y = jnp.einsum(
        "mtd,mdf->mtf", x, w.astype(x.dtype), preferred_element_type=jnp.float32
    )
    if b is not None:
        y = y + b.astype(jnp.float32)[:, None, :]
    return y.astype(x.dtype)


def group_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Merged per-instance RMS norm: x (M,T,D), scale (M,D)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)[:, None, :]
    return y.astype(x.dtype)


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array
) -> jax.Array:
    """Single-token GQA decode attention.

    q: (M,B,H,hd); k,v: (M,B,S,KVH,hd); kv_len: (M,B) int32 — number of
    valid cache slots (prefix-valid layout).  Returns (M,B,H,hd) in
    q.dtype; softmax/accumulation in f32."""
    m, b, h, hd = q.shape
    s, kvh = k.shape[2], k.shape[3]
    g = h // kvh
    qg = q.reshape(m, b, kvh, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("mbkgd,mbskd->mbkgs", qg, kf) / math.sqrt(hd)
    mask = jnp.arange(s)[None, None] < kv_len[..., None]       # (M,B,S)
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("mbkgs,mbskd->mbkgd", p, vf)
    return o.reshape(m, b, h, hd).astype(q.dtype)


def chunk_prefill_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, offset: jax.Array, *,
    s_cache: int, pin: int = 0, window: int = 0, sink: int = 0,
    causal: bool = True,
) -> jax.Array:
    """Chunked-prefill GQA attention over [cache-before, chunk].

    q: (M,B,C,H,hd); k,v: (M,B,s_cache + C,KVH,hd) — the pre-chunk cache
    (pinned-prefix ring layout, ``pin`` slots pinned) concatenated with
    the chunk's own k/v; offset: (M,B) int32 absolute position of the
    chunk's first token.  Masking is positional: ring validity via
    ``layers.cache_positions_after``, causality, sliding ``window`` with
    the first ``sink`` positions exempt.  Returns (M,B,C,H,hd) in
    q.dtype; softmax/accumulation in f32."""
    from repro.models import layers as L

    m, b, c, h, hd = q.shape
    kvh = k.shape[3]
    g = h // kvh
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)   # (M,B,C)
    before = L.cache_positions_after(offset - 1, s_cache, pin)
    kv_pos = jnp.concatenate([before, positions], axis=-1)           # (M,B,T)
    qg = q.reshape(m, b, c, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum(
        "mbckgd,mbskd->mbkgcs", qg, k.astype(jnp.float32)
    ) / math.sqrt(hd)                                                # (M,B,KVH,G,C,T)
    valid = kv_pos[:, :, None, :] >= 0                               # (M,B,1,T)
    if causal:
        valid = valid & (kv_pos[:, :, None, :] <= positions[..., None])
    if window > 0:
        in_win = positions[..., None] - kv_pos[:, :, None, :] < window
        if sink > 0:
            in_win = in_win | (kv_pos[:, :, None, :] < sink)
        valid = valid & in_win
    scores = jnp.where(valid[:, :, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("mbkgcs,mbskd->mbckgd", p, v.astype(jnp.float32))
    return o.reshape(m, b, c, h, hd).astype(q.dtype)


def slstm_cell(pre: jax.Array, r: jax.Array, state: tuple, *, num_heads: int):
    """sLSTM scan oracle (mirrors repro.models.ssm.slstm_block's step).

    pre: (M,B,S,4,D); r: (M,4,H,hd,hd); state: (c,n,h,m) each (M,B,D).
    Returns (hs (M,B,S,D) in h.dtype, (c,n,h,m))."""
    m, b, s, _, d = pre.shape
    hh = num_heads
    hd = d // hh
    c0, n0, h0, m0 = state
    rf = r.astype(jnp.float32)
    out_dtype = h0.dtype

    def step(carry, pre_t):
        c, n, h, mstab = carry
        hhd = h.astype(jnp.float32).reshape(m, b, hh, hd)
        rec = jnp.einsum("mbhd,mghde->mbghe", hhd, rf).reshape(m, b, 4, d)
        pre_f = pre_t.astype(jnp.float32)
        zt, it, ft, ot = [pre_f[:, :, j] + rec[:, :, j] for j in range(4)]
        lf = jax.nn.log_sigmoid(ft)
        mt = jnp.maximum(lf + mstab, it)
        fp = jnp.exp(lf + mstab - mt)
        ip = jnp.exp(it - mt)
        c_new = fp * c + ip * jnp.tanh(zt)
        n_new = fp * n + ip
        h_new = (jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)).astype(out_dtype)
        return (c_new, n_new, h_new, mt), h_new

    (c, n, h, mst), hs = jax.lax.scan(
        step, (c0.astype(jnp.float32), n0.astype(jnp.float32), h0, m0.astype(jnp.float32)),
        jnp.moveaxis(pre, 2, 0),
    )
    return jnp.moveaxis(hs, 0, 2), (c, n, h, mst)


def mlstm_chunkwise(q, k, v, lf, li, *, chunk: int = 64):
    """Chunkwise mLSTM oracle — delegates to the model's pure-jnp
    chunkwise scan (repro.models.ssm.mlstm_sequence), which tests already
    pin against the per-step recurrence."""
    from repro.models import ssm
    h, (c, n, m) = ssm.mlstm_sequence(q, k, v, lf, li, chunk=chunk)
    return h.astype(q.dtype), (c, n, m)


def decode_layer(lp, x, ck, cv, pos, *, num_heads, head_dim, rope_theta,
                 window: int = 0, eps: float = 1e-5):
    """Unfused dense decode layer — exactly the models/dense.py
    ``_attn_mlp`` decode semantics (rms -> QKV+rope -> ring append ->
    flash attention -> out-proj -> residual -> rms -> SwiGLU -> residual).

    x: (M,B,D) residual for the decode position; ck/cv: (M,B,S,KVH,hd)
    ring cache before the token; pos: (M,B) int32.  Returns
    (x_out (M,B,D), k_out, v_out)."""
    from repro.models import layers as L

    xs = x[:, :, None]                                       # (M,B,1,D)
    n = L.rms_norm(xs, lp["attn_norm"], eps)
    a, new_cache = L.gqa_attention(
        n, lp, num_heads=num_heads, num_kv_heads=ck.shape[3],
        head_dim=head_dim, rope_theta=rope_theta, positions=pos[..., None],
        window=window, cache=(ck, cv), decode_pos=pos,
    )
    xs = xs + a
    n = L.rms_norm(xs, lp["mlp_norm"], eps)
    xs = xs + L.swiglu_mlp(n, lp["w_gate"], lp["w_up"], lp["w_down"])
    return xs[:, :, 0], new_cache[0], new_cache[1]


def logits_sample(x, scale, head, *, eps: float = 1e-5):
    """Final-norm + f32 logits + greedy argmax: x (M,B,D), scale (M,D),
    head (M,D,V) -> (M,B) int32."""
    from repro.models import layers as L

    n = L.rms_norm(x[:, :, None], scale, eps)
    logits = L.unembed(n, head)[:, :, 0]
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
