"""Pallas TPU kernel: sLSTM cell — the full recurrent scan of one block.

§Perf xlstm pair B named this the next lever: the lax.scan formulation
round-trips the (c, n, h, m) state and ~10 gate intermediates through
HBM every timestep.  On TPU the natural shape is ONE kernel that owns
the whole sequence: the state lives in VMEM scratch across all S steps,
pre-activations stream in S-chunks, and only the h outputs stream back —
HBM traffic drops from O(S · 10 · B · D) residuals to the unavoidable
O(S · B · D) in/out streams.

Math (identical to repro.models.ssm.slstm_block's step, exponential
gating with the m-stabilizer):

    rec_g = h_{t-1} @ r_g          (per-head block-diagonal, g ∈ z,i,f,o)
    z,i,f,o = pre_t[g] + rec_g
    lf = log_sigmoid(f);  m_t = max(lf + m_{t-1}, i)
    c_t = exp(lf + m_{t-1} - m_t) · c + exp(i - m_t) · tanh(z)
    n_t = exp(lf + m_{t-1} - m_t) · n + exp(i - m_t)
    h_t = sigmoid(o) · c_t / max(n_t, 1e-6)

Grid: (M, H, S/cs) — instances × heads × sequence chunks.  Heads are
independent (block-diagonal recurrence), so each program owns one
(instance, head) and carries (c, n, h, m) ∈ (B, hd) f32 scratch across
the S-axis grid steps (the same revisiting pattern as the fused-matmul
K axis).  The per-step recurrent matvec batches over B into a
(B, hd)x(hd, hd) MXU matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pre_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            hs_ref, cf_ref, nf_ref, hf_ref, mf_ref,
            c_s, n_s, h_s, m_s, *, cs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        c_s[...] = c0_ref[0, :, 0].astype(jnp.float32)
        n_s[...] = n0_ref[0, :, 0].astype(jnp.float32)
        h_s[...] = h0_ref[0, :, 0].astype(jnp.float32)
        m_s[...] = m0_ref[0, :, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)           # (4, hd, hd)

    def step(t, _):
        pre_t = pre_ref[0, :, t, :, 0].astype(jnp.float32)  # (B, 4, hd)
        h_prev = h_s[...]                             # (B, hd) f32
        rec = jax.lax.dot_general(
            h_prev, r,                                 # (B,hd) x (4,hd,hd)
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # (B, 4, hd)
        zt = pre_t[:, 0] + rec[:, 0]
        it = pre_t[:, 1] + rec[:, 1]
        ft = pre_t[:, 2] + rec[:, 2]
        ot = pre_t[:, 3] + rec[:, 3]
        lf = jax.nn.log_sigmoid(ft)
        mt = jnp.maximum(lf + m_s[...], it)
        fp = jnp.exp(lf + m_s[...] - mt)
        ip = jnp.exp(it - mt)
        c_new = fp * c_s[...] + ip * jnp.tanh(zt)
        n_new = fp * n_s[...] + ip
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        c_s[...], n_s[...], m_s[...] = c_new, n_new, mt
        h_s[...] = h_new
        hs_ref[0, :, t, 0, :] = h_new.astype(hs_ref.dtype)
        return 0

    jax.lax.fori_loop(0, cs, step, 0)

    @pl.when(si == ns - 1)
    def _done():
        cf_ref[0, :, 0] = c_s[...]
        nf_ref[0, :, 0] = n_s[...]
        hf_ref[0, :, 0] = h_s[...].astype(hf_ref.dtype)
        mf_ref[0, :, 0] = m_s[...]


def _vmem(b: int, hd: int):
    """(B, hd) f32 VMEM state scratch."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((b, hd), jnp.float32)


def _clamp(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("num_heads", "chunk", "interpret"))
def slstm_cell(
    pre: jax.Array,
    r: jax.Array,
    state: tuple,
    *,
    num_heads: int,
    chunk: int = 256,
    interpret: bool = True,
):
    """Full sLSTM scan.

    pre: (M, B, S, 4, D) gate pre-activations (x-side, any float dtype);
    r: (M, 4, H, hd, hd) recurrent weights; state: (c, n, h, m) each
    (M, B, D) — c/n/m f32, h in storage dtype.  Returns
    (hs (M, B, S, D) in h.dtype, new state).
    """
    m, b, s, four, d = pre.shape
    assert four == 4
    hh = num_heads
    hd = d // hh
    c0, n0, h0, m0 = state
    cs = _clamp(chunk, s)
    ns = s // cs
    grid = (m, hh, ns)

    # head-major layouts: (M, B, S, 4, H, hd) pre; (M, B, H, hd) state
    pre_h = pre.reshape(m, b, s, 4, hh, hd)
    st = lambda x: x.reshape(m, b, hh, hd)

    out_shape = (
        jax.ShapeDtypeStruct((m, b, s, hh, hd), h0.dtype),   # hs
        jax.ShapeDtypeStruct((m, b, hh, hd), jnp.float32),   # c
        jax.ShapeDtypeStruct((m, b, hh, hd), jnp.float32),   # n
        jax.ShapeDtypeStruct((m, b, hh, hd), h0.dtype),      # h
        jax.ShapeDtypeStruct((m, b, hh, hd), jnp.float32),   # m
    )
    state_spec = pl.BlockSpec((1, b, 1, hd), lambda mi, hi, si: (mi, 0, hi, 0))
    hs, cf, nf, hf, mf = pl.pallas_call(
        functools.partial(_kernel, cs=cs, ns=ns),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b, cs, 4, 1, hd), lambda mi, hi, si: (mi, 0, si, 0, hi, 0)),
            pl.BlockSpec((1, 4, 1, hd, hd), lambda mi, hi, si: (mi, 0, hi, 0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, b, cs, 1, hd), lambda mi, hi, si: (mi, 0, si, hi, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_shape=out_shape,
        scratch_shapes=[_vmem(b, hd) for _ in range(4)],
        interpret=interpret,
    )(pre_h, r, st(c0), st(n0), st(h0), st(m0))

    unst = lambda x: x.reshape(m, b, d)
    return hs.reshape(m, b, s, d), (unst(cf), unst(nf), unst(hf), unst(mf))
