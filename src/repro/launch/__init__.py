# Launch layer: production meshes, sharding rules, dry-run, HLO roofline
# analysis, train/serve drivers.  NOTE: repro.launch.dryrun sets
# XLA_FLAGS for 512 host devices at import — import it only in dry-run
# entrypoints.
