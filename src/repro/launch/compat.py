"""Version shims over drifting JAX mesh APIs.

The repo's execution layers (dryrun / train / serve / the serving
engine) all activate a mesh with ``with set_mesh(mesh), rules:`` so that
``jax.lax.with_sharding_constraint`` calls carrying bare PartitionSpecs
(``models.common.constrain``) resolve against it.  The spelling of "make
this mesh current" has drifted across JAX releases:

* newest JAX exposes ``jax.set_mesh`` (setter AND context manager),
* a range of releases had ``jax.sharding.use_mesh`` (context manager),
* 0.4.x has neither — but ``Mesh`` itself is a context manager that
  installs the resource env ``with_sharding_constraint`` reads.

``set_mesh(mesh)`` below returns a context manager valid on all three.
``install()`` additionally polyfills ``jax.set_mesh`` when the running
JAX lacks it, so external callers (tests, notebooks) written against the
modern spelling keep working; it is invoked from ``repro/__init__``.

Only the CONTEXT-MANAGER form is supported by the fallback: always write
``with set_mesh(mesh):`` (never a bare ``set_mesh(mesh)`` statement).
"""
from __future__ import annotations

import contextlib
import os

import jax

_NATIVE_SET_MESH = getattr(jax, "set_mesh", None)
_USE_MESH = getattr(jax.sharding, "use_mesh", None)
try:
    _NATIVE_SHARD_MAP = jax.shard_map
except AttributeError:
    _NATIVE_SHARD_MAP = None


def set_mesh(mesh):
    """Context manager making ``mesh`` current, on any supported JAX."""
    if _NATIVE_SET_MESH is not None:
        return _NATIVE_SET_MESH(mesh)
    if _USE_MESH is not None:
        return _USE_MESH(mesh)
    return mesh  # 0.4.x: Mesh is itself a resource-env context manager


def _vma_spelled_shard_map(raw):
    """Adapt ``raw`` to the modern signature: the replication-check
    keyword was renamed check_rep -> check_vma, and some releases ship a
    top-level ``jax.shard_map`` that still spells it check_rep."""
    try:
        import inspect
        has_vma = "check_vma" in inspect.signature(raw).parameters
    except (TypeError, ValueError):  # pragma: no cover — C-accelerated sig
        has_vma = True
    if has_vma:
        return raw

    # keep the historical positional order — install() may put this over
    # jax.shard_map, where third-party callers pass positionally
    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return raw(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    return shard_map


if _NATIVE_SHARD_MAP is not None:
    shard_map = _vma_spelled_shard_map(_NATIVE_SHARD_MAP)
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    shard_map = _vma_spelled_shard_map(_exp_shard_map)


def install() -> None:
    """Polyfill the modern spellings onto the jax module when missing.

    Installed once from ``repro/__init__``; module-dict assignment wins
    over jax's deprecation ``__getattr__``, so ``jax.set_mesh`` /
    ``jax.shard_map`` call sites (the model zoo, the test-suite) run
    unmodified on every supported JAX.  Caveat: the ``jax.set_mesh``
    polyfill supports only the ``with jax.set_mesh(mesh):`` context
    form — a bare setter statement has no 0.4.x equivalent and would
    silently not install the mesh (see module docstring)."""
    if getattr(jax, "set_mesh", None) is None:
        jax.set_mesh = set_mesh
    if getattr(jax, "shard_map", None) is not shard_map:
        jax.shard_map = shard_map


@contextlib.contextmanager
def mesh_context(mesh, *ctxs):
    """Enter ``set_mesh(mesh)`` plus any extra context managers (Rules).

    ``mesh`` may be None (no-op — the single-device path), so callers
    can hold ONE code path for mesh-parametric and plain execution."""
    with contextlib.ExitStack() as stack:
        if mesh is not None:
            stack.enter_context(set_mesh(mesh))
        for c in ctxs:
            if c is not None:
                stack.enter_context(c)
        yield


def make_host_mesh(shape: tuple[int, ...] | None = None,
                   axes: tuple[str, ...] = ("data", "model")):
    """A ("data", "model") mesh over the visible devices.

    ``shape=None`` puts every device on the data axis (pure DP serving);
    pass an explicit (data, model) shape to split off tensor parallelism.
    """
    if shape is None:
        shape = (jax.device_count(),) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, axes)


def force_host_devices_from_argv(argv) -> int:
    """Apply a ``--devices N`` / ``--devices=N`` CLI flag as
    ``--xla_force_host_platform_device_count=N`` BEFORE the first jax
    backend init (the device count locks there; call this at script top,
    before any jax API that touches devices).  N <= 0 or a malformed
    value is left for argparse to reject later — XLA_FLAGS untouched.
    Returns the parsed count (0 if absent/disabled)."""
    n = 0
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--devices="):
            val = a.split("=", 1)[1]
        else:
            continue
        try:
            n = int(val)
        except ValueError:
            return 0
        break
    if n > 0:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}"
        )
    return max(n, 0)


def mesh_from_args(devices: int, mesh_shape: str | None):
    """Serving-mesh construction shared by launch/serve and serve_bench:
    ``mesh_shape`` is a "DxT" string ((data, model) split, e.g. "2x4"),
    ``devices`` a host-platform override already applied by
    :func:`force_host_devices_from_argv`.  Neither set (``devices <= 0``
    counts as unset, matching the flag parser) -> None (the engine's
    plain single-device path)."""
    if devices <= 0 and not mesh_shape:
        return None
    shape = (
        tuple(int(p) for p in mesh_shape.split("x")) if mesh_shape else None
    )
    return make_host_mesh(shape)
