import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the
device count at first init); 512 placeholder CPU devices back both the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh.  Nothing here
allocates model memory — params, caches and batches are
ShapeDtypeStructs end to end.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 1-pod baselines
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results are cached as JSON under results/dryrun/.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import SHAPES
from repro.configs import registry
from repro.launch import hlo_analysis
from repro.launch.compat import set_mesh
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, num_chips
from repro.launch.shardings import (
    batch_shardings, dp_train_rules, moe_dp_compute, moe_ep_shmap,
    moments_rules, replicated, serve_rules, train_rules, tree_shardings,
)
from repro.models.common import count_params
from repro.optim.adamw import OptState, adamw_update
from repro.train.loop import TrainState

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Gradient-accumulation microbatch counts for the train_4k dry-run.
# Production-realistic for the big architectures: bounds the live
# activation stack (saved remat carries + logits) per chip.
TRAIN_MICROBATCHES = {
    "deepseek-67b": 8,
    "internvl2-26b": 8,
    "qwen3-moe-30b-a3b": 4,
    "hymba-1.5b": 4,
    "xlstm-1.3b": 4,
    "granite-3-2b": 4,
    "tinyllama-1.1b": 2,
    "olmoe-1b-7b": 2,
}


# ---------------------------------------------------------------------------
# model FLOPs (6·N·D train / 2·N·D inference; N = active params)
# ---------------------------------------------------------------------------


def active_params(cfg, n_total: int) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    if cfg.family != "moe":
        return n_total
    per_expert = 3 * cfg.d_model * cfg.d_ff
    dense_part = n_total - cfg.num_layers * cfg.num_experts * per_expert * cfg.num_instances
    active = dense_part + cfg.num_layers * cfg.num_experts_per_tok * per_expert * cfg.num_instances
    return active


def model_flops(cfg, shape, n_total: int) -> float:
    n_act = active_params(cfg, n_total) / max(cfg.num_instances, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    tokens = shape.global_batch * 1  # decode: ONE new token
    return 2.0 * n_act * tokens


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def build_lowerable(cfg, shape, mesh, rules, *, opt_rules=None, micro_override=None):
    """Returns (fn, arg_specs, in_shardings).  opt_rules optionally shards
    optimizer moments differently from params (ZeRO-1 under dp rules)."""
    specs = api.input_specs(cfg, shape)
    params_abs = api.abstract_params(cfg)
    params_ax = api.axes(cfg)
    p_shard = tree_shardings(rules, params_ax, params_abs)

    if shape.kind == "train":
        mrules = opt_rules or rules
        opt_abs = OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_abs),
            nu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_abs),
        )
        opt_shard = OptState(
            step=replicated(rules),
            mu=tree_shardings(mrules, params_ax, opt_abs.mu),
            nu=tree_shardings(mrules, params_ax, opt_abs.nu),
        )
        state_abs = TrainState(params_abs, opt_abs)
        state_shard = TrainState(p_shard, opt_shard)
        b_shard = batch_shardings(rules, specs["batch"])

        micro = micro_override or TRAIN_MICROBATCHES.get(cfg.name, 1)

        def train_step(state, batch):
            params, opt = state

            def grads_of(b):
                return jax.value_and_grad(
                    lambda p: api.loss_fn(cfg, p, b), has_aux=True
                )(params)

            if micro > 1:
                def mb(i, carry):
                    lsum, gsum = carry
                    sub = jax.tree.map(
                        lambda x: x.reshape(
                            x.shape[0], micro, x.shape[1] // micro, *x.shape[2:]
                        )[:, i],
                        batch,
                    )
                    (l, _), g = grads_of(sub)
                    return lsum + l, jax.tree.map(jnp.add, gsum, g)

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                loss, grads = jax.lax.fori_loop(
                    0, micro, mb, (jnp.float32(0.0), zero)
                )
                loss = loss / micro
                grads = jax.tree.map(lambda g: g / micro, grads)
            else:
                (loss, _), grads = grads_of(batch)
            new_params, new_opt, om = adamw_update(grads, opt, params, lr=1e-4)
            return TrainState(new_params, new_opt), {"loss": loss, **om}

        return train_step, (state_abs, specs["batch"]), (state_shard, b_shard)

    if shape.kind == "prefill":
        b_shard = batch_shardings(rules, specs["batch"])

        def prefill_step(params, batch):
            return api.prefill(cfg, params, batch)

        return prefill_step, (params_abs, specs["batch"]), (p_shard, b_shard)

    # decode
    cache_abs = specs["cache"]
    cache_ax = api.cache_axes(cfg)
    c_shard = tree_shardings(rules, cache_ax, cache_abs)
    tok_shard = batch_shardings(rules, specs["tokens"])
    pos_shard = batch_shardings(rules, specs["pos"])

    def serve_step(params, cache, tokens, pos):
        return api.decode_step(cfg, params, cache, tokens, pos)

    return (
        serve_step,
        (params_abs, cache_abs, specs["tokens"], specs["pos"]),
        (p_shard, c_shard, tok_shard, pos_shard),
    )


# §Perf production defaults ("ship the winners"): models whose params fit
# replicated (≲3 B) train pure-DP with ZeRO-1 moments — the TP=16 Megatron
# collectives dominate them otherwise (hymba/xlstm iterations: ~10-20x on
# the dominant roofline term).  Opt back into TP with --tag _tp.
DP_TRAIN_ARCHS = {
    "tinyllama-1.1b", "qwen1.5-0.5b", "granite-3-2b", "hymba-1.5b",
    "xlstm-1.3b",
}


def rules_for(mesh, kind: str, tag: str, arch: str | None = None):
    """(rules, opt_rules) for a §Perf variant tag.  Tags:
      ""       production default (train: DP+ZeRO-1 for DP_TRAIN_ARCHS,
               else TP+SP+FSDP; serve: TP+SP+context-sharded caches;
               MoE train: DP-compute dispatch)
      "_tp"    force the TP+SP+FSDP train baseline
      "_dp"    force pure-DP train
      "_moeep" MoE train: force expert-parallel einsums (paper baseline)
      "_moedp" MoE: force DP-compute dispatch (train default; serve opt-in)
    """
    opt_rules = None
    micro = None
    want_dp = tag.startswith("_dp") or (
        not tag.startswith("_tp") and arch in DP_TRAIN_ARCHS
    )
    if kind == "train" and want_dp:
        rules, opt_rules = dp_train_rules(mesh), moments_rules(mesh)
        micro = 1   # batch shards over all 256+ chips; no accumulation needed
    elif kind == "train":
        rules = train_rules(mesh)
    else:
        rules = serve_rules(mesh)
    # MoE dispatch-buffer compute placement: weight-gather (DP-compute)
    # wins for training shapes (dispatched activations ~K*cf x token
    # bytes >> expert weights); EP wins for decode (1-token buffers <<
    # weights).  serve rules therefore stay EP unless _moedp is forced.
    if tag.startswith("_moeps") or not tag.startswith(("_moeep", "_moedp")):
        # §Perf A4: canonical EP (expert-window dispatch + token psum)
        # dominates GSPMD-EP and weight-gather for training AND serving
        # (ablated: olmoe prefill 9.9->8.35 s, qwen3 decode 37.4->35.9 ms).
        rules = moe_ep_shmap(rules)
    elif tag.startswith("_moedp"):
        rules = moe_dp_compute(rules)
    return rules, opt_rules, micro


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            num_instances: int = 1, force: bool = False, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    mesh_tag = "2pod" if multi_pod else "1pod"
    inst_tag = f"_m{num_instances}" if num_instances != 1 else ""
    out_path = RESULTS_DIR / f"{arch}_{shape_name}_{mesh_tag}{inst_tag}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "num_instances": num_instances, "ok": False,
    }
    if not registry.supported(arch, shape):
        rec["skipped"] = "unsupported (see DESIGN.md §4)"
        _write(out_path, rec)
        return rec

    t0 = time.perf_counter()
    try:
        cfg = registry.config_for_shape(arch, shape, num_instances=num_instances)
        if "_c128" in tag:    # §Perf knob: mLSTM chunk length
            cfg = cfg.with_(mlstm_chunk=128)
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules, opt_rules, micro = rules_for(mesh, shape.kind, tag, arch=arch)
        with set_mesh(mesh), rules:
            fn, args, in_sh = build_lowerable(
                cfg, shape, mesh, rules, opt_rules=opt_rules,
                micro_override=micro,
            )
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        chips = num_chips(mesh)
        n_total = count_params(api.abstract_params(cfg))
        txt = compiled.as_text()
        analysis = hlo_analysis.analyze_hlo_text(txt)
        terms = hlo_analysis.roofline_terms(
            analysis, chips=chips,
            peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW,
        )
        mf = model_flops(cfg, shape, n_total)
        # per-chip useful model flops for the useful-compute ratio
        mf_per_chip = mf / chips

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # pragma: no cover
            mem["error"] = str(e)

        xla_ca = {}
        try:
            ca = compiled.cost_analysis()
            xla_ca = {k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca}
        except Exception as e:  # pragma: no cover
            xla_ca["error"] = str(e)

        rec.update({
            "ok": True,
            "family": cfg.family,
            "chips": chips,
            "params_total": int(n_total),
            "params_active": int(active_params(cfg, n_total)),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "hlo": {k: analysis[k] for k in ("flops", "bytes", "collective_bytes")},
            "collectives": analysis["collectives"],
            "roofline": terms,
            "model_flops_global": mf,
            "model_flops_per_chip": mf_per_chip,
            "useful_compute_ratio": (
                mf_per_chip / analysis["flops"] if analysis["flops"] else None
            ),
            "memory_analysis": mem,
            "xla_cost_analysis_reference": xla_ca,
        })
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(registry.ASSIGNED), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true", help="all arch x shape pairs")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--num-instances", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="rules variant tag (e.g. _dp)")
    args = ap.parse_args()

    pairs = (
        [(a, s) for a in sorted(registry.ASSIGNED) for s in
         ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
        if args.all else [(args.arch, args.shape)]
    )
    for arch, shape in pairs:
        t0 = time.perf_counter()
        rec = run_one(
            arch, shape, multi_pod=args.multi_pod,
            num_instances=args.num_instances, force=args.force, tag=args.tag,
        )
        status = "OK " if rec.get("ok") else ("SKIP" if "skipped" in rec else "FAIL")
        extra = ""
        if rec.get("ok"):
            r = rec["roofline"]
            extra = (
                f"compute {r['t_compute_s']:.3e}s mem {r['t_memory_s']:.3e}s "
                f"coll {r['t_collective_s']:.3e}s -> {r['bottleneck']}"
            )
            # paper deliverable: print the compile artifacts' analyses
            print(f"  memory_analysis: {rec['memory_analysis']}")
            print(f"  cost_analysis(xla reference): {rec['xla_cost_analysis_reference']}")
        elif "error" in rec:
            extra = rec["error"][:200]
        print(f"[{status}] {arch} x {shape} ({'2pod' if args.multi_pod else '1pod'}) "
              f"{time.perf_counter()-t0:.1f}s {extra}", flush=True)


if __name__ == "__main__":
    main()
