"""HLO-text cost model for the roofline analysis.

Why not ``compiled.cost_analysis()``: XLA's built-in analysis counts a
``while`` body ONCE, so every ``lax.scan`` (layer stacks, flash-attention
KV streaming, SSM chunk scans) would be undercounted by its trip count —
verified empirically (scan of 8 matmuls reports the FLOPs of 1).  The
spec's collective-bytes accounting requires parsing the HLO text anyway,
so this module walks the optimized HLO and accounts:

* FLOPs — dots (2·prod(out)·prod(contracting)), convolutions, and
  1-flop-per-element for arithmetic elementwise/reduce ops; ops inside
  ``while`` bodies are multiplied by ``known_trip_count`` from XLA's
  backend_config.
* bytes — first-order HBM traffic, producer-side: each top-level
  (post-fusion) op writes its result once and that tensor is read ~once
  downstream (×2), so traffic ≈ 2·Σ result bytes + entry-parameter
  reads; in-place ops (dynamic-update-slice / scatter) count their
  UPDATE size, not the aliased full operand (XLA aliases these buffers).
  Fusion internals stay in registers/VMEM — only the fusion result
  counts.  ×trip inside loops.
* collective bytes — operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (×trip), with
  all-gather counted at result size (that's what moves on the wire).

The model is intentionally a first-order roofline tool, not a cycle
simulator; see EXPERIMENTS.md §Roofline "method" for its error bars.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt",
    "tanh", "logistic", "sine", "cosine", "maximum", "minimum", "abs",
    "negate", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "clamp", "and", "or",
    "xor", "not", "atan2", "cbrt", "erf", "remainder",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
}

_DATA_OPS = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "get-dimension-size", "optimization-barrier",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


# ---------------------------------------------------------------------------
# shape parsing
# ---------------------------------------------------------------------------


def _parse_shape(s: str):
    """'f32[128,64]{1,0}' -> ('f32', [128, 64]); tuples -> list of these."""
    s = s.strip()
    if s.startswith("("):
        # tuple type: split top-level commas
        inner = s[1:-1] if s.endswith(")") else s[1:]
        parts, depth, cur = [], 0, []
        for ch in inner:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            parts.append("".join(cur))
        return [(_parse_shape(p)) for p in parts if p.strip()]
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", s)
    if not m:
        return ("opaque", [])
    dt = m.group(1)
    dims = [int(x) for x in m.group(2).split(",") if x] if m.group(2) else []
    return (dt, dims)


def _nbytes(shape) -> int:
    if isinstance(shape, list):
        return sum(_nbytes(x) for x in shape)
    dt, dims = shape
    n = _DTYPE_BYTES.get(dt, 4)
    for d in dims:
        n *= d
    return n


def _nelems(shape) -> int:
    if isinstance(shape, list):
        return sum(_nelems(x) for x in shape)
    _, dims = shape
    n = 1
    for d in dims:
        n *= d
    return n


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    name: str
    shape: object
    opcode: str
    operands: list[str]
    attrs: str


_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")


def _split_type_rest(s: str):
    """'f32[1,2]{1,0} dot(%a, %b), attrs' -> (shape, opcode, operands, attrs)."""
    s = s.strip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = s[: i + 1], s[i + 1 :].strip()
    else:
        sp = s.index(" ")
        type_str, rest = s[:sp], s[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest, re.S)
    if not m:
        return _parse_shape(type_str), "unknown", [], rest
    opcode = m.group(1)
    tail = m.group(2)
    # operands end at the matching close paren
    depth, i = 1, 0
    while i < len(tail) and depth:
        if tail[i] == "(":
            depth += 1
        elif tail[i] == ")":
            depth -= 1
        i += 1
    operand_str, attrs = tail[: i - 1], tail[i:]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return _parse_shape(type_str), opcode, operands, attrs


def parse_hlo(text: str) -> dict[str, list[Op]]:
    """computation name -> ops."""
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    entry_name = None
    for line in text.splitlines():
        if line.startswith((" ", "\t")):
            m = _OP_RE.match(line)
            if m and cur is not None:
                shape, opcode, operands, attrs = _split_type_rest(m.group(2))
                cur.append(Op(m.group(1), shape, opcode, operands, attrs))
            continue
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name = m.group(1)
            cur = comps.setdefault(name, [])
            if line.lstrip().startswith("ENTRY"):
                entry_name = name
    comps["__entry__"] = comps.get(entry_name, [])
    return comps


# ---------------------------------------------------------------------------
# cost walk
# ---------------------------------------------------------------------------


def _called(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(attrs: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', attrs)
    return int(m.group(1)) if m else 1


def _dot_flops(op: Op, shapes: dict[str, object]) -> float:
    out = _nelems(op.shape)
    lhs = shapes.get(op.operands[0]) if op.operands else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contracted = 1
    if lhs is not None and not isinstance(lhs, list) and m and m.group(1):
        for d in m.group(1).split(","):
            i = int(d)
            if i < len(lhs[1]):
                contracted *= lhs[1][i]
    return 2.0 * out * contracted


def _conv_flops(op: Op, shapes: dict[str, object]) -> float:
    out = _nelems(op.shape)
    ker = shapes.get(op.operands[1]) if len(op.operands) > 1 else None
    if ker is None or isinstance(ker, list):
        return 2.0 * out
    kelems = 1
    for d in ker[1]:
        kelems *= d
    # kernel elems = spatial * Cin/g * Cout ; per output element work =
    # 2 * spatial * Cin/g = 2 * kelems / Cout. Find Cout via dim_labels.
    m = re.search(r"dim_labels=\w+_(\w+)->", op.attrs)
    cout = 1
    if m:
        lab = m.group(1)
        if "o" in lab:
            cout = ker[1][lab.index("o")]
    g = 1
    mg = re.search(r"feature_group_count=(\d+)", op.attrs)
    if mg:
        g = int(mg.group(1))
    return 2.0 * out * max(kelems / max(cout, 1), 1.0)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k, self.collective_bytes * k,
            {kk: v * k for kk, v in self.collectives.items()},
        )


def _result_bytes(op: Op, shapes: dict, comps: dict | None = None) -> float:
    """Producer-side traffic for one op (see module docstring)."""
    if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
        return 2.0 * _nbytes(shapes.get(op.operands[1], ("opaque", [])))
    if op.opcode == "scatter" and len(op.operands) >= 3:
        return 2.0 * _nbytes(shapes.get(op.operands[2], ("opaque", [])))
    if op.opcode == "fusion" and comps is not None:
        called = _called(op.attrs, "calls")
        inner = comps.get(called, [])
        if inner:
            in_shapes = {o.name: o.shape for o in inner}
            # XLA emits DUS/scatter fusions in place when the big operand
            # flows straight to the result (possibly through dtype
            # converts): only the update slice is written, the buffer is
            # aliased.  Detect: exactly one DUS/scatter, all other
            # non-data ops are converts.
            real = [o for o in inner if o.opcode not in _DATA_OPS]
            dus = [o for o in real if o.opcode == "dynamic-update-slice"]
            scat = [o for o in real if o.opcode == "scatter"]
            others = [
                o for o in real
                if o.opcode not in ("dynamic-update-slice", "scatter",
                                    "convert", "reduce-precision",
                                    # elementwise companions of an in-place
                                    # update (assoc-scan tree steps fuse a
                                    # select/arith into the DUS; XLA emits
                                    # them in place on the aliased buffer)
                                    "select", "multiply", "add", "exponential",
                                    "subtract", "maximum", "broadcast")
            ]
            if len(dus) == 1 and not scat and not others and len(dus[0].operands) >= 2:
                return 2.0 * _nbytes(in_shapes.get(dus[0].operands[1], ("opaque", [])))
            if len(scat) == 1 and not dus and not others and len(scat[0].operands) >= 3:
                return 2.0 * _nbytes(in_shapes.get(scat[0].operands[2], ("opaque", [])))
            # pure dtype-convert fusions are CPU-backend artifacts (oneDNN
            # wants f32); TPU computes bf16 natively without materializing
            # the converted buffer -> no HBM traffic.
            if real and all(o.opcode in ("convert", "reduce-precision") for o in real):
                return 0.0
    if op.opcode in ("convert", "reduce-precision"):
        return 0.0
    return 2.0 * _nbytes(op.shape)


def _comp_cost(
    comps: dict[str, list[Op]],
    name: str,
    *,
    bytes_mode: bool,
    memo: dict,
    is_entry: bool = False,
) -> Cost:
    key = (name, bytes_mode, is_entry)
    if key in memo:
        return memo[key]
    memo[key] = Cost()  # cycle guard
    total = Cost()
    shapes = {op.name: op.shape for op in comps.get(name, [])}
    for op in comps.get(name, []):
        c = Cost()
        oc = op.opcode
        if oc == "while":
            body = _called(op.attrs, "body")
            cond = _called(op.attrs, "condition")
            trip = _trip_count(op.attrs)
            sub = Cost()
            if body:
                sub += _comp_cost(comps, body, bytes_mode=bytes_mode, memo=memo)
            if cond:
                sub += _comp_cost(comps, cond, bytes_mode=bytes_mode, memo=memo)
            c = sub.scaled(trip)
        elif oc == "fusion":
            called = _called(op.attrs, "calls")
            if called:
                inner = _comp_cost(comps, called, bytes_mode=False, memo=memo)
                c.flops = inner.flops
                c.collective_bytes = inner.collective_bytes
                c.collectives = dict(inner.collectives)
            if bytes_mode:
                c.bytes = _result_bytes(op, shapes, comps)
        elif oc in ("call", "async-start", "async-done"):
            called = _called(op.attrs, "calls") or _called(op.attrs, "to_apply")
            if called:
                c = _comp_cost(comps, called, bytes_mode=bytes_mode, memo=memo)
        elif oc == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", op.attrs)
            names = []
            if branches:
                names = re.findall(r"%?([\w.\-]+)", branches[0])
            else:
                for kk in ("true_computation", "false_computation"):
                    n2 = _called(op.attrs, kk)
                    if n2:
                        names.append(n2)
            subs = [
                _comp_cost(comps, n2, bytes_mode=bytes_mode, memo=memo) for n2 in names
            ]
            if subs:
                c = max(subs, key=lambda s: s.flops)
        elif oc == "dot":
            c.flops = _dot_flops(op, shapes)
            if bytes_mode:
                c.bytes = _result_bytes(op, shapes)
        elif oc == "convolution":
            c.flops = _conv_flops(op, shapes)
            if bytes_mode:
                c.bytes = _result_bytes(op, shapes)
        elif oc in _COLLECTIVES:
            base = oc.replace("-start", "")
            if base == "all-gather":
                moved = _nbytes(op.shape)   # result moves on the wire
            else:
                moved = sum(_nbytes(shapes.get(o, ("opaque", []))) for o in op.operands)
            c.collective_bytes = moved
            c.collectives = {base: moved}
            if bytes_mode:
                c.bytes = moved
            called = _called(op.attrs, "to_apply")
            _ = called  # reduction computation cost negligible
        elif oc in _DATA_OPS:
            if bytes_mode and is_entry and oc == "parameter":
                c.bytes = _nbytes(op.shape)  # weights/caches read from HBM
        else:
            # arithmetic / reduce / softmax pieces / gathers etc.
            if oc in _ARITH_OPS or oc in ("reduce", "reduce-window", "map", "exponential"):
                c.flops = float(_nelems(op.shape))
                if oc in ("reduce", "reduce-window"):
                    c.flops = float(
                        sum(_nelems(shapes.get(o, ("opaque", []))) for o in op.operands[: max(1, len(op.operands) // 2)])
                    )
            elif oc == "sort":
                n = _nelems(op.shape)
                c.flops = n * max(1.0, math.log2(max(n, 2)))
            if bytes_mode and oc not in _DATA_OPS:
                c.bytes = _result_bytes(op, shapes)
        total += c
    memo[key] = total
    return total


def analyze_hlo_text(text: str) -> dict:
    comps = parse_hlo(text)
    memo: dict = {}
    cost = _comp_cost(comps, "__entry__", bytes_mode=True, memo=memo, is_entry=True)
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collectives": cost.collectives,
    }


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def roofline_terms(
    analysis: dict,
    *,
    chips: int,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> dict:
    """Per-chip roofline seconds.  The HLO analyzed is the SPMD program of
    ONE device (GSPMD partitions before backend compilation), so no
    division by `chips` on flops/bytes; collective bytes are per-device
    link traffic."""
    t_compute = analysis["flops"] / peak_flops
    t_memory = analysis["bytes"] / hbm_bw
    t_collective = analysis["collective_bytes"] / ici_bw
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": dom,
        "chips": chips,
    }


def breakdown_collectives(text: str, top: int = 25) -> list[tuple[str, float, int]]:
    """Top collective ops by total moved bytes (×trip-count), labelled with
    opcode, result shape and the jax op_name metadata — the 'profile' for
    collective-bound §Perf iterations.  Returns (label, bytes, count)."""
    comps = parse_hlo(text)
    acc: dict[str, list[float]] = {}

    def visit(name, mult):
        shapes = {op.name: op.shape for op in comps.get(name, [])}
        for op in comps.get(name, []):
            oc = op.opcode
            if oc == "while":
                trip = _trip_count(op.attrs)
                for key in ("body", "condition"):
                    c = _called(op.attrs, key)
                    if c:
                        visit(c, mult * trip)
            elif oc in ("call", "async-start", "async-done"):
                c = _called(op.attrs, "calls") or _called(op.attrs, "to_apply")
                if c:
                    visit(c, mult)
            elif oc == "fusion":
                c = _called(op.attrs, "calls")
                if c:
                    visit(c, mult)
            elif oc in _COLLECTIVES:
                base = oc.replace("-start", "")
                if base == "all-gather":
                    moved = _nbytes(op.shape)
                else:
                    moved = sum(
                        _nbytes(shapes.get(o, ("opaque", []))) for o in op.operands
                    )
                m = re.search(r'op_name="([^"]*)"', op.attrs)
                src = m.group(1)[-80:] if m else "?"
                label = f"{base} {str(op.shape)[:40]} <{src}>"
                e = acc.setdefault(label, [0.0, 0])
                e[0] += mult * moved
                e[1] += mult

    visit("__entry__", 1)
    rows = sorted(((k, v[0], int(v[1])) for k, v in acc.items()), key=lambda r: -r[1])
    return rows[:top]


def breakdown(text: str, top: int = 20) -> list[tuple[str, float, float]]:
    """Top (op-label, flops, bytes) contributors with trip multipliers —
    the dry-run 'profile' used by the §Perf iteration loop."""
    comps = parse_hlo(text)
    acc: dict[str, list[float]] = {}

    def walk(name, mult, is_entry=False):
        shapes = {op.name: op.shape for op in comps.get(name, [])}
        for op in comps.get(name, []):
            oc = op.opcode
            if oc == "while":
                trip = _trip_count(op.attrs)
                for key in ("body", "condition"):
                    c = _called(op.attrs, key)
                    if c:
                        walk(c, mult * trip)
            elif oc == "call":
                c = _called(op.attrs, "calls")
                if c:
                    walk(c, mult)
            elif oc in _DATA_OPS:
                if is_entry and oc == "parameter":
                    label = f"parameter {str(op.shape)[:48]}"
                    acc.setdefault(label, [0.0, 0.0])[1] += _nbytes(op.shape)
            else:
                fl = 0.0
                if oc == "dot":
                    fl = _dot_flops(op, shapes)
                elif oc == "fusion":
                    called = _called(op.attrs, "calls")
                    if called:
                        memo: dict = {}
                        fl = _comp_cost(comps, called, bytes_mode=False, memo=memo).flops
                by = _result_bytes(op, shapes, comps)
                label = f"{op.name.split('.')[0]} {str(op.shape)[:48]}"
                e = acc.setdefault(label, [0.0, 0.0])
                e[0] += mult * fl
                e[1] += mult * by

    walk("__entry__", 1, is_entry=True)
    rows = sorted(
        ((k, v[0], v[1]) for k, v in acc.items()), key=lambda r: -(r[2])
    )
    return rows[:top]
