"""Production meshes.

Target: TPU v5e pods; single pod = 16x16 (256 chips), multi-pod = 2 pods
= 512 chips with a leading "pod" axis.  A FUNCTION (not a module-level
constant) so importing never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax
init; everything else sees 1 CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
