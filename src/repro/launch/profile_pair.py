import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler for the §Perf loop: lower+compile ONE (arch × shape)
pair on the production mesh and print the top collective ops and the top
HBM-bytes ops from the optimized HLO.

  PYTHONPATH=src python -m repro.launch.profile_pair --arch qwen3-moe-30b-a3b \
      --shape train_4k [--tag _dp] [--multi-pod] [--num-instances 8]
"""
import argparse

import jax

from repro.configs.base import SHAPES
from repro.configs import registry
from repro.launch import hlo_analysis
from repro.launch import dryrun as D
from repro.launch.compat import set_mesh
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--num-instances", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    shape = SHAPES[args.shape]
    cfg = registry.config_for_shape(args.arch, shape, num_instances=args.num_instances)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules, opt_rules, micro = D.rules_for(mesh, shape.kind, args.tag, arch=args.arch)
    with set_mesh(mesh), rules:
        fn, fargs, in_sh = D.build_lowerable(
            cfg, shape, mesh, rules, opt_rules=opt_rules,
            micro_override=micro,
        )
        txt = jax.jit(fn, in_shardings=in_sh).lower(*fargs).compile().as_text()

    print(f"== {args.arch} x {args.shape} tag={args.tag!r} "
          f"m={args.num_instances} {'2pod' if args.multi_pod else '1pod'} ==")
    print("-- top collectives (moved bytes x trips) --")
    for label, by, cnt in hlo_analysis.breakdown_collectives(txt, args.top):
        print(f"  {by/1e9:11.2f} GB  x{cnt:<5d} {label}")
    print("-- top HBM-bytes ops --")
    for label, fl, by in hlo_analysis.breakdown(txt, args.top):
        print(f"  {by/1e9:11.2f} GB  {fl/1e12:8.2f} TF  {label}")


if __name__ == "__main__":
    main()
