"""Serving launcher: NetFuse-merged multi-model serving demo/driver.

Trains nothing — initializes (or restores) M fine-tuned instances,
merges them (the paper's offline merge step, timed), and serves batched
requests from per-instance queues through the fused decode.  Every
servable family works (dense / moe / vlm / audio / ssm / hybrid);
admission policy, sampling and the prefill chunk/budget are flags.
Per-instance throughput/latency/queue metrics are reported at the end.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \\
      --smoke --num-instances 4 --requests 32 --policy token-budget

Mesh-parametric serving: ``--devices N`` forces N host-platform devices
(must be consumed before jax initializes) and ``--mesh-shape DxT``
serves the (M, B) grid under a (data=D, model=T) mesh — slot surgery,
prefill, decode and sampling all run sharded (engine ``mesh=``).

Async frontend (DESIGN.md §6.4): ``--stream`` drives the same synthetic
workload through the ``AsyncEngine`` as concurrent clients, printing
tokens as each fused step lands; ``--http PORT`` serves the engine over
HTTP (OpenAI-style ``POST /v1/completions`` with SSE streaming, ``GET
/metrics``) until interrupted, then drains gracefully and prints the
metrics table (now including TTFT/ITL p50/p95/p99 tails).
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import time

# --devices must win before the first jax backend init (the device
# count locks there; importing jax below is still safe)
from repro.launch.compat import force_host_devices_from_argv, mesh_from_args

force_host_devices_from_argv(sys.argv)

import numpy as np
import jax

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import MultiModelServer, Request, SERVABLE_FAMILIES
from repro.serving.scheduler import POLICIES


def _supervise(engine, args):
    """Wrap the engine in a Supervisor when the run asked for fault
    tolerance (--fault-plan and/or --watchdog-ms); returns it or None."""
    if not (args.fault_plan or args.watchdog_ms > 0):
        return None
    from repro.serving import Supervisor

    sup = Supervisor(
        engine,
        watchdog_s=(args.watchdog_ms / 1e3) if args.watchdog_ms > 0 else None,
        max_restarts=args.max_restarts, seed=args.seed,
    )
    sup.start()
    return sup


def _print_obs(server) -> None:
    """End-of-run per-tenant attribution + SLO tables (DESIGN.md §6.9);
    silent when neither accounting nor an SLO config is active."""
    acct = server.accounting
    if acct.enabled or acct.settled_s > 0:
        print(acct.format_table())
    rep = server.metrics.slo_report()
    if rep.get("configured"):
        cfg = rep["config"]
        lines = [f"SLO (target {cfg['target']:.0%}"
                 + (f", ttft<={cfg['ttft_ms']:g}ms" if cfg["ttft_ms"] else "")
                 + (f", itl<={cfg['itl_ms']:g}ms" if cfg["itl_ms"] else "")
                 + ")"]
        for i, inst in enumerate(rep["instances"]):
            objs = "  ".join(
                f"{name}: {o['bad_frac']:.2%} bad, "
                f"burn {o['burn_rate']:.2f}, "
                f"budget {o['budget_remaining']:.0%}"
                for name, o in inst["objectives"].items())
            lines.append(f"  inst {i} [{inst['state']:>8}]  {objs}")
        print("\n".join(lines))


def _print_recovery(sup) -> None:
    if sup is None:
        return
    s = sup.snapshot()
    print(f"supervision: {s['driver_restarts']} restart(s), "
          f"{s['watchdog_timeouts']} watchdog timeout(s), "
          f"{s['request_retries']} request requeue(s), "
          f"{s['tokens_replayed']} token(s) replayed"
          + (f", last recovery {s['last_recovery_s'] * 1e3:.1f} ms"
             if s["last_recovery_s"] is not None else ""))


async def _stream_clients(server, reqs, max_queue, args):
    """The --stream path: one async client per request, tokens printed
    as each fused engine step lands (the sync path's streams are
    bit-identical under greedy sampling, even across supervised driver
    crashes — replayed tokens are never re-printed)."""
    from repro.serving import AsyncEngine

    engine = AsyncEngine(server, max_queue_depth=max_queue)
    sup = _supervise(engine, args)

    async def client(r):
        stream = await engine.submit(r)
        async for tok in stream:
            print(f"  req {stream.request_id:>3} inst {r.instance} +{tok}")
        return await stream.result()

    results = await asyncio.gather(*(client(r) for r in reqs))
    await engine.aclose()
    _print_recovery(sup)
    return [r for r in results if r.status == "ok"]


def _serve_http(server, args):
    """The --http path: expose the engine over HTTP until interrupted,
    then drain in-flight requests and print the metrics table."""
    from repro.serving import AsyncEngine, start_http_server

    async def run():
        engine = AsyncEngine(server, max_queue_depth=args.max_queue)
        sup = _supervise(engine, args)
        http = await start_http_server(engine, port=args.http)
        addr = http.sockets[0].getsockname()
        print(f"serving HTTP on {addr[0]}:{addr[1]} — "
              f"POST /v1/completions (model-0..model-{server.m - 1}, "
              f"prompt = token ids, \"stream\": true for SSE), GET /metrics")
        if sup is not None:
            print(f"supervised: watchdog="
                  f"{args.watchdog_ms or 'off'} ms, "
                  f"max_restarts={args.max_restarts}"
                  + (f", fault plan armed ({args.fault_plan})"
                     if args.fault_plan else ""))
        try:
            async with http:
                await http.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            http.close()
            await http.wait_closed()
            await engine.aclose()          # graceful drain
            _print_recovery(sup)

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    print(server.metrics.format_table())
    _print_obs(server)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ASSIGNED))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--num-instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--policy", choices=sorted(POLICIES), default="fifo")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (tokens per admission call)")
    ap.add_argument("--chunk-budget", type=int, default=4,
                    help="max prefill chunk calls interleaved per engine step")
    ap.add_argument("--lanes", type=int, default=4,
                    help="concurrent prefill lanes (requests mid-admission)")
    ap.add_argument("--no-tail-fold", action="store_true",
                    help="disable padded-final-chunk tail folding (two "
                         "compiled shapes + per-token tail calls, for A/B)")
    ap.add_argument("--decode-steps", type=int, default=1, metavar="K",
                    help="fuse K decode+sample steps into one device call "
                         "(multi-step decode, DESIGN.md §6.6; stop "
                         "handling is on-device, streams are bit-identical "
                         "to K=1 under greedy sampling)")
    ap.add_argument("--pallas-kernels", action="store_true",
                    help="route decode through the fused Pallas path "
                         "(decode-layer megakernel + fused greedy "
                         "sampling, DESIGN.md §6.7; interpret mode off "
                         "TPU, so expect launch-count wins, not "
                         "wall-clock, on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices (0 = real devices)")
    ap.add_argument("--mesh-shape", default=None, metavar="DxT",
                    help="serve under a (data=D, model=T) mesh, e.g. 2x4; "
                         "default with --devices: all devices on data")
    ap.add_argument("--stream", action="store_true",
                    help="drive the workload through the AsyncEngine as "
                         "concurrent clients, printing tokens as they arrive")
    ap.add_argument("--http", type=int, default=0, metavar="PORT",
                    help="serve over HTTP on this port (POST /v1/completions "
                         "SSE + GET /metrics) until interrupted")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="per-instance queue bound for the async frontend "
                         "(0 = unbounded); full queues answer HTTP 429")
    ap.add_argument("--fault-plan", default=None, metavar="JSON",
                    help="deterministic fault plan (DESIGN.md §6.8): a "
                         "path to a JSON file or inline JSON, e.g. "
                         "'{\"seed\": 0, \"faults\": [{\"site\": "
                         "\"driver\", \"at_call\": 3}]}'; armed for the "
                         "whole run — with --stream/--http a Supervisor "
                         "recovers the driver")
    ap.add_argument("--watchdog-ms", type=float, default=0.0,
                    help="supervised per-device-step deadline in ms for "
                         "the async paths (0 = no watchdog); steps that "
                         "overrun are treated as stalls and recovered")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="supervisor restart budget before giving up")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="capture a step trace of the run and write it as "
                         "Chrome-trace JSON (Perfetto / chrome://tracing); "
                         "with --http, toggle capture via POST "
                         "/debug/trace/start|stop instead")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="per-instance TTFT objective in ms (DESIGN.md "
                         "§6.9); 0 = no TTFT SLO. Error-budget burn is "
                         "reported per instance at end of run and on "
                         "GET /v1/slo")
    ap.add_argument("--slo-itl-ms", type=float, default=0.0,
                    help="per-instance inter-token-latency objective in "
                         "ms; 0 = no ITL SLO")
    ap.add_argument("--slo-target", type=float, default=0.99,
                    help="fraction of tokens that must meet each latency "
                         "objective (the SLO target, default 0.99)")
    ap.add_argument("--account", action="store_true",
                    help="per-tenant device-time attribution (DESIGN.md "
                         "§6.9): split every settled device call's wall "
                         "time across the instances occupying the grid; "
                         "prints the attribution table at end of run")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the flight recorder: on driver crash, "
                         "watchdog fire, or quarantine, dump last-N trace "
                         "events + metrics + scheduler depths + SLO state "
                         "to DIR/flight-NNNN.json")
    args = ap.parse_args()

    base = registry.get_smoke_config(args.arch) if args.smoke else registry.get_config(args.arch)
    if base.family not in SERVABLE_FAMILIES:
        raise SystemExit(f"family {base.family!r} is not servable")
    max_context = args.max_context
    if base.family == "hybrid":
        from repro.models import hybrid as H
        need = H.min_serving_context(base, args.max_new)
        if max_context < need:
            print(f"raising --max-context {max_context} -> {need} "
                  f"(hybrid meta tokens + SWA ring)")
            max_context = need
    if args.pallas_kernels:
        base = base.with_(use_pallas_kernels=True)
    m = args.num_instances
    cfg1 = base.with_(num_instances=1)
    cfg = base.with_(num_instances=m)

    # M independently-"fine-tuned" instances (different random weights)
    keys = jax.random.split(jax.random.PRNGKey(args.seed), m)
    instances = [api.init(cfg1, k) for k in keys]

    # the paper's offline merge (§4: once per model set, amortized)
    t0 = time.perf_counter()
    merged = C.merge_instances(instances, api.axes(cfg1))
    jax.block_until_ready(jax.tree.leaves(merged)[0])
    print(f"NetFuse merge of {m} instances: {(time.perf_counter()-t0)*1e3:.1f} ms")

    mesh = mesh_from_args(args.devices, args.mesh_shape)
    if mesh is not None:
        print(f"serving mesh: {dict(mesh.shape)} over {mesh.size} devices")

    faults = None
    if args.fault_plan:
        from repro.serving import FaultInjector
        faults = FaultInjector.from_json(args.fault_plan)
        print(f"fault plan: {len(faults.plan)} spec(s), seed {faults.seed}")

    slo = None
    if args.slo_ttft_ms > 0 or args.slo_itl_ms > 0:
        from repro.serving import SLOConfig
        slo = SLOConfig(
            ttft_ms=args.slo_ttft_ms or None, itl_ms=args.slo_itl_ms or None,
            target=args.slo_target)
    flight = None
    if args.flight_dir:
        from repro.serving import FlightRecorder
        flight = FlightRecorder(args.flight_dir)

    server = MultiModelServer(
        cfg, merged, slots_per_instance=args.slots, max_context=max_context,
        temperature=args.temperature, top_k=args.top_k, seed=args.seed,
        scheduler=args.policy, prefill_chunk=args.chunk,
        prefill_lanes=args.lanes, chunk_budget=args.chunk_budget,
        tail_fold=not args.no_tail_fold, mesh=mesh,
        decode_steps=args.decode_steps, faults=faults,
        slo=slo, flight=flight,
    )
    if args.account:
        server.accounting.start()
    if faults is not None:
        faults.arm()
    if args.http:
        _serve_http(server, args)
        return

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            instance=i % m,
            prompt=rng.integers(1, cfg.vocab_size,
                                size=rng.integers(2, 8)).tolist(),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    if args.trace_out:
        server.tracer.start()
    t0 = time.perf_counter()
    if args.stream:
        results = asyncio.run(
            _stream_clients(server, reqs, args.max_queue, args))
    else:
        for r in reqs:
            server.submit(r)
        results = server.run_until_drained()
    dt = time.perf_counter() - t0
    if args.trace_out:
        import json as _json
        server.tracer.stop()
        chrome = server.tracer.export_chrome()
        summ = server.tracer.summary()
        with open(args.trace_out, "w") as f:
            _json.dump(chrome, f)
        do = summ["dispatch_overhead_ms"]
        print(f"wrote {args.trace_out}: {len(chrome['traceEvents'])} events, "
              f"dispatch overhead p50/p95 "
              f"{do['p50']:.2f}/{do['p95']:.2f} ms, "
              f"grid occupancy {summ['mean_grid_occupancy']:.2f}"
              if do is not None else f"wrote {args.trace_out}")
    toks = sum(len(r.tokens) for r in results)
    snap = server.metrics.snapshot()
    print(f"served {len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {snap['decode_steps']} fused decode steps "
          f"in {server.steps} device calls @ K={args.decode_steps}, "
          f"{snap['tokens_per_device_call']:.1f} tok/device-call, "
          f"policy={args.policy})")
    print(f"chunked prefill: chunk={server.prefill.chunk}, "
          f"tail_fold={'off' if args.no_tail_fold else 'on'}, "
          f"{server.prefill.compiled_shapes} compiled shape(s), "
          f"{server.prefill.device_calls} device calls for "
          f"{server.prefill.admitted} admissions, "
          f"{1e3 * server.metrics.admission_stall_s:.1f} ms admission stall")
    print(server.metrics.format_table())
    _print_obs(server)
    for r in results[:4]:
        print(f"  req {r.request_id} (instance {r.instance}): {r.tokens[:8]}...")


if __name__ == "__main__":
    main()
