"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/cache leaf carries logical axis names (see
repro.models.common); the mappings below turn them into PartitionSpecs
with divisibility guards (a dim that doesn't divide its mesh axes is
replicated — e.g. 8 kv heads on a 16-way model axis, or batch=1 for
long_500k).

serve rules: tensor parallel over "model", batch/instances over
("pod",)"data".
train rules: + FSDP — the params' embed dim additionally shards over
"data", so AdamW moments (which mirror params) shard too.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import Rules


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def serve_rules(mesh) -> Rules:
    data = _batch_axes(mesh)
    return Rules(mesh, {
        "batch": data,
        "instances": data,          # merged instances are data-parallel
        "act_embed": None,
        "embed": None,
        "heads": "model", "kv_heads": "model",
        # cache_seq: KV caches shard their context dim over "model"
        # (Pope et al. flash-decode style — §Perf tinyllama-decode
        # iteration): attention contracts the local context shard and
        # combines softmax stats with tiny all-reduces, instead of
        # all-gathering KV whenever kv_heads doesn't divide the TP axis.
        # Listed before kv_heads in cache axes tuples, so it claims
        # "model" first; kv_heads/kv_hd then replicate (axis reuse guard).
        "cache_seq": "model",
        # kv_hd: head-dim fallback for caches whose kv_heads don't divide
        # the model axis (e.g. 8 kv heads on 16-way TP): the spec dedupe
        # keeps kv_heads when it divides, else the head_dim shards.
        "kv_hd": "model",
        "heads_flat": "model", "kv_flat": "model",
        "mlp": "model", "expert_mlp": None,
        "experts": "model",
        # activation constraint inside the MoE dispatch region (moe.py):
        # "model" = expert-parallel compute, None = DP-compute/weight-gather.
        "experts_compute": "model",
        "vocab": "model",
        "layers": None,
        # Megatron-style sequence parallelism: the residual stream shards
        # its seq dim over "model" (norms/elementwise are per-token);
        # attention and MLP regions constrain seq to None, so GSPMD
        # inserts the all-gather / reduce-scatter pair at region entry.
        "seq": "model",
    })


def default_serve_rules(mesh, rules: Rules | None = None) -> Rules | None:
    """Resolve the serving layer's ``mesh=``/``rules=`` pair: no mesh ->
    no rules (plain single-device path); a mesh without explicit rules
    -> :func:`serve_rules`.  Shared by the engine and ChunkedPrefill so
    their defaults can't drift."""
    if mesh is None:
        return None
    return rules if rules is not None else serve_rules(mesh)


def train_rules(mesh, *, fsdp: bool = True) -> Rules:
    r = serve_rules(mesh)
    if fsdp:
        r.mapping = dict(r.mapping, embed=_batch_axes(mesh))
    return r


def spec_for(rules: Rules, logical, shape=None) -> P:
    if isinstance(logical, str):
        logical = [None if p in ("", "none") else p for p in logical.split(",")]
    return rules.spec(logical, shape)


def tree_shardings(rules: Rules, axes_tree: Any, abstract_tree: Any):
    """Pytree of NamedSharding matching ``abstract_tree``.

    ``axes_tree`` leaves are tuples (param trees) or comma-strings (cache
    trees)."""
    is_leaf = lambda x: isinstance(x, (tuple, str)) and not hasattr(x, "_fields")

    def mk(ax, leaf):
        return NamedSharding(rules.mesh, spec_for(rules, ax, leaf.shape))

    return jax.tree.map(mk, axes_tree, abstract_tree, is_leaf=is_leaf)


def batch_shardings(rules: Rules, batch_specs: Any):
    """Shardings for input batches: dim0=instances, dim1=batch, rest
    replicated."""
    def mk(leaf):
        logical = ["instances", "batch"] + [None] * (len(leaf.shape) - 2)
        return NamedSharding(rules.mesh, rules.spec(logical, leaf.shape))
    return jax.tree.map(mk, batch_specs)


def replicated(rules: Rules):
    return NamedSharding(rules.mesh, P())


def dp_train_rules(mesh) -> Rules:
    """Pure data-parallel training for small (<~3B) models: batch over
    BOTH mesh axes, params replicated (bf16-compute models of this size
    fit), optimizer moments ZeRO-1-sharded via moments_rules().  §Perf
    finding: TP=16 Megatron-SP collectives dominate small-model training
    on a 256-chip pod; trading them for one gradient all-reduce moves the
    collective term ~10x down."""
    # "pod" LAST: the suffix-drop divisibility guard (common.Rules.spec)
    # then keeps global_batch=256 sharded 256-way over (data, model) on the
    # 2-pod mesh (replicated across pods) instead of replicating everywhere.
    both = ("data", "model") + (("pod",) if "pod" in mesh.shape else ())
    return Rules(mesh, {
        "batch": both,
        "instances": both,
        "act_embed": None, "embed": None,
        "heads": None, "kv_heads": None, "kv_hd": None,
        "heads_flat": None, "kv_flat": None,
        "mlp": None, "expert_mlp": None,
        "experts": None, "vocab": None,
        "layers": None, "seq": None,
    })


def moe_dp_compute(rules: Rules) -> Rules:
    """§Perf variant (_moedp): MoE dispatch buffers stay batch-sharded;
    expert weights are all-gathered per layer instead of all-to-all'ing
    the (K·cf)x-inflated activation buffers."""
    return Rules(rules.mesh, dict(rules.mapping, experts_compute=None))


def moe_ep_shmap(rules: Rules) -> Rules:
    """§Perf variant (_moeps): canonical expert parallelism — per-rank
    expert-window dispatch + local einsums + token-space psum inside one
    shard_map (moe._moe_mlp_ep_shmap)."""
    return Rules(rules.mesh, dict(rules.mapping, experts_compute="ep"))


def moments_rules(mesh) -> Rules:
    """ZeRO-1: AdamW moments shard 2-D (embed x model-ish dims) even when
    params are replicated."""
    data = _batch_axes(mesh)
    return Rules(mesh, {
        "batch": None, "instances": None,
        "act_embed": None,
        "embed": data,
        "heads": "model", "kv_heads": "model", "kv_hd": "model",
        "heads_flat": "model", "kv_flat": "model",
        "mlp": "model", "expert_mlp": "model",
        "experts": "model", "vocab": "model",
        "layers": None, "seq": None,
    })
