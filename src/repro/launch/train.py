"""Training launcher.

CPU-scale real runs (smoke configs, synthetic data) AND the production
path: with --mesh the same train_step is pjit-compiled against the
sharding rules (on real hardware this is the entry point; on this
container use dryrun.py for the 512-device lowering).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \\
      --smoke --steps 100 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import api
from repro.configs import registry
from repro.data import pipeline
from repro.optim import cosine_with_warmup
from repro.train import loop as train_loop
from repro import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ASSIGNED))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--num-instances", type=int, default=1,
                    help="NetFuse-merge M instances and train them together")
    ap.add_argument("--save", default=None, help="checkpoint dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", action="store_true",
                    help="run under the production sharding rules on the "
                         "available devices (pjit path; on this container "
                         "that is a 1x1 mesh — the 512-device lowering "
                         "lives in dryrun.py)")
    # size overrides (e.g. the ~100M CPU end-to-end run in EXPERIMENTS.md:
    #   --arch tinyllama-1.1b --smoke --layers 8 --d-model 768 --heads 12
    #   --kv-heads 4 --d-ff 2048 --vocab 32000 --steps 300)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--heads", type=int, default=0)
    ap.add_argument("--kv-heads", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch) if args.smoke else registry.get_config(args.arch)
    cfg = cfg.with_(num_instances=args.num_instances)
    over = {k: v for k, v in (
        ("num_layers", args.layers), ("d_model", args.d_model),
        ("num_heads", args.heads), ("num_kv_heads", args.kv_heads),
        ("d_ff", args.d_ff), ("vocab_size", args.vocab),
    ) if v}
    if over:
        if "d_model" in over:
            over.setdefault("head_dim", 0)  # recompute from new dims
        cfg = cfg.with_(**over)
    print(f"arch={cfg.name} family={cfg.family} M={cfg.num_instances} "
          f"devices={jax.device_count()}")

    data = _data_for(cfg, args.seq)
    sched = cosine_with_warmup(args.lr, warmup_steps=args.steps // 10 + 1,
                               total_steps=args.steps)
    t0 = time.perf_counter()

    def run():
        return train_loop.train_loop(
            cfg, data, steps=args.steps, batch_size=args.batch,
            seq_len=args.seq, lr_schedule=sched,
            key=jax.random.PRNGKey(args.seed),
        )

    if args.mesh:
        from repro.launch.compat import set_mesh
        from repro.launch.shardings import train_rules
        n = jax.device_count()
        mesh = jax.make_mesh((n, 1), ("data", "model"))
        print(f"mesh=(data={n}, model=1); rules active (constrain/shard_map paths engaged)")
        with set_mesh(mesh), train_rules(mesh):
            state, losses = run()
    else:
        state, losses = run()
    print(f"done in {time.perf_counter()-t0:.1f}s; "
          f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")
    if args.save:
        ckpt.save(args.save, state.params, extra={"arch": cfg.name, "steps": args.steps})
        print(f"saved params to {args.save}")


def _data_for(cfg, seq):
    class _D:
        def batch(self, step, batch_size, seq_len):
            return pipeline.make_batch(cfg, step, batch_size, seq_len, seed=17)
    return _D()


if __name__ == "__main__":
    main()
