"""Fusion-aware model zoo: every architecture is written with a leading
NetFuse ``instances`` axis (M=1 == plain model)."""
from repro.models import audio, cnn, common, dense, encoder, hybrid, layers, moe, ssm, vlm
