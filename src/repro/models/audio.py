"""Whisper-style encoder-decoder — whisper-small [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the
assignment spec: ``input_specs`` provides post-conv frame embeddings
(M, B, F, D).  Implemented here: sinusoidal encoder positions, the
bidirectional encoder stack, and the causal decoder with self- +
cross-attention (pre-LN, GELU MLPs, learned decoder positions — extended
beyond 448 to cover the assigned train_4k shape; noted in DESIGN.md).

Decode caches: ring-buffer self-attention KV (as dense) plus per-layer
cross-attention K/V computed once from the encoder output at prefill.
long_500k is SKIPPED for this arch (encoder-decoder with fixed encoder
horizon — see DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory, make_factory, param_axes, param_values, stack_layer_params,
)
from repro.models.layers import KVCache


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _attn_params(cfg, f, prefix, kv_dim=None):
    m, d, h, hd = cfg.num_instances, cfg.d_model, cfg.num_heads, cfg.head_dim
    kvh = cfg.num_kv_heads
    return {
        f"{prefix}wq": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        f"{prefix}wk": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        f"{prefix}wv": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        f"{prefix}wo": f((m, h * hd, d), ("instances", "heads_flat", "embed"), init="fan_in"),
        f"{prefix}bq": f((m, h * hd), ("instances", "heads_flat"), init="zeros"),
        f"{prefix}bv": f((m, kvh * hd), ("instances", "kv_flat"), init="zeros"),
        f"{prefix}bo": f((m, d), ("instances", "embed"), init="zeros"),
    }


def _enc_layer(cfg, f):
    m, d, ff = cfg.num_instances, cfg.d_model, cfg.d_ff
    p = {
        "ln1_s": f((m, d), ("instances", None), init="ones"),
        "ln1_b": f((m, d), ("instances", None), init="zeros"),
        "ln2_s": f((m, d), ("instances", None), init="ones"),
        "ln2_b": f((m, d), ("instances", None), init="zeros"),
        "w1": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "b1": f((m, ff), ("instances", "mlp"), init="zeros"),
        "w2": f((m, ff, d), ("instances", "mlp", "embed"), init="fan_in"),
        "b2": f((m, d), ("instances", "embed"), init="zeros"),
    }
    p.update(_attn_params(cfg, f, ""))
    return p


def _dec_layer(cfg, f):
    m, d, ff = cfg.num_instances, cfg.d_model, cfg.d_ff
    p = {
        "ln1_s": f((m, d), ("instances", None), init="ones"),
        "ln1_b": f((m, d), ("instances", None), init="zeros"),
        "ln_x_s": f((m, d), ("instances", None), init="ones"),
        "ln_x_b": f((m, d), ("instances", None), init="zeros"),
        "ln2_s": f((m, d), ("instances", None), init="ones"),
        "ln2_b": f((m, d), ("instances", None), init="zeros"),
        "w1": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "b1": f((m, ff), ("instances", "mlp"), init="zeros"),
        "w2": f((m, ff, d), ("instances", "mlp", "embed"), init="fan_in"),
        "b2": f((m, d), ("instances", "embed"), init="zeros"),
    }
    p.update(_attn_params(cfg, f, ""))       # self-attention
    p.update(_attn_params(cfg, f, "x_"))     # cross-attention
    return p


def build_params(cfg: ModelConfig, f: Factory):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    enc_l = cfg.encoder_layers or cfg.num_layers
    max_pos = cfg.max_target_positions or 4608
    return {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "pos_embed": f((m, max_pos, d), ("instances", None, "embed")),
        "enc_layers": stack_layer_params([_enc_layer(cfg, f) for _ in range(enc_l)]),
        "enc_ln_s": f((m, d), ("instances", None), init="ones"),
        "enc_ln_b": f((m, d), ("instances", None), init="zeros"),
        "dec_layers": stack_layer_params([_dec_layer(cfg, f) for _ in range(cfg.num_layers)]),
        "final_ln_s": f((m, d), ("instances", None), init="ones"),
        "final_ln_b": f((m, d), ("instances", None), init="zeros"),
    }


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def _sinusoid(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _mha(cfg, lp, x, kv_x, *, prefix="", causal, positions=None, q_pos=None,
         cache=None, decode_pos=None):
    """Whisper MHA (no RoPE, learned/sinusoidal positions added outside)."""
    m, b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.linear(x, lp[f"{prefix}wq"], lp.get(f"{prefix}bq")).reshape(m, b, s, h, hd)
    if kv_x is not None:
        skv = kv_x.shape[2]
        k = L.linear(kv_x, lp[f"{prefix}wk"]).reshape(m, b, skv, kvh, hd)
        v = L.linear(kv_x, lp[f"{prefix}wv"], lp.get(f"{prefix}bv")).reshape(m, b, skv, kvh, hd)
    else:
        k = v = None
    if cache is not None:
        ck, cv = L.cache_update_one(cache[0], cache[1], k, v, decode_pos)
        kv_pos = L.cache_slot_positions(decode_pos, ck.shape[2])
        o = L.flash_attention(q, ck, cv, decode_pos[..., None], kv_pos, causal=True)
        new_cache = (ck, cv)
    else:
        skv = k.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (m, b, skv))
        qp = q_pos if q_pos is not None else (
            positions if positions is not None
            else jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
        )
        o = L.flash_attention(q, k, v, qp, kv_pos, causal=causal)
        new_cache = None
    out = L.linear(o.reshape(m, b, s, h * hd), lp[f"{prefix}wo"], lp.get(f"{prefix}bo"))
    return out, new_cache, (k, v)


def encode(cfg, params, frame_embeds):
    """frame_embeds: (M,B,F,D) stub conv features -> encoder states."""
    m, b, fr, d = frame_embeds.shape
    x = frame_embeds.astype(jnp.dtype(cfg.dtype))
    x = x + jnp.asarray(_sinusoid(fr, d), x.dtype)

    def body(xc, lp):
        n = L.layer_norm(xc, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, _, _ = _mha(cfg, lp, n, n, causal=False)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(n, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return xc, None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_ln_s"], params["enc_ln_b"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _dec_embed(cfg, params, tokens, start: int = 0):
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    s = tokens.shape[2]
    pe = lax.dynamic_slice_in_dim(params["pos_embed"], start, s, axis=1)
    return x + pe[:, None].astype(x.dtype)


def decode_full(cfg, params, tokens, enc_out, *, remat: bool = False):
    """Teacher-forced decoder pass (training). Returns (M,B,S,V) logits."""
    x = _dec_embed(cfg, params, tokens)
    m, b, s, d = x.shape

    def body(xc, lp):
        n = L.layer_norm(xc, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, _, _ = _mha(cfg, lp, n, n, causal=True)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln_x_s"], lp["ln_x_b"], cfg.norm_eps)
        a, _, _ = _mha(cfg, lp, n, enc_out, prefix="x_", causal=False)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(n, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return xc, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["dec_layers"])
    x = L.layer_norm(x, params["final_ln_s"], params["final_ln_b"], cfg.norm_eps)
    return L.unembed(x, jnp.swapaxes(params["embed"], -1, -2))


def forward(cfg, params, tokens, frame_embeds, *, remat: bool = False):
    return decode_full(cfg, params, tokens, encode(cfg, params, frame_embeds), remat=remat)


def prefill(cfg, params, tokens, frame_embeds, *, cache_len: int | None = None):
    """Encode audio + run the decoder prompt; returns (last logits, cache).
    cache = {"self": KVCache, "cross_k": (L,M,B,F,KVH,hd), "cross_v": ...}"""
    enc_out = encode(cfg, params, frame_embeds)
    x = _dec_embed(cfg, params, tokens)
    m, b, s, d = x.shape
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))

    def body(xc, lp):
        n = L.layer_norm(xc, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, _, (k, v) = _mha(cfg, lp, n, n, causal=True, positions=positions)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln_x_s"], lp["ln_x_b"], cfg.norm_eps)
        a, _, (xk, xv) = _mha(cfg, lp, n, enc_out, prefix="x_", causal=False)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(n, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        pad = cache_len - s
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.dtype(cfg.dtype)
        return xc, (kc.astype(dt), vc.astype(dt), xk.astype(dt), xv.astype(dt))

    x, (ck, cv, xk, xv) = lax.scan(body, x, params["dec_layers"])
    x = L.layer_norm(x[:, :, -1:], params["final_ln_s"], params["final_ln_b"], cfg.norm_eps)
    logits = L.unembed(x, jnp.swapaxes(params["embed"], -1, -2))[:, :, 0]
    return logits, {"self": KVCache(k=ck, v=cv), "cross_k": xk, "cross_v": xv}


def decode_step(cfg, params, cache, tokens, pos):
    """One decoder token; cross-attention reads precomputed encoder KV."""
    m, b, _ = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    # learned position embedding at each request's position (pos may vary
    # per (m, b); gather per instance-batch element)
    flat_pos = pos.reshape(m * b).astype(jnp.int32)
    tables = jnp.repeat(params["pos_embed"], b, axis=0)        # (M*B, P, D)
    pe = jax.vmap(lambda t, i: lax.dynamic_slice_in_dim(t, i, 1, axis=0))(
        tables, flat_pos
    ).reshape(m, b, 1, -1)
    x = x + pe.astype(x.dtype)

    def body(xc, xs):
        lp, ck, cv, xk, xv = xs
        n = L.layer_norm(xc, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, new_cache, _ = _mha(cfg, lp, n, n, causal=True, cache=(ck, cv), decode_pos=pos)
        xc = xc + a
        n = L.layer_norm(xc, lp["ln_x_s"], lp["ln_x_b"], cfg.norm_eps)
        # cross attention against cached encoder K/V
        h_, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = L.linear(n, lp["x_wq"], lp.get("x_bq")).reshape(m, b, 1, h_, hd)
        fr = xk.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(fr, dtype=jnp.int32), (m, b, fr))
        o = L.flash_attention(q, xk, xv, pos[..., None] * 0 + fr, kv_pos, causal=False)
        a = L.linear(o.reshape(m, b, 1, h_ * hd), lp["x_wo"], lp.get("x_bo"))
        xc = xc + a
        n = L.layer_norm(xc, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(n, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return xc, new_cache

    x, (nk, nv) = lax.scan(
        body, x, (params["dec_layers"], cache["self"].k, cache["self"].v,
                  cache["cross_k"], cache["cross_v"])
    )
    x = L.layer_norm(x, params["final_ln_s"], params["final_ln_b"], cfg.norm_eps)
    logits = L.unembed(x, jnp.swapaxes(params["embed"], -1, -2))[:, :, 0]
    return logits, {"self": KVCache(k=nk, v=nv), "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}


def init_chunk_carry(cfg, m, b, cache_len):
    return {"cache": make_cache(cfg, m, b, cache_len)}


def chunk_carry_axes(cfg):
    return {"cache": cache_axes(cfg)}


def prefill_chunk(cfg, params, batch, carry, offset):
    """One decoder chunk of a state-carrying prefill.

    The encoder runs on batch["frames"] every chunk and the (identical)
    cross-attention K/V are rewritten into the carry — recomputation
    keeps the runtime at exactly two compiled shapes (chunk + tail)
    instead of adding a third init-time shape; frames are short relative
    to decode work, and serving feeds stub (zero) frames anyway."""
    from repro.models.common import constrain_axes

    tokens, frames = batch["tokens"], batch["frames"]
    cache = carry["cache"]
    valid = batch.get("valid")            # (M,B,C) tail-folding junk mask
    m, b, c = tokens.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(cfg, params, frames)
    fr = enc_out.shape[2]
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)   # (M,B,C)
    x = L.embed(tokens, params["embed"], dt)
    # learned positions gathered at each lane's absolute offsets
    pidx = jnp.clip(positions, 0, params["pos_embed"].shape[1] - 1)
    pe = jax.vmap(lambda t, i: jnp.take(t, i, axis=0))(
        params["pos_embed"], pidx.reshape(m, b * c)
    ).reshape(m, b, c, -1)
    x = x + pe.astype(x.dtype)
    s_cache = cache["self"].k.shape[3]
    before = L.cache_positions_after(offset - 1, s_cache, 0)
    kv_pos_self = jnp.concatenate([before, positions], axis=-1)
    kv_pos_x = jnp.broadcast_to(jnp.arange(fr, dtype=jnp.int32), (m, b, fr))
    kv_ax = ("instances", "batch", "cache_seq", "kv_heads", "kv_hd")

    def body(xc, xs):
        lp, ck, cv = xs
        n = L.layer_norm(xc, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        q = L.linear(n, lp["wq"], lp.get("bq")).reshape(m, b, c, h, hd)
        k = L.linear(n, lp["wk"]).reshape(m, b, c, kvh, hd)
        v = L.linear(n, lp["wv"], lp.get("bv")).reshape(m, b, c, kvh, hd)
        o = L.flash_attention(
            q,
            jnp.concatenate([ck, k.astype(ck.dtype)], axis=2),
            jnp.concatenate([cv, v.astype(cv.dtype)], axis=2),
            positions, kv_pos_self, causal=True,
        )
        xc = xc + L.linear(o.reshape(m, b, c, h * hd), lp["wo"], lp.get("bo"))
        n = L.layer_norm(xc, lp["ln_x_s"], lp["ln_x_b"], cfg.norm_eps)
        xq = L.linear(n, lp["x_wq"], lp.get("x_bq")).reshape(m, b, c, h, hd)
        xk = L.linear(enc_out, lp["x_wk"]).reshape(m, b, fr, kvh, hd)
        xv = L.linear(enc_out, lp["x_wv"], lp.get("x_bv")).reshape(m, b, fr, kvh, hd)
        o = L.flash_attention(xq, xk, xv, positions, kv_pos_x, causal=False)
        xc = xc + L.linear(o.reshape(m, b, c, h * hd), lp["x_wo"], lp.get("x_bo"))
        n = L.layer_norm(xc, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(n, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        nk = constrain_axes(L.cache_append_chunk(ck, k, positions, 0, valid), kv_ax)
        nv = constrain_axes(L.cache_append_chunk(cv, v, positions, 0, valid), kv_ax)
        return xc, (nk, nv, xk.astype(dt), xv.astype(dt))

    _, (nk, nv, xks, xvs) = lax.scan(body, x, (params["dec_layers"], cache["self"].k, cache["self"].v))
    return {"cache": {"self": KVCache(k=nk, v=nv), "cross_k": xks, "cross_v": xvs}}


def make_cache(cfg, m, b, context_len, num_frames=None):
    fr = num_frames or cfg.num_audio_frames
    dt = jnp.dtype(cfg.dtype)
    kvh, hd, l = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "self": L.make_kv_cache(l, m, b, context_len, kvh, hd, dt),
        "cross_k": jnp.zeros((l, m, b, fr, kvh, hd), dt),
        "cross_v": jnp.zeros((l, m, b, fr, kvh, hd), dt),
    }


def cache_axes(cfg):
    ax = ("layers", "instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    return {
        "self": KVCache(k=ax, v=ax),
        "cross_k": ax,
        "cross_v": ax,
    }
