"""ResNet-50 / ResNeXt-50 — the paper's own CNN evaluation models (§5.1).

These run in the paper's *concat form*: merged activations live as
(B, H, W, M*C) channel-concatenated tensors and every conv is a grouped
conv with ``feature_group_count = M * cardinality`` (paper Appendix A),
batch norms concatenate channels, and the final per-task FC heads stay
unmerged (paper §6 — each task may have a different class count).

Inference-mode only (the paper evaluates inference); batch norm uses
stored statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fused_ops
from repro.models.common import Factory, make_factory, param_axes, param_values


def stage_widths(cfg: ModelConfig) -> list[tuple[int, int]]:
    """[(inner, out)] per stage; ResNeXt uses 2x inner width."""
    mult = 2 if cfg.cnn_cardinality > 1 else 1
    return [
        (cfg.cnn_width * (2 ** s) * mult, cfg.cnn_width * 4 * (2 ** s))
        for s in range(len(cfg.cnn_stage_blocks))
    ]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _conv_bn(f: Factory, m: int, k: int, cin: int, cout: int, name_axes=("instances", None, None, None, "mlp")):
    return {
        "w": f((m, k, k, cin, cout), name_axes, init="fan_in"),
        "bn_scale": f((m, cout), ("instances", "mlp"), init="ones"),
        "bn_bias": f((m, cout), ("instances", "mlp"), init="zeros"),
        "bn_mean": f((m, cout), ("instances", "mlp"), init="zeros"),
        "bn_var": f((m, cout), ("instances", "mlp"), init="ones"),
    }


def build_params(cfg: ModelConfig, f: Factory):
    m = cfg.num_instances
    card = cfg.cnn_cardinality
    p = {"stem": _conv_bn(f, m, 7, 3, cfg.cnn_width)}
    cin = cfg.cnn_width
    stages = []
    for si, nblocks in enumerate(cfg.cnn_stage_blocks):
        inner, cout = stage_widths(cfg)[si]
        blocks = []
        for bi in range(nblocks):
            blk = {
                "reduce": _conv_bn(f, m, 1, cin, inner),
                "conv3": _conv_bn(f, m, 3, inner // card, inner),
                "expand": _conv_bn(f, m, 1, inner, cout),
            }
            if bi == 0:
                blk["down"] = _conv_bn(f, m, 1, cin, cout)
            blocks.append(blk)
            cin = cout
        stages.append(blocks)
    p["stages"] = stages
    p["head"] = {"w": f((m, cin, cfg.num_classes), ("instances", "mlp", None), init="fan_in")}
    return p


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# forward (concat form)
# ---------------------------------------------------------------------------


def _to_concat(x):
    """(M,B,H,W,C) -> (B,H,W,M*C)."""
    m, b, h, w, c = x.shape
    return jnp.moveaxis(x, 0, 3).reshape(b, h, w, m * c)


def _conv_bn_relu(p, x, m, *, stride=1, groups=1, relu=True):
    """x: (B,H,W,M*Cin); weights stored (M,K,K,Cin/g,Cout)."""
    w = jnp.moveaxis(p["w"], 0, 3)                             # (K,K,Cin/g,M,Cout)
    w = w.reshape(*w.shape[:2], w.shape[2], -1)                # (K,K,Cin/g,M*Cout)
    y = fused_ops.grouped_conv2d(x, w, groups=m * groups, stride=stride)
    y = fused_ops.merged_batch_norm(
        y, p["bn_mean"].reshape(-1), p["bn_var"].reshape(-1),
        p["bn_scale"].reshape(-1), p["bn_bias"].reshape(-1),
    )
    return jax.nn.relu(y) if relu else y


def forward(cfg: ModelConfig, params, images) -> list[jax.Array]:
    """images: (M,B,H,W,3). Returns per-task logits list (paper §6:
    backbone merged, task heads separate)."""
    m = images.shape[0]
    card = cfg.cnn_cardinality
    x = _to_concat(images)
    x = _conv_bn_relu(params["stem"], x, m, stride=2)
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            res = x
            y = _conv_bn_relu(blk["reduce"], x, m)
            y = _conv_bn_relu(blk["conv3"], y, m, stride=stride, groups=card)
            y = _conv_bn_relu(blk["expand"], y, m, relu=False)
            if "down" in blk:
                res = _conv_bn_relu(blk["down"], x, m, stride=stride, relu=False)
            x = jax.nn.relu(y + res)
    feats = jnp.mean(x, axis=(1, 2))                           # (B, M*C)
    b = feats.shape[0]
    c = feats.shape[1] // m
    feats = feats.reshape(b, m, c)
    # unmerged per-task heads
    return [
        feats[:, i] @ params["head"]["w"][i] for i in range(m)
    ]
