"""Shared utilities for the fusion-aware model zoo.

Conventions (see DESIGN.md §2.1):

* every parameter tensor carries a leading ``instances`` axis ``M``
  (NetFuse-merged fine-tuned instances; M=1 is the plain model),
* activations are ``(M, B, ...)`` — per-instance batches,
* layer stacks are stacked along a leading ``L`` axis and executed with
  ``lax.scan``,
* every param is built together with its *logical sharding axes* so the
  launcher can derive PartitionSpecs (MaxText-style logical axis rules).

``build_params(cfg, factory)`` functions return a pytree whose leaves are
:class:`PA` (value + logical axes).  ``factory`` decides whether values
are real random arrays (init) or ShapeDtypeStructs (abstract init for the
multi-pod dry-run — no host allocation for 67B-param models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PA:
    """A parameter leaf: value + logical sharding axes (one name per dim,
    None = replicated dim)."""
    value: Any
    axes: tuple[str | None, ...]


def _is_pa(x) -> bool:
    return isinstance(x, PA)


def param_values(tree):
    return jax.tree.map(lambda p: p.value, tree, is_leaf=_is_pa)


def param_axes(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_pa)


class Factory:
    """Creates parameter leaves; real or abstract."""

    def __init__(self, key: jax.Array | None, dtype=jnp.float32, abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def __call__(
        self,
        shape: Sequence[int],
        axes: tuple[str | None, ...],
        *,
        init: str = "normal",
        scale: float = 0.02,
    ) -> PA:
        shape = tuple(int(s) for s in shape)
        assert len(shape) == len(axes), f"shape {shape} vs axes {axes}"
        if self.abstract:
            return PA(jax.ShapeDtypeStruct(shape, self.dtype), tuple(axes))
        if init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        elif init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale else 1.0 / np.sqrt(fan_in)
            v = (jax.random.normal(self._next_key(), shape) * s).astype(self.dtype)
        elif init == "fan_in":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            v = (jax.random.normal(self._next_key(), shape) / np.sqrt(fan_in)).astype(self.dtype)
        else:
            raise ValueError(init)
        return PA(v, tuple(axes))


def make_factory(cfg, key=None, abstract: bool = False) -> Factory:
    dtype = jnp.dtype(cfg.param_dtype)
    return Factory(key, dtype=dtype, abstract=abstract)


# ---------------------------------------------------------------------------
# Logical-axis sharding constraints for activations
# ---------------------------------------------------------------------------

_ACTIVE_RULES: "Rules | None" = None


class Rules:
    """Maps logical axis names -> mesh axis names, with divisibility checks."""

    def __init__(self, mesh, mapping: dict[str, Any]):
        self.mesh = mesh
        self.mapping = mapping  # logical -> mesh axis (str | tuple | None)

    def _axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        n = 1
        for a in mesh_axes:
            n *= self.mesh.shape[a]
        return n

    def spec(self, logical: Sequence[str | None], shape: Sequence[int] | None = None):
        from jax.sharding import PartitionSpec as P

        parts = []
        used: set = set()
        for i, name in enumerate(logical):
            mesh_axes = self.mapping.get(name) if name else None
            if mesh_axes is not None and shape is not None:
                # progressive suffix-drop: if the dim doesn't divide the
                # full axis tuple, retry with trailing axes removed (e.g.
                # global_batch=256 on ("data","model","pod")=512 devices
                # still shards 256-way over ("data","model") instead of
                # replicating outright).
                flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
                while flat and shape[i] % self._axis_size(flat) != 0:
                    flat = flat[:-1]
                mesh_axes = flat or None
            if mesh_axes is not None:
                flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
                if any(a in used for a in flat):
                    mesh_axes = None  # a mesh axis may appear once per spec
                else:
                    used.update(flat)
                    # singleton tuples unwrap to the bare axis name: some
                    # JAX versions don't canonicalize P(("data",)) ==
                    # P("data"), and specs must compare stably
                    mesh_axes = flat[0] if len(flat) == 1 else flat
            parts.append(mesh_axes)
        return P(*parts)

    def __enter__(self):
        global _ACTIVE_RULES
        self._prev = _ACTIVE_RULES
        _ACTIVE_RULES = self
        return self

    def __exit__(self, *exc):
        global _ACTIVE_RULES
        _ACTIVE_RULES = self._prev


def active_rules() -> "Rules | None":
    """The Rules currently in scope (None in plain CPU tests)."""
    return _ACTIVE_RULES


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Apply a sharding constraint if logical rules are active (no-op in
    plain CPU tests)."""
    if _ACTIVE_RULES is None:
        return x
    spec = _ACTIVE_RULES.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_axes(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """``constrain`` taking the logical-axes tuple a param/cache leaf
    already carries (no-op without active rules)."""
    if _ACTIVE_RULES is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACTIVE_RULES.spec(axes, x.shape))


def constrain_tree(tree, axes_tree):
    """Constrain every leaf of ``tree`` to its logical axes under the
    active rules — the whole-pytree form of :func:`constrain_axes`, used
    by the mesh-parametric serving jits to pin cache/state trees to the
    rules' layout (no-op without active rules)."""
    if _ACTIVE_RULES is None:
        return tree
    return jax.tree.map(
        lambda ax, l: constrain_axes(l, ax), axes_tree, tree,
        is_leaf=_is_axes_tuple,
    )


# ---------------------------------------------------------------------------
# chunk-carry protocol (serving chunked prefill)
# ---------------------------------------------------------------------------
#
# Every family exposes a chainable, state-carrying chunk prefill (see
# DESIGN.md §6.2):
#
#   init_chunk_carry(cfg, m, b, cache_len) -> carry
#   chunk_carry_axes(cfg)                  -> logical-axes tree for carry
#   prefill_chunk(cfg, params, batch, carry, offset) -> carry
#
# ``carry`` is a dict holding "cache" (EXACTLY the family's decode
# cache/state tree, so slot surgery consumes it unchanged) plus any
# family extras (moe keeps per-layer expert-usage counts).  ``offset``
# is the (M, B) absolute position of the chunk's first token — families
# with a learned prefix (hybrid meta tokens, vlm image patches) count
# prefix positions in the same stream, substituting prefix embeddings
# for positions below the prefix length.  ``batch["valid"]`` (M, B, C)
# bool, when present, marks the junk suffix of a PADDED final chunk
# (serving tail folding — DESIGN.md §6.3): KV families drop the junk
# cache scatters, moe masks routing, recurrent families make the junk
# steps gate-neutral, so the carry equals the exact-length pass.  The
# helpers below let the serving runtime keep K independent requests
# ("lanes") in ONE carry tree: a (K,) mask selects which lanes actually
# advance each call.


def tree_select_lanes(mask, new_tree, old_tree, axes_tree):
    """Per-lane merge of two carry trees: lane k (along each leaf's
    ``instances`` dim) takes ``new_tree`` where ``mask[k]``, else keeps
    ``old_tree``.  Used by the chunked prefill so one compiled chunk fn
    serves lanes at different prompt offsets — finished/idle lanes ride
    through unchanged."""
    mask = jnp.asarray(mask)

    def _sel(ax, n, o):
        i = ax.index("instances")
        mk = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - i - 1))
        return jnp.where(mk, n, o)

    return jax.tree.map(_sel, axes_tree, new_tree, old_tree,
                        is_leaf=_is_axes_tuple)


def tree_select_slots(mask, new_tree, old_tree, axes_tree):
    """Per-(instance, slot) merge of two grid cache trees: slot (m, b)
    takes ``new_tree`` where ``mask[m, b]``, else keeps ``old_tree``.
    The (M, B) mask lands on each leaf's adjacent ``instances``/``batch``
    dims and broadcasts over the rest.  Used by the multi-step decode
    scan (DESIGN.md §6.6): a lane that hits its stop condition mid-block
    freezes — its cache rows stop advancing while live slots keep
    decoding — so K=1 and K>1 greedy streams are bit-identical."""
    mask = jnp.asarray(mask)

    def _sel(ax, n, o):
        i = ax.index("instances")
        assert ax[i + 1] == "batch", ax   # grid leaves: instances then batch
        mk = mask.reshape((1,) * i + mask.shape + (1,) * (n.ndim - i - 2))
        return jnp.where(mk, n, o)

    return jax.tree.map(_sel, axes_tree, new_tree, old_tree,
                        is_leaf=_is_axes_tuple)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def stack_layer_params(layer_trees: list):
    """Stack per-layer PA-trees along a leading L axis (for lax.scan)."""
    def _stack(*ps):
        vals = [p.value for p in ps]
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals),) + vals[0].shape, vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return PA(v, ("layers",) + ps[0].axes)
    return jax.tree.map(_stack, *layer_trees, is_leaf=_is_pa)


def count_params(params) -> int:
    """Total parameter count (excluding the instances axis)."""
    tot = 0
    for leaf in jax.tree.leaves(params):
        n = int(np.prod(leaf.shape))
        tot += n
    return tot


# ---------------------------------------------------------------------------
# NetFuse merging of whole-model checkpoints
# ---------------------------------------------------------------------------
#
# Layer-stacked leaves are (L, M, ...) while top-level leaves are (M, ...);
# the ``axes`` tree records where the instances axis sits, so merging M
# fine-tuned checkpoints (each built with num_instances=1) stacks each leaf
# at the right position.


def _inst_axis(ax: tuple) -> int:
    return ax.index("instances")


_is_axes_leaf = lambda x: isinstance(x, tuple)


def merge_instances(params_list: list, axes_tree):
    """NetFuse-merge M single-instance checkpoints -> one merged pytree."""
    def _m(ax, *leaves):
        i = _inst_axis(ax)
        return jnp.concatenate(leaves, axis=i)
    return jax.tree.map(_m, axes_tree, *params_list, is_leaf=_is_axes_leaf)


def split_instances(params, axes_tree):
    """Inverse of merge_instances: merged pytree -> list of M=1 pytrees."""
    n = None
    def _probe(ax, leaf):
        nonlocal n
        n = leaf.shape[_inst_axis(ax)]
        return leaf
    jax.tree.map(_probe, axes_tree, params, is_leaf=_is_axes_leaf)
    out = []
    for i in range(n):
        out.append(
            jax.tree.map(
                lambda ax, l, i=i: jnp.take(l, jnp.array([i]), axis=_inst_axis(ax)),
                axes_tree, params, is_leaf=_is_axes_leaf,
            )
        )
    return out


def take_instance(params, axes_tree, i: int):
    """Slice instance i (keeping M=1) from a merged pytree."""
    return jax.tree.map(
        lambda ax, l: jnp.take(l, jnp.array([i]), axis=_inst_axis(ax)),
        axes_tree, params, is_leaf=_is_axes_leaf,
    )


def gather_instances(params, axes_tree, idx):
    """Gather instance rows ``idx`` (k,) from a merged pytree -> a pytree
    whose instances axis is k.  ``idx`` may be traced (jit-friendly); used
    by the serving prefill to batch k requests for k different fine-tuned
    models through ONE fused program (each request rides the instances
    axis — paper §2.1 applied to admission instead of steady-state)."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(
        lambda ax, l: jnp.take(l, idx, axis=_inst_axis(ax)),
        axes_tree, params, is_leaf=_is_axes_leaf,
    )


# ---------------------------------------------------------------------------
# slot surgery on (M, B)-grid trees (KV caches / recurrent states)
# ---------------------------------------------------------------------------
#
# Serving keeps one cache/state tree for the whole (M, B) slot grid; the
# ``cache_axes``/``state_axes`` trees name where the instances/batch dims
# sit on every leaf, so a single pair of helpers covers every family —
# uniform KVCache stacks (dense/moe/vlm/audio) AND the nested recurrent
# state layouts (ssm/hybrid).  Indices may be traced: one jit covers all
# slots.


def _is_axes_tuple(x) -> bool:
    # logical-axes leaves are plain tuples of str/None; NamedTuple pytree
    # nodes (KVCache) must NOT be treated as leaves.
    return isinstance(x, tuple) and not hasattr(x, "_fields")


def tree_take_slot(tree, axes_tree, m, b):
    """Slice grid slot (m, b) from every leaf, keeping singleton dims.

    Shard-safe: when rules are active the sliced singleton leaf is
    re-constrained to its logical axes (the instances/batch dims collapse
    to 1 and replicate via the divisibility guard; other dims — e.g. a
    context-sharded ``cache_seq`` — keep their mesh placement), so slot
    extraction under a mesh never forces a host gather."""
    def _take(ax, leaf):
        i, j = ax.index("instances"), ax.index("batch")
        leaf = jax.lax.dynamic_slice_in_dim(leaf, m, 1, axis=i)
        leaf = jax.lax.dynamic_slice_in_dim(leaf, b, 1, axis=j)
        return constrain_axes(leaf, ax)
    return jax.tree.map(_take, axes_tree, tree, is_leaf=_is_axes_tuple)


def tree_put_slot(grid, axes_tree, one, m, b):
    """Write a single-slot tree (instances=batch=1 dims) into grid slot
    (m, b).  Leaves whose ``cache_seq`` dim is longer/shorter than the
    grid's are prefix-clipped (prefill caches vs. grid context).

    Shard-safe: the updated grid leaf is constrained back to its logical
    axes, so surgery under a mesh preserves every leaf's NamedSharding
    (the dynamic-update lowers to an on-device scatter into the owning
    shards — the grid never round-trips through the host)."""
    def _put(ax, g, o):
        i, j = ax.index("instances"), ax.index("batch")
        if "cache_seq" in ax:
            sa = ax.index("cache_seq")
            s = min(o.shape[sa], g.shape[sa])
            o = jax.lax.slice_in_dim(o, 0, s, axis=sa)
        start = [jnp.int32(0)] * g.ndim
        start[i], start[j] = m, b
        out = jax.lax.dynamic_update_slice(g, o.astype(g.dtype), tuple(start))
        return constrain_axes(out, ax)
    return jax.tree.map(_put, axes_tree, grid, one, is_leaf=_is_axes_tuple)
