"""Dense llama-family decoder (tinyllama, deepseek-67b, granite-3-2b,
qwen1.5-0.5b; also the LM trunk reused by the VLM family).

Fusion-aware: params carry a leading instances axis M; tokens are
(M, B, S) with per-instance batches.  Layer stack runs under lax.scan
over params stacked on a leading L axis.

Entry points:
  forward(cfg, params, tokens)                      -> logits (M,B,S,V)
  prefill(cfg, params, tokens)                      -> (last logits, KVCache)
  decode_step(cfg, params, cache, tokens, pos)      -> (logits, KVCache)

``cfg.sliding_window > 0`` switches every layer to sliding-window
attention (the sub-quadratic variant used for the long_500k shape); the
decode cache is then a ring buffer of window size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory,
    constrain,
    make_factory,
    param_axes,
    param_values,
    stack_layer_params,
)
from repro.models.layers import KVCache


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _layer_params(cfg: ModelConfig, f: Factory):
    m, d, h, kvh, hd, ff = (
        cfg.num_instances, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.head_dim, cfg.d_ff,
    )
    p = {
        "attn_norm": f((m, d), ("instances", None), init="ones"),
        "wq": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wk": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wv": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wo": f((m, h * hd, d), ("instances", "heads_flat", "embed"), init="fan_in"),
        "mlp_norm": f((m, d), ("instances", None), init="ones"),
        "w_gate": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_up": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_down": f((m, ff, d), ("instances", "mlp", "embed"), init="fan_in"),
    }
    if cfg.qkv_bias:
        p["bq"] = f((m, h * hd), ("instances", "heads_flat"), init="zeros")
        p["bk"] = f((m, kvh * hd), ("instances", "kv_flat"), init="zeros")
        p["bv"] = f((m, kvh * hd), ("instances", "kv_flat"), init="zeros")
    return p


def build_params(cfg: ModelConfig, f: Factory):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    layers = stack_layer_params([_layer_params(cfg, f) for _ in range(cfg.num_layers)])
    p = {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "layers": layers,
        "final_norm": f((m, d), ("instances", None), init="ones"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = f((m, d, v), ("instances", "embed", "vocab"), init="fan_in")
    return p


def init(cfg: ModelConfig, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg: ModelConfig):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg: ModelConfig):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_mlp(cfg: ModelConfig, lp, x, positions, *, window, cache=None, decode_pos=None):
    """One transformer block; returns (x, new_cache_layer)."""
    n = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    h, new_cache = L.gqa_attention(
        n, lp,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, window=window, cache=cache, decode_pos=decode_pos,
    )
    x = x + h
    n = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.swiglu_mlp(n, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, new_cache


def _positions(tokens):
    m, b, s = tokens.shape
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))


def _embed_in(cfg, params, tokens):
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    return constrain(x, "instances", "batch", "seq", "act_embed")


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["lm_head"] if not cfg.tie_embeddings else jnp.swapaxes(params["embed"], -1, -2)
    return L.unembed(x, head)


def forward(
    cfg: ModelConfig,
    params,
    tokens,
    *,
    inputs_embeds=None,
    positions=None,
    remat: bool = False,
) -> jax.Array:
    """Full-sequence forward (training / evaluation). Returns (M,B,S,V)."""
    x = _embed_in(cfg, params, tokens) if inputs_embeds is None else inputs_embeds
    positions = _positions(tokens) if positions is None else positions
    window = cfg.sliding_window

    def body(xc, lp):
        out, _ = _attn_mlp(cfg, lp, xc, positions, window=window)
        return out, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["layers"])
    return _logits(cfg, params, x)


def prefill(cfg: ModelConfig, params, tokens, *, cache_len: int | None = None):
    """Process a full prompt; returns (logits for last position, KVCache).

    The returned cache has length ``cache_len`` (defaults to the window
    size for sliding-window models, else the prompt length) and is laid
    out ring-buffer-consistently so decode can continue at pos = S."""
    m, b, s = tokens.shape
    x = _embed_in(cfg, params, tokens)
    positions = _positions(tokens)
    window = cfg.sliding_window
    if cache_len is None:
        cache_len = window if window else s

    def body(xc, lp):
        n = L.rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        # recompute k/v for cache extraction: run attention and also emit k,v
        q = L.linear(n, lp["wq"], lp.get("bq")).reshape(m, b, s, cfg.num_heads, cfg.head_dim)
        k = L.linear(n, lp["wk"], lp.get("bk")).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        v = L.linear(n, lp["wv"], lp.get("bv")).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        o = L.flash_attention(q, k, v, positions, positions, window=window)
        h = L.linear(o.reshape(m, b, s, -1), lp["wo"], lp.get("bo"))
        xc = xc + h
        nn = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = xc + L.swiglu_mlp(nn, lp["w_gate"], lp["w_up"], lp["w_down"])
        if cache_len >= s:
            pad = cache_len - s
            kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            assert s % cache_len == 0, "prompt must be a multiple of the window"
            kc, vc = k[:, :, s - cache_len :], v[:, :, s - cache_len :]
        return xc, (kc.astype(jnp.dtype(cfg.dtype)), vc.astype(jnp.dtype(cfg.dtype)))

    x, (ck, cv) = lax.scan(body, x, params["layers"])
    logits = _logits(cfg, params, x[:, :, -1:])[:, :, 0]
    return logits, KVCache(k=ck, v=cv)


def init_chunk_carry(cfg: ModelConfig, m: int, b: int, cache_len: int):
    return {"cache": make_cache(cfg, m, b, cache_len)}


def chunk_carry_axes(cfg: ModelConfig):
    return {"cache": cache_axes(cfg)}


def prefill_chunk(cfg: ModelConfig, params, batch, carry, offset):
    """One chunk of a state-carrying prefill (serving admission).

    batch["tokens"]: (M,B,C) tokens at absolute positions
    offset..offset+C-1 (offset (M,B) int32, may differ per instance
    row).  The carry's KV cache holds every earlier position; the chunk
    attends over [cache-so-far, chunk] and appends its k/v at the ring
    slots, so any prompt length runs through the same compiled shape.
    batch["valid"] (M,B,C) bool, when present, marks the junk suffix of
    a padded final chunk (tail folding): invalid rows never reach the
    cache, and causality keeps them invisible to the real queries."""
    x = _embed_in(cfg, params, batch["tokens"])
    return _prefill_chunk_embeds(cfg, params, x, carry, offset,
                                 valid=batch.get("valid"))


def _prefill_chunk_embeds(cfg: ModelConfig, params, x, carry, offset, valid=None):
    """Chunk body on precomputed input embeddings (shared with vlm)."""
    from repro.models.common import active_rules, constrain_axes

    cache = carry["cache"]
    m, b, c, _ = x.shape
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)   # (M,B,C)
    window = cfg.sliding_window
    s_cache = cache.k.shape[3]
    # the cache as it stood BEFORE this chunk: ring slots labeled with
    # their absolute positions (-1 = not yet written); chunk keys ride
    # along with their own positions, so one positional mask covers
    # causality + sliding window + ring validity mid-prompt
    before = L.cache_positions_after(offset - 1, s_cache, 0)
    kv_pos = jnp.concatenate([before, positions], axis=-1)
    kv_ax = ("instances", "batch", "cache_seq", "kv_heads", "kv_hd")

    def body(xc, xs):
        lp, ck, cv = xs
        n = L.rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q = L.linear(n, lp["wq"], lp.get("bq")).reshape(m, b, c, cfg.num_heads, cfg.head_dim)
        k = L.linear(n, lp["wk"], lp.get("bk")).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
        v = L.linear(n, lp["wv"], lp.get("bv")).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        k_all = jnp.concatenate([ck, k.astype(ck.dtype)], axis=2)
        v_all = jnp.concatenate([cv, v.astype(cv.dtype)], axis=2)
        if cfg.use_pallas_kernels:
            # Pallas chunk-prefill flash attention: streams the cache S
            # axis through VMEM with online softmax, positions derived
            # in-kernel from the scalar-prefetched lane offsets
            from repro.kernels import ops as K
            o = K.chunk_prefill_attention(
                q, k_all, v_all, offset, s_cache=s_cache, window=window,
                rules=active_rules(),
            )
        else:
            o = L.flash_attention(q, k_all, v_all, positions, kv_pos, window=window)
        xc = xc + L.linear(o.reshape(m, b, c, -1), lp["wo"], lp.get("bo"))
        nn = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = xc + L.swiglu_mlp(nn, lp["w_gate"], lp["w_up"], lp["w_down"])
        # pin the appended cache to its logical layout inside the scan
        # body — without the constraint GSPMD re-derives the kv sharding
        # per iteration and can fall back to full rematerialization
        nk = constrain_axes(L.cache_append_chunk(ck, k, positions, 0, valid), kv_ax)
        nv = constrain_axes(L.cache_append_chunk(cv, v, positions, 0, valid), kv_ax)
        return xc, (nk, nv)

    _, (nk, nv) = lax.scan(body, x, (params["layers"], cache.k, cache.v))
    return {"cache": KVCache(k=nk, v=nv)}


def _decode_layers_fused(cfg: ModelConfig, params, cache: KVCache, x, pos):
    """Megakernel decode body: one Pallas launch per layer
    (kernels/decode_layer.py) — norms, QKV+RoPE, in-kernel ring append,
    flash decode attention, out-proj, SwiGLU all fused over the (M, B)
    grid.  x: (M,B,D) residual; returns (x_out, updated cache)."""
    from repro.kernels import ops as K
    from repro.models.common import active_rules

    rules = active_rules()

    def body(xc, xs):
        lp, ck, cv = xs
        out, nk, nv = K.decode_layer(
            lp, xc, ck, cv, pos, num_heads=cfg.num_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=cfg.sliding_window, eps=cfg.norm_eps, rules=rules,
        )
        return out, (nk, nv)

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache.k, cache.v))
    return x, KVCache(k=nk, v=nv)


def decode_step(cfg: ModelConfig, params, cache: KVCache, tokens, pos):
    """One decode step. tokens (M,B,1); pos (M,B) = index of this token.
    Returns (logits (M,B,V), updated cache)."""
    x = _embed_in(cfg, params, tokens)
    if cfg.use_pallas_kernels:
        x, new_cache = _decode_layers_fused(cfg, params, cache, x[:, :, 0], pos)
        logits = _logits(cfg, params, x[:, :, None])[:, :, 0]
        return logits, new_cache
    positions = pos[..., None]
    window = cfg.sliding_window

    def body(xc, xs):
        lp, ck, cv = xs
        out, new_cache = _attn_mlp(
            cfg, lp, xc, positions, window=window, cache=(ck, cv), decode_pos=pos
        )
        return out, new_cache

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache.k, cache.v))
    logits = _logits(cfg, params, x)[:, :, 0]
    return logits, KVCache(k=nk, v=nv)


def decode_step_sample(cfg: ModelConfig, params, cache: KVCache, tokens, pos):
    """Greedy decode step: returns (next_token (M,B) int32, new cache).

    With ``cfg.use_pallas_kernels`` the final-norm + logits projection +
    argmax collapse into one fused kernel
    (kernels/decode_layer.py::logits_sample), so a steady-state decode
    scan step is ~num_layers + 1 launches; otherwise this is argmax over
    the plain decode_step logits (the two are token-identical)."""
    if cfg.use_pallas_kernels:
        from repro.kernels import ops as K
        from repro.models.common import active_rules

        x = _embed_in(cfg, params, tokens)[:, :, 0]
        x, new_cache = _decode_layers_fused(cfg, params, cache, x, pos)
        head = (
            jnp.swapaxes(params["embed"], -1, -2) if cfg.tie_embeddings
            else params["lm_head"]
        )
        tok = K.logits_sample(x, params["final_norm"], head,
                              eps=cfg.norm_eps, rules=active_rules())
        return tok, new_cache
    logits, new_cache = decode_step(cfg, params, cache, tokens, pos)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache


def make_cache(cfg: ModelConfig, m: int, b: int, context_len: int) -> KVCache:
    s_cache = cfg.sliding_window if cfg.sliding_window else context_len
    return L.make_kv_cache(
        cfg.num_layers, m, b, s_cache, cfg.num_kv_heads, cfg.head_dim,
        jnp.dtype(cfg.dtype),
    )


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    return KVCache(k=ax, v=ax)
