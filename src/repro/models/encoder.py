"""BERT / XLNet-style encoders — the paper's NLP evaluation models (§5.1).

Instance-axis fusion-aware form: all matmuls are instance-batched
(matmul -> batch-matmul merge) and all layer norms are per-instance
normalized (layer-norm -> group-norm merge).  The paper evaluates these
at sequence length 128 with per-task FC heads left unmerged.

The XLNet variant uses Transformer-XL relative-position attention
(content + position terms with the u/v biases and the relative-shift
trick) — the "extra computations" the paper cites when explaining why
the concurrent baseline degrades most on XLNet.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory, make_factory, param_axes, param_values, stack_layer_params,
)


def _layer(cfg: ModelConfig, f: Factory, xlnet: bool):
    m, d, h, hd, ff = (
        cfg.num_instances, cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.d_ff,
    )
    p = {
        "wq": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wk": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wv": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wo": f((m, h * hd, d), ("instances", "heads_flat", "embed"), init="fan_in"),
        "ln1_s": f((m, d), ("instances", None), init="ones"),
        "ln1_b": f((m, d), ("instances", None), init="zeros"),
        "w1": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "b1": f((m, ff), ("instances", "mlp"), init="zeros"),
        "w2": f((m, ff, d), ("instances", "mlp", "embed"), init="fan_in"),
        "b2": f((m, d), ("instances", "embed"), init="zeros"),
        "ln2_s": f((m, d), ("instances", None), init="ones"),
        "ln2_b": f((m, d), ("instances", None), init="zeros"),
    }
    if xlnet:
        p["wr"] = f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in")
        p["u"] = f((m, h, hd), ("instances", "heads", None), init="zeros")
        p["v"] = f((m, h, hd), ("instances", "heads", None), init="zeros")
    return p


def build_params(cfg: ModelConfig, f: Factory, *, xlnet: bool = False):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    max_pos = cfg.max_target_positions or 512
    p = {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "layers": stack_layer_params(
            [_layer(cfg, f, xlnet) for _ in range(cfg.num_layers)]
        ),
    }
    if not xlnet:
        p["pos_embed"] = f((m, max_pos, d), ("instances", None, "embed"))
    return p


def init(cfg, key, *, xlnet=False):
    return param_values(build_params(cfg, make_factory(cfg, key), xlnet=xlnet))


def axes(cfg, *, xlnet=False):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True), xlnet=xlnet))


def _sinusoid_rel(s: int, d: int) -> np.ndarray:
    """Transformer-XL relative positions s-1 .. 0 encoded sinusoidally."""
    pos = np.arange(s - 1, -1, -1)[:, None]
    inv = 1.0 / (10000 ** (np.arange(0, d, 2) / d))
    ang = pos * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _rel_shift(x):
    """(..., S_q, S_k) relative-score shift (Transformer-XL trick)."""
    *lead, sq, sk = x.shape
    x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, 0), (1, 0)])
    x = x.reshape(*lead, sk + 1, sq)
    return x[..., 1:, :].reshape(*lead, sq, sk)


def _attention(cfg, lp, x, *, xlnet: bool, rel_enc=None):
    """Bidirectional MHA at S<=512 (paper setting) — direct S×S scores."""
    m, b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = L.linear(x, lp["wq"]).reshape(m, b, s, h, hd)
    k = L.linear(x, lp["wk"]).reshape(m, b, s, h, hd)
    v = L.linear(x, lp["wv"]).reshape(m, b, s, h, hd)
    if xlnet:
        r = jnp.einsum("sd,mdf->msf", rel_enc, lp["wr"].astype(jnp.float32))
        r = r.reshape(m, s, h, hd)
        ac = jnp.einsum("mbqhd,mbkhd->mbhqk",
                        q + lp["u"][:, None, None].astype(q.dtype), k)
        bd = jnp.einsum("mbqhd,mkhd->mbhqk",
                        q + lp["v"][:, None, None].astype(q.dtype), r.astype(q.dtype))
        scores = (ac + _rel_shift(bd)) / np.sqrt(hd)
    else:
        scores = jnp.einsum("mbqhd,mbkhd->mbhqk", q, k) / np.sqrt(hd)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("mbhqk,mbkhd->mbqhd", p, v).reshape(m, b, s, h * hd)
    return L.linear(o, lp["wo"])


def forward(cfg: ModelConfig, params, tokens, *, xlnet: bool = False):
    """tokens (M,B,S) -> final hidden states (M,B,S,D) (post-LN stack)."""
    m, b, s = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    rel_enc = None
    if xlnet:
        rel_enc = jnp.asarray(_sinusoid_rel(s, cfg.d_model))
    else:
        x = x + params["pos_embed"][:, None, :s].astype(x.dtype)

    def body(xc, lp):
        a = _attention(cfg, lp, xc, xlnet=xlnet, rel_enc=rel_enc)
        xc = L.layer_norm(xc + a, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        f = L.gelu_mlp(xc, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        xc = L.layer_norm(xc + f, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        return xc, None

    x, _ = lax.scan(body, x, params["layers"])
    return x
