"""Hymba-style hybrid-head decoder — hymba-1.5b [arXiv:2411.13676].

Every block runs an attention head-group and a Mamba (selective-SSM)
head-group *in parallel* on the same input; outputs are per-branch
normalized and averaged.  Additional Hymba features implemented:

* **meta tokens** — ``R`` learnable tokens prepended to the sequence,
  visible to every query as attention sinks even under the sliding
  window (flash_attention's ``sink``),
* **SWA/global mix** — layers {0, L/2, L-1} use full attention, the
  rest sliding-window (per-layer window is a *traced* scalar so the
  whole stack still runs under one ``lax.scan`` for train/prefill;
  decode groups layers by cache size: ring buffers for SWA layers,
  full-context caches for the three global layers).

The Mamba branch uses a chunked associative scan over time (TPU
adaptation: the CUDA selective-scan kernel becomes chunk-parallel
prefix products — see DESIGN.md §2).  Sub-quadratic end to end, so this
architecture runs the long_500k shape natively.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory, constrain, make_factory, param_axes, param_values,
    stack_layer_params,
)
from repro.models.layers import KVCache

NUM_META_TOKENS = 128
GLOBAL_WINDOW = 1 << 30  # "no window" sentinel for global-attention layers
DEFAULT_SWA = 1024


def d_inner(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model  # mamba expansion factor 2


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


SSM_HEAD_DIM = 64


def ssm_heads(cfg: ModelConfig) -> int:
    di = d_inner(cfg)
    hd = SSM_HEAD_DIM
    while di % hd:
        hd //= 2
    return di // hd


def global_layers(cfg: ModelConfig) -> set[int]:
    n = cfg.num_layers
    return {0, n // 2, n - 1} if n >= 3 else set(range(n))


def swa_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window if cfg.sliding_window else DEFAULT_SWA


def min_serving_context(cfg: ModelConfig, max_new: int = 0) -> int:
    """Smallest serving max_context for this config: the SWA ring layout
    needs meta tokens + a full window (plus decode headroom)."""
    return NUM_META_TOKENS + swa_window(cfg) + max_new


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32 per-layer window (GLOBAL_WINDOW for global layers)."""
    g = global_layers(cfg)
    w = swa_window(cfg)
    return jnp.array(
        [GLOBAL_WINDOW if i in g else w for i in range(cfg.num_layers)], jnp.int32
    )


def decode_groups(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """Contiguous (start, end, is_global) layer groups for decode."""
    g = global_layers(cfg)
    groups, start = [], 0
    for i in range(1, cfg.num_layers + 1):
        if i == cfg.num_layers or (i in g) != (start in g):
            groups.append((start, i, start in g))
            start = i
    return groups


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _layer_params(cfg: ModelConfig, f: Factory):
    m, d, h, kvh, hd = (
        cfg.num_instances, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
    )
    di, n, r = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    return {
        "norm": f((m, d), ("instances", None), init="ones"),
        # attention branch
        "wq": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wk": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wv": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wo": f((m, h * hd, d), ("instances", "heads_flat", "embed"), init="fan_in"),
        "attn_out_norm": f((m, d), ("instances", None), init="ones"),
        # mamba branch
        "w_ssm_in": f((m, d, 2 * di), ("instances", "embed", "mlp"), init="fan_in"),
        "conv_w": f((m, cfg.conv_kernel, di), ("instances", None, "mlp"), init="fan_in"),
        "conv_b": f((m, di), ("instances", "mlp"), init="zeros"),
        "w_bc": f((m, di, 2 * n), ("instances", "mlp", None), init="fan_in"),
        # SSD (Mamba-2) head-shared decay: dt/A per SSM head, not per
        # channel — the TPU adaptation that turns the selective scan into
        # MXU matmuls (DESIGN.md §Perf / [Dao & Gu 2024]).
        "w_dt": f((m, di, ssm_heads(cfg)), ("instances", "mlp", None), init="fan_in"),
        "b_dt": f((m, ssm_heads(cfg)), ("instances", None), init="zeros"),
        "a_log": f((m, ssm_heads(cfg)), ("instances", None), init="zeros"),
        "d_skip": f((m, di), ("instances", "mlp"), init="ones"),
        "w_ssm_out": f((m, di, d), ("instances", "mlp", "embed"), init="fan_in"),
        "ssm_out_norm": f((m, d), ("instances", None), init="ones"),
        # ffn
        "mlp_norm": f((m, d), ("instances", None), init="ones"),
        "w_gate": f((m, d, cfg.d_ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_up": f((m, d, cfg.d_ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_down": f((m, cfg.d_ff, d), ("instances", "mlp", "embed"), init="fan_in"),
    }


def build_params(cfg: ModelConfig, f: Factory):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    return {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "meta_tokens": f((m, NUM_META_TOKENS, d), ("instances", None, "embed")),
        "layers": stack_layer_params([_layer_params(cfg, f) for _ in range(cfg.num_layers)]),
        "final_norm": f((m, d), ("instances", None), init="ones"),
        "lm_head": f((m, d, v), ("instances", "embed", "vocab"), init="fan_in"),
    }


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# mamba branch
# ---------------------------------------------------------------------------


def _ssd_chunk_scan(u, da, b_in, c_out, h0, *, chunk: int = 64):
    """SSD chunkwise scan (exact, stable — all exponents <= 0).

    u: (M,B,S,H,hd) dt-scaled inputs; da: (M,B,S,H) per-head log decay
    (<= 0); b_in, c_out: (M,B,S,N); h0: (M,B,H,hd,N).
    Returns (y (M,B,S,H,hd), h_final).

    Within a chunk the pairwise decay exp(cum_t - cum_s), s <= t, is a
    (Cs, Cs) matrix PER HEAD (not per channel), so the intra-chunk part
    is two MXU einsums; chunks are linked by a cheap lax.scan carrying
    the (H, hd, N) state.
    """
    m, b, s, h, hd = u.shape
    n = b_in.shape[-1]
    cs = min(chunk, s)
    while s % cs:
        cs -= 1
    nc = s // cs

    uc = u.reshape(m, b, nc, cs, h, hd).astype(jnp.float32)
    dac = da.reshape(m, b, nc, cs, h)
    bc = b_in.reshape(m, b, nc, cs, n).astype(jnp.float32)
    cc = c_out.reshape(m, b, nc, cs, n).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=3)                              # (M,B,nc,Cs,H)
    # pairwise decay L[t,s] = exp(cum_t - cum_s + da_s?) — recurrence
    # h_t = e^{da_t} h_{t-1} + u_t gives weight exp(cum_t - cum_s) for u_s.
    diff = cum[:, :, :, :, None, :] - cum[:, :, :, None, :, :]  # (M,B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((cs, cs), bool))[None, None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)   # (M,B,nc,t,s,H)
    G = jnp.einsum("mbctn,mbcsn->mbcts", cc, bc)               # (M,B,nc,t,s)
    y_intra = jnp.einsum("mbctsh,mbcshd->mbcthd", L * G[..., None], uc)

    # chunk summaries -> inter-chunk state scan
    decay_end = jnp.exp(cum[:, :, :, -1, :])                   # (M,B,nc,H)
    w_end = jnp.exp(cum[:, :, :, -1:, :] - cum)                # (M,B,nc,Cs,H)
    chunk_in = jnp.einsum("mbcsh,mbcshd,mbcsn->mbchdn", w_end, uc, bc)

    def link(hst, xs):
        dec, cin = xs                                          # (M,B,H), (M,B,H,hd,N)
        h_new = dec[..., None, None] * hst + cin
        return h_new, hst                                      # emit state BEFORE chunk

    h_fin, h_starts = lax.scan(
        link, h0.astype(jnp.float32),
        (jnp.moveaxis(decay_end, 2, 0), jnp.moveaxis(chunk_in, 2, 0)),
    )
    h_starts = jnp.moveaxis(h_starts, 0, 2)                    # (M,B,nc,H,hd,N)
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
        "mbchdn,mbctn->mbcthd", h_starts, cc
    )
    y = (y_intra + y_inter).reshape(m, b, s, h, hd)
    return y, h_fin


def mamba_branch(cfg: ModelConfig, lp, xn, *, state=None, valid=None):
    """Selective SSM, SSD (head-shared-decay) form. xn: (M,B,S,D).
    state (decode): {"h": (M,B,Di,N) f32, "conv": (M,B,K-1,Di)}.
    ``valid`` (M,B,S) bool marks the junk suffix of a padded final chunk
    (serving tail folding): junk steps are made gate-neutral (zero decay,
    zero input → h unchanged) and the conv window is gathered at the last
    VALID inputs, so the carried state matches the exact-length pass.
    Returns (out (M,B,S,D), new_state)."""
    m, b, s, d = xn.shape
    di, n = d_inner(cfg), cfg.ssm_state
    nh = ssm_heads(cfg)
    hd = di // nh

    up = L.linear(xn, lp["w_ssm_in"])                          # (M,B,S,2Di)
    xi, z = up[..., :di], up[..., di:]
    conv_state = state["conv"] if state is not None else None
    nvalid = valid.sum(-1).astype(jnp.int32) if valid is not None else None
    xc, new_conv = _conv(xi, lp["conv_w"], lp["conv_b"], conv_state,
                         nvalid=nvalid)
    xc = jax.nn.silu(xc)

    bcp = L.linear(xc, lp["w_bc"]).astype(jnp.float32)         # (M,B,S,2N)
    b_in, c_out = bcp[..., :n], bcp[..., n:]
    dt = jax.nn.softplus(
        L.linear(xc, lp["w_dt"]).astype(jnp.float32)
        + lp["b_dt"][:, None, None, :].astype(jnp.float32)
    )                                                          # (M,B,S,H)
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))              # (M,H)
    da = dt * a[:, None, None, :]                              # (M,B,S,H) <= 0

    xh = xc.reshape(m, b, s, nh, hd).astype(jnp.float32)
    u = dt[..., None] * xh                                     # (M,B,S,H,hd)
    if valid is not None:
        # gate-neutral junk: exp(0)·h + 0 = h — the recurrence skips the
        # padded steps exactly (their y outputs are garbage, discarded)
        da = jnp.where(valid[..., None], da, 0.0)
        u = jnp.where(valid[..., None, None], u, 0.0)

    if state is None or s > 1:
        h0 = (
            state["h"].reshape(m, b, nh, hd, n) if state is not None
            else jnp.zeros((m, b, nh, hd, n), jnp.float32)
        )
        y, h_fin = _ssd_chunk_scan(u, da, b_in, c_out, h0)
        y = y.reshape(m, b, s, di)
    else:
        h0 = state["h"].reshape(m, b, nh, hd, n)
        h_new = (
            jnp.exp(da[:, :, 0])[..., None, None] * h0
            + u[:, :, 0][..., None] * b_in[:, :, 0][:, :, None, None, :]
        )
        y = jnp.einsum("mbhdn,mbn->mbhd", h_new, c_out[:, :, 0])
        y = y.reshape(m, b, 1, di)
        h_fin = h_new

    y = y.astype(xn.dtype) + xc * lp["d_skip"][:, None, None, :].astype(xn.dtype)
    out = L.linear(y * jax.nn.silu(z), lp["w_ssm_out"])
    new_state = {"h": h_fin.reshape(m, b, di, n), "conv": new_conv}
    return out, new_state


def _conv(x, w, bias, conv_state=None, nvalid=None):
    """Depthwise causal conv — the mamba branch shares ssm's cell
    (incl. the tail-folding nvalid window gather), but keeps its own
    no-state short-sequence pad: a stateless call over fewer than K-1
    positions still emits a full (K-1)-deep conv state."""
    from repro.models.ssm import _causal_conv

    k = w.shape[1]
    if conv_state is None and nvalid is None and x.shape[2] < k - 1:
        conv_state = jnp.zeros(x.shape[:2] + (k - 1, x.shape[3]), x.dtype)
    return _causal_conv(x, w, bias, conv_state, nvalid=nvalid)


def mamba_state_shape(cfg, m, b):
    di, n, k = d_inner(cfg), cfg.ssm_state, cfg.conv_kernel
    return {
        "h": ((m, b, di, n), jnp.float32),
        "conv": ((m, b, k - 1, di), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# hybrid block
# ---------------------------------------------------------------------------


def _norm_branch(y, scale, eps):
    return L.rms_norm(y, scale, eps)


def hymba_block(
    cfg, lp, x, positions, window, *,
    kv_cache=None, decode_pos=None, cache_slot=None, cache_kv_pos=None,
    ssm_state=None, kernel_attn: bool = False,
):
    """One hybrid block. window: static int or traced scalar.
    ``kernel_attn`` routes decode attention through the Pallas
    flash-decode kernel (valid only for the plain-ring global-group
    layout — see gqa_attention's use_kernel contract)."""
    xn = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    attn_out, new_kv = L.gqa_attention(
        xn, lp,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, window=window, sink=NUM_META_TOKENS,
        cache=kv_cache, decode_pos=decode_pos,
        cache_slot=cache_slot, cache_kv_pos=cache_kv_pos,
        use_kernel=kernel_attn,
    )
    ssm_out, new_ssm = mamba_branch(cfg, lp, xn, state=ssm_state)
    fused = 0.5 * (
        _norm_branch(attn_out, lp["attn_out_norm"], cfg.norm_eps)
        + _norm_branch(ssm_out, lp["ssm_out_norm"], cfg.norm_eps)
    )
    x = x + fused
    n = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.swiglu_mlp(n, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, new_kv, new_ssm


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _prepend_meta(cfg, params, x):
    m, b, s, d = x.shape
    meta = jnp.broadcast_to(
        params["meta_tokens"][:, None].astype(x.dtype), (m, b, NUM_META_TOKENS, d)
    )
    return jnp.concatenate([meta, x], axis=2)


def _positions(m, b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))


def forward(cfg, params, tokens, *, remat: bool = False):
    """Training forward; logits over the real (non-meta) positions."""
    m, b, s = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    x = _prepend_meta(cfg, params, x)
    positions = _positions(m, b, s + NUM_META_TOKENS)
    windows = layer_windows(cfg)

    def body(xc, xs):
        lp, w = xs
        out, _, _ = hymba_block(cfg, lp, xc, positions, w)
        return out, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, (params["layers"], windows))
    x = x[:, :, NUM_META_TOKENS:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])


def make_cache(cfg, m, b, context_len):
    """Decode caches: per decode-group KV (ring W+meta for SWA, full ctx
    for global) + per-layer mamba states."""
    w = swa_window(cfg)
    kv = []
    for (i0, i1, is_global) in decode_groups(cfg):
        s_cache = context_len if is_global else min(NUM_META_TOKENS + w, context_len)
        kv.append(L.make_kv_cache(
            i1 - i0, m, b, s_cache, cfg.num_kv_heads, cfg.head_dim, jnp.dtype(cfg.dtype)
        ))
    shapes = mamba_state_shape(cfg, m, b)
    ssm_state = {
        k: jnp.zeros((cfg.num_layers,) + sh, dt) for k, (sh, dt) in shapes.items()
    }
    return {"kv": kv, "ssm": ssm_state}


def _swa_slot_positions(pos, s_cache):
    """Slot->absolute-position map for the meta+ring cache layout: slots
    [0, R) hold meta tokens 0..R-1 forever; slots [R, R+W) ring over
    positions >= R.  pos: (M,B) current absolute position (>= R)."""
    r = NUM_META_TOKENS
    w = s_cache - r
    ring = L.cache_slot_positions(pos - r, w)                  # (M,B,w) of pos-r
    ring = jnp.where(ring >= 0, ring + r, -1)
    meta = jnp.broadcast_to(
        jnp.arange(r, dtype=jnp.int32), pos.shape + (r,)
    )
    return jnp.concatenate([meta, ring], axis=-1)


def decode_step(cfg, params, cache, tokens, pos):
    """tokens (M,B,1); pos (M,B) absolute position INCLUDING the meta
    offset (first real token decodes at pos = NUM_META_TOKENS + prompt)."""
    m, b, _ = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    positions = pos[..., None]
    w = swa_window(cfg)
    new_kv, new_ssm = [], {k: [] for k in cache["ssm"]}

    for gi, (i0, i1, is_global) in enumerate(decode_groups(cfg)):
        lp_g = jax.tree.map(lambda t: t[i0:i1], params["layers"])
        ssm_g = jax.tree.map(lambda t: t[i0:i1], cache["ssm"])
        kv_g = cache["kv"][gi]
        s_cache = kv_g.k.shape[3]
        if is_global:
            slot = pos % s_cache
            kv_pos = L.cache_slot_positions(pos, s_cache)
            win = GLOBAL_WINDOW
        else:
            r = NUM_META_TOKENS
            slot = r + (pos - r) % (s_cache - r)
            kv_pos = _swa_slot_positions(pos, s_cache)
            win = w

        # global groups are a plain ring with no effective window, which
        # is exactly the flash-decode kernel's contract; SWA groups keep
        # the meta-pinned XLA path
        kattn = bool(cfg.use_pallas_kernels) and is_global

        def body(xc, xs, win=win, slot=slot, kv_pos=kv_pos, kattn=kattn):
            lp, ck, cv, sh, sconv = xs
            out, nkv, nssm = hymba_block(
                cfg, lp, xc, positions, win,
                kv_cache=(ck, cv), decode_pos=pos,
                cache_slot=slot, cache_kv_pos=kv_pos,
                ssm_state={"h": sh, "conv": sconv}, kernel_attn=kattn,
            )
            return out, (nkv[0], nkv[1], nssm["h"], nssm["conv"])

        x, (nk, nv, nh, nconv) = lax.scan(
            body, x, (lp_g, kv_g.k, kv_g.v, ssm_g["h"], ssm_g["conv"])
        )
        new_kv.append(KVCache(k=nk, v=nv))
        new_ssm["h"].append(nh)
        new_ssm["conv"].append(nconv)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["lm_head"])[:, :, 0]
    new_cache = {
        "kv": new_kv,
        "ssm": {k: jnp.concatenate(v, axis=0) for k, v in new_ssm.items()},
    }
    return logits, new_cache


def prefill(cfg, params, tokens):
    """Prompt pass; returns (last logits, decode cache). The prompt plus
    meta tokens must fit the SWA ring for SWA layers (or be <= context)."""
    m, b, s = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    x = _prepend_meta(cfg, params, x)
    st = s + NUM_META_TOKENS
    positions = _positions(m, b, st)
    w = swa_window(cfg)
    cache = make_cache(cfg, m, b, context_len=max(st, NUM_META_TOKENS + w))
    windows = layer_windows(cfg)

    # run layer-by-layer (python loop) so per-layer k/v can be captured
    # into the heterogeneous group caches; prefill is offline so HLO size
    # is acceptable here.
    groups = decode_groups(cfg)
    new_kv = []
    ssm_h, ssm_conv = [], []
    for gi, (i0, i1, is_global) in enumerate(groups):
        kv_g = cache["kv"][gi]
        ks, vs = [], []
        for li in range(i0, i1):
            lp = jax.tree.map(lambda t: t[li], params["layers"])
            xn = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            q = L.linear(xn, lp["wq"]).reshape(m, b, st, cfg.num_heads, cfg.head_dim)
            kk = L.linear(xn, lp["wk"]).reshape(m, b, st, cfg.num_kv_heads, cfg.head_dim)
            vv = L.linear(xn, lp["wv"]).reshape(m, b, st, cfg.num_kv_heads, cfg.head_dim)
            q = L.rope(q, positions, cfg.rope_theta)
            kk = L.rope(kk, positions, cfg.rope_theta)
            win = GLOBAL_WINDOW if is_global else w
            o = L.flash_attention(
                q, kk, vv, positions, positions, window=win, sink=NUM_META_TOKENS
            )
            attn_out = L.linear(o.reshape(m, b, st, -1), lp["wo"])
            ssm_out, sstate = mamba_branch(cfg, lp, xn)
            fused = 0.5 * (
                _norm_branch(attn_out, lp["attn_out_norm"], cfg.norm_eps)
                + _norm_branch(ssm_out, lp["ssm_out_norm"], cfg.norm_eps)
            )
            x = x + fused
            n = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            x = x + L.swiglu_mlp(n, lp["w_gate"], lp["w_up"], lp["w_down"])
            ssm_h.append(sstate["h"])
            ssm_conv.append(sstate["conv"])
            # place k/v into this group's cache layout
            s_cache = kv_g.k.shape[3]
            if is_global:
                pad = s_cache - st
                kc = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                r = NUM_META_TOKENS
                ring = s_cache - r
                # meta tokens + last `ring` real positions, ring-aligned
                n_real = st - r
                if n_real <= ring:
                    pad = ring - n_real
                    real_k = jnp.pad(kk[:, :, r:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                    real_v = jnp.pad(vv[:, :, r:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                else:
                    # keep last `ring` positions, rotated to ring slots
                    keep_k = kk[:, :, st - ring:]
                    keep_v = vv[:, :, st - ring:]
                    shift = (st - r) % ring
                    real_k = jnp.roll(keep_k, shift, axis=2)
                    real_v = jnp.roll(keep_v, shift, axis=2)
                kc = jnp.concatenate([kk[:, :, :r], real_k], axis=2)
                vc = jnp.concatenate([vv[:, :, :r], real_v], axis=2)
            ks.append(kc.astype(jnp.dtype(cfg.dtype)))
            vs.append(vc.astype(jnp.dtype(cfg.dtype)))
        new_kv.append(KVCache(k=jnp.stack(ks), v=jnp.stack(vs)))

    x = L.rms_norm(x[:, :, -1:], params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["lm_head"])[:, :, 0]
    return logits, {
        "kv": new_kv,
        "ssm": {"h": jnp.stack(ssm_h), "conv": jnp.stack(ssm_conv)},
    }


def init_chunk_carry(cfg, m, b, cache_len):
    return {"cache": make_cache(cfg, m, b, cache_len)}


def chunk_carry_axes(cfg):
    return {"cache": cache_axes(cfg)}


def prefill_chunk(cfg, params, batch, carry, offset):
    """One chunk of a state-carrying Hymba prefill — the mid-prompt
    chaining of the meta-token + SWA-ring caches that exact-length
    prefill couldn't do (old serving limitation).

    Positions [0, R) are the meta tokens (their embeddings come from
    ``params["meta_tokens"]``, the chunk's token ids there are ignored);
    prompt tokens follow at R+i.  Per decode group, chunk queries attend
    over [group cache before this chunk, chunk k/v] with one positional
    mask (causality + per-group window + ring validity + meta sink), so
    a ring slot overwritten by this chunk is still visible to exactly
    the chunk queries that precede the overwriting position.  Mamba
    states thread through ``mamba_branch(state=...)`` as in decode."""
    from repro.models.common import active_rules, constrain_axes

    tokens = batch["tokens"]
    cache = carry["cache"]
    valid = batch.get("valid")            # (M,B,C) tail-folding junk mask
    m, b, c = tokens.shape
    r = NUM_META_TOKENS
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)   # (M,B,C)
    tok_x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    midx = jnp.clip(positions, 0, r - 1)
    meta_x = jax.vmap(lambda t, i: jnp.take(t, i, axis=0))(
        params["meta_tokens"], midx.reshape(m, b * c)
    ).reshape(m, b, c, -1).astype(tok_x.dtype)
    x = jnp.where((positions < r)[..., None], meta_x, tok_x)
    w = swa_window(cfg)
    kv_ax = ("instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    new_kv, new_ssm = [], {k: [] for k in cache["ssm"]}

    for gi, (i0, i1, is_global) in enumerate(decode_groups(cfg)):
        lp_g = jax.tree.map(lambda t: t[i0:i1], params["layers"])
        ssm_g = jax.tree.map(lambda t: t[i0:i1], cache["ssm"])
        kv_g = cache["kv"][gi]
        s_cache = kv_g.k.shape[3]
        pin = 0 if is_global else r
        win = GLOBAL_WINDOW if is_global else w
        before = L.cache_positions_after(offset - 1, s_cache, pin)
        kv_pos = jnp.concatenate([before, positions], axis=-1)

        def body(xc, xs, win=win, pin=pin, kv_pos=kv_pos, s_cache=s_cache):
            lp, ck, cv, sh, sconv = xs
            xn = L.rms_norm(xc, lp["norm"], cfg.norm_eps)
            q = L.linear(xn, lp["wq"]).reshape(m, b, c, cfg.num_heads, cfg.head_dim)
            kk = L.linear(xn, lp["wk"]).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
            vv = L.linear(xn, lp["wv"]).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
            q = L.rope(q, positions, cfg.rope_theta)
            kk = L.rope(kk, positions, cfg.rope_theta)
            k_all = jnp.concatenate([ck, kk.astype(ck.dtype)], axis=2)
            v_all = jnp.concatenate([cv, vv.astype(cv.dtype)], axis=2)
            if cfg.use_pallas_kernels:
                # the group's window/pin are static (groups are a python
                # loop), so the Pallas chunk-prefill kernel derives the
                # causal+window+ring+sink mask from the lane offsets alone
                from repro.kernels import ops as K
                o = K.chunk_prefill_attention(
                    q, k_all, v_all, offset, s_cache=s_cache, pin=pin,
                    window=win, sink=r, rules=active_rules(),
                )
            else:
                o = L.flash_attention(
                    q, k_all, v_all, positions, kv_pos, window=win, sink=r,
                )
            attn_out = L.linear(o.reshape(m, b, c, -1), lp["wo"])
            ssm_out, nssm = mamba_branch(
                cfg, lp, xn, state={"h": sh, "conv": sconv}, valid=valid
            )
            fused = 0.5 * (
                _norm_branch(attn_out, lp["attn_out_norm"], cfg.norm_eps)
                + _norm_branch(ssm_out, lp["ssm_out_norm"], cfg.norm_eps)
            )
            xc = xc + fused
            n = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
            xc = xc + L.swiglu_mlp(n, lp["w_gate"], lp["w_up"], lp["w_down"])
            nk = constrain_axes(L.cache_append_chunk(ck, kk, positions, pin, valid), kv_ax)
            nv = constrain_axes(L.cache_append_chunk(cv, vv, positions, pin, valid), kv_ax)
            return xc, (nk, nv, nssm["h"], nssm["conv"])

        x, (nk, nv, nh, nconv) = lax.scan(
            body, x, (lp_g, kv_g.k, kv_g.v, ssm_g["h"], ssm_g["conv"])
        )
        new_kv.append(KVCache(k=nk, v=nv))
        new_ssm["h"].append(nh)
        new_ssm["conv"].append(nconv)

    return {"cache": {
        "kv": new_kv,
        "ssm": {k: jnp.concatenate(v, axis=0) for k, v in new_ssm.items()},
    }}


def cache_abstract(cfg, m, b, context_len):
    """ShapeDtypeStruct cache (for the dry-run input specs)."""
    real = make_cache.__wrapped__ if hasattr(make_cache, "__wrapped__") else None
    c = jax.eval_shape(lambda: make_cache(cfg, m, b, context_len))
    return c


def cache_axes(cfg):
    ax = ("layers", "instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    return {
        "kv": [KVCache(k=ax, v=ax) for _ in decode_groups(cfg)],
        "ssm": {
            "h": ("layers", "instances", "batch", "mlp", None),
            "conv": ("layers", "instances", "batch", None, "mlp"),
        },
    }


def take_state(cfg, cache, m, b):
    """Slice slot (m, b) out of the (M, B) hybrid cache (KV group caches
    + per-layer mamba states), keeping singleton dims.  The SWA ring and
    global caches keep their layouts, so a slot extracted here drops back
    in with put_state without re-rotation."""
    from repro.models.common import tree_take_slot
    return tree_take_slot(cache, cache_axes(cfg), m, b)


def put_state(cfg, grid, one, m, b):
    """Write a single-slot hybrid cache into grid slot (m, b).  KV leaves
    with a different cache_seq length are prefix-clipped (a per-request
    prefill cache may be shorter than the serving grid's context)."""
    from repro.models.common import tree_put_slot
    return tree_put_slot(grid, cache_axes(cfg), one, m, b)
