"""Fusion-aware neural-net primitives.

All ops here are the NetFuse *input-weight-local* counterparts operating
in instance-axis form: activations ``(M, B, ...)``, weights with leading
``M``.  With M=1 they reduce to the ordinary ops; with M>1 each instance's
inputs only ever touch that instance's weights (paper §3.1).

Attention is a chunked online-softmax ("flash") implementation: queries
are processed in static chunks (python loop at trace time), keys/values
streamed with ``lax.scan`` — S×S score matrices are never materialized,
which is what makes the 32k-prefill and 512k-decode shapes lowerable.
Masking is positional: ``q_pos``/``kv_pos`` arrays encode causality,
sliding windows and ring-buffer cache validity in one rule.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import fused_ops
from repro.models.common import active_rules, constrain

# ---------------------------------------------------------------------------
# basic ops
# ---------------------------------------------------------------------------


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Merged matmul: x (M, ..., D) @ w (M, D, F)  [+ b (M, F)]."""
    y = jnp.einsum("m...d,mdf->m...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype).reshape(b.shape[0], *([1] * (y.ndim - 2)), b.shape[-1])
    return y


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """x (M, ..., D), scale (M, D). Stats in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    m, d = scale.shape
    s = scale.astype(jnp.float32).reshape((m,) + (1,) * (x.ndim - 2) + (d,))
    return (y * s).astype(x.dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array | None, eps: float = 1e-5
) -> jax.Array:
    """Merged layer norm == group norm with G=M (instance-axis form)."""
    xf = x.astype(jnp.float32)
    y = fused_ops.merged_layer_norm(
        xf, scale.astype(jnp.float32),
        bias.astype(jnp.float32) if bias is not None else None, eps=eps,
    )
    return y.astype(x.dtype)


def embed(ids: jax.Array, table: jax.Array, dtype) -> jax.Array:
    """ids (M, B, S), table (M, V, D) -> (M, B, S, D)."""
    return fused_ops.merged_embedding(ids, table).astype(dtype)


def unembed(x: jax.Array, table_or_head: jax.Array) -> jax.Array:
    """Logits in f32: x (M,B,S,D), head (M,D,V) -> (M,B,S,V)."""
    return jnp.einsum(
        "mbsd,mdv->mbsv", x.astype(jnp.float32), table_or_head.astype(jnp.float32)
    )


def swiglu_mlp(x, wg, wu, wd):
    h = jax.nn.silu(linear(x, wg)) * linear(x, wu)
    h = constrain(h, "instances", "batch", None, "mlp")
    return linear(h, wd)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(linear(x, w1, b1))
    h = constrain(h, "instances", "batch", None, "mlp")
    return linear(h, w2, b2)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x (M,B,S,H,hd), pos (M,B,S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs          # (M,B,S,half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)          # (M,B,S,1,half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pick_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    window: int | jax.Array = 0,
    sink: int = 0,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """GQA attention without materializing S_q x S_kv.

    q: (M,B,Sq,H,hd); k,v: (M,B,Skv,KVH,hd); q_pos: (M,B,Sq) int32;
    kv_pos: (M,B,Skv) int32 with -1 marking invalid (empty cache) slots.
    Mask: valid & (kv_pos <= q_pos if causal) & (q_pos - kv_pos < window
    if window).  ``window`` may be a traced scalar (per-layer windows under
    lax.scan — hybrid models); ``sink`` exempts the first ``sink``
    positions from the window (attention sinks / Hymba meta tokens).

    Distribution (§Perf qwen1.5-prefill iterations): when sharding rules
    are active and Sq>1, the chunked streaming runs under ``jax.shard_map``
    over (batch axes, q-heads->"model") — GSPMD replicates while-loop
    operands whose head dims are sharded (every scan/slice formulation we
    tried re-gathered the KV per loop), so the scan must be device-local.
    KV heads ride along sharded when KVH divides the axis; otherwise each
    rank slices the kv-head group(s) backing its local q heads.  Decode
    (Sq=1) instead relies on GSPMD with the context-sharded cache: one KV
    block, softmax stats combined with tiny all-reduces.
    """
    rules = active_rules()
    m, b, sq, h, hd = q.shape
    kvh = k.shape[3]
    g = h // kvh
    if rules is not None and sq > 1:
        nm = dict(rules.mesh.shape).get("model", 1)
        h_l = h // nm if h % nm == 0 else 0
        aligned = h_l > 0 and (g % h_l == 0 or h_l % g == 0)
        q_spec = rules.spec(("instances", "batch", None, "heads", None), q.shape)
        if aligned and q_spec[3] == "model":
            kv_div = kvh % nm == 0
            if not kv_div:
                # Expand KV to query heads so the head dim shards fully
                # local (replicating whole KV per rank costs more HBM than
                # the g-fold expansion sliced 1/nm ways: per-rank bytes go
                # kvh·hd -> h_l·hd, a win whenever h_l < kvh·nm ... i.e.
                # always, since h_l·nm = h = g·kvh ≥ kvh).
                k = jnp.repeat(k, g, axis=3)
                v = jnp.repeat(v, g, axis=3)
            kv_spec = rules.spec(
                ("instances", "batch", None,
                 "heads" if not kv_div else "kv_heads", None),
                k.shape,
            )
            pos_spec = rules.spec(("instances", "batch", None), q_pos.shape)

            def body(q_l, k_l, v_l, qp_l, kp_l):
                return _flash_body(
                    q_l, k_l, v_l, qp_l, kp_l, window=window, sink=sink,
                    causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
                )

            return jax.shard_map(
                body, mesh=rules.mesh,
                in_specs=(q_spec, kv_spec, kv_spec, pos_spec, pos_spec),
                out_specs=q_spec, check_vma=False,
            )(q, k, v, q_pos, kv_pos)
    return _flash_body(
        q, k, v, q_pos, kv_pos, window=window, sink=sink, causal=causal,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )


def _flash_body(
    q, k, v, q_pos, kv_pos, *, window, sink, causal, q_chunk, kv_chunk
) -> jax.Array:
    """Chunked online-softmax attention on (possibly shard_map-local)
    arrays — see flash_attention."""
    m, b, sq, h, hd = q.shape
    skv, kvh = k.shape[2], k.shape[3]
    g = h // kvh
    use_window = isinstance(window, jax.Array) or window > 0
    qc = _pick_chunk(sq, q_chunk)
    # Single-token decode: one KV block over the whole cache.  The scan's
    # per-chunk dynamic-slice would otherwise walk the cache's context dim,
    # which is sharded over "model" (cache_seq rule) — GSPMD can't partition
    # a loop-varying slice of a sharded dim and would all-gather the KV
    # every chunk (§Perf tinyllama-decode iteration).  With one block, the
    # score/attend einsums contract the *local* context shard and GSPMD
    # combines the softmax stats with tiny all-reduces.  At Sq=1 the score
    # tensor is only (M,B,H,Skv) so nothing needs streaming.
    kc = skv if sq == 1 else _pick_chunk(skv, kv_chunk)
    n_q, n_kv = sq // qc, skv // kc
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(m, b, sq, kvh, g, hd)
    # Pre-chunk the KV stream once.  This body runs either with no rules
    # (plain CPU tests) or as the LOCAL program inside flash_attention's
    # shard_map — never under GSPMD with sharded head dims, where every
    # chunked formulation we tried (per-q-chunk slices, in-body
    # dynamic-slice, shared xs, nested scan) re-gathered or replicated the
    # KV per while loop (§Perf qwen1.5-prefill iterations).
    k_ch = k.reshape(m, b, n_kv, kc, kvh, hd)
    v_ch = v.reshape(m, b, n_kv, kc, kvh, hd)
    kp_ch = kv_pos.reshape(m, b, n_kv, kc)

    out_chunks = []
    for qi in range(n_q):
        q_blk = qg[:, :, qi * qc : (qi + 1) * qc]              # (M,B,qc,KVH,G,hd)
        qp_blk = q_pos[:, :, qi * qc : (qi + 1) * qc]          # (M,B,qc)
        # causal block skip: kv chunks beyond this q chunk can't attend.
        n_need = n_kv if not causal or sq == 1 or n_q == 1 else min(
            n_kv, ((qi + 1) * qc + kc - 1) // kc
        )

        def kv_step(carry, xs, q_blk=q_blk, qp_blk=qp_blk):
            m_prev, l_prev, acc = carry
            k_blk, v_blk, kp_blk = xs                          # (M,B,kc,KVH,hd), .., (M,B,kc)
            s = jnp.einsum(
                "mbqkgd,mbckd->mbkgqc", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale                                          # (M,B,KVH,G,qc,kc)
            valid = (kp_blk >= 0)[:, :, None, :]               # (M,B,1,kc)
            if causal:
                valid = valid & (kp_blk[:, :, None, :] <= qp_blk[:, :, :, None])
            if use_window:
                in_win = (
                    qp_blk[:, :, :, None] - kp_blk[:, :, None, :] < window
                )
                if sink > 0:
                    in_win = in_win | (kp_blk[:, :, None, :] < sink)
                valid = valid & in_win
            mask = valid[:, :, None, None, :, :]               # (M,B,1,1,qc|1,kc)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))        # (M,B,KVH,G,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "mbkgqc,mbckd->mbkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        init = (
            jnp.full((m, b, kvh, g, qc), NEG_INF, jnp.float32),
            jnp.zeros((m, b, kvh, g, qc), jnp.float32),
            jnp.zeros((m, b, kvh, g, qc, hd), jnp.float32),
        )
        xs = (
            jnp.moveaxis(k_ch[:, :, :n_need], 2, 0),
            jnp.moveaxis(v_ch[:, :, :n_need], 2, 0),
            jnp.moveaxis(kp_ch[:, :, :n_need], 2, 0),
        )
        (m_f, l_f, acc), _ = lax.scan(kv_step, init, xs)
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]           # (M,B,KVH,G,qc,hd)
        out_chunks.append(jnp.moveaxis(o, -2, 2))              # (M,B,qc,KVH,G,hd)
    out = jnp.concatenate(out_chunks, axis=2) if n_q > 1 else out_chunks[0]
    return out.reshape(m, b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Ring-buffer KV cache for one layer stack.

    k, v: (L, M, B, S_cache, KVH, hd).  ``S_cache`` is the full context
    for dense attention or the window size for sliding-window attention.
    Absolute positions of slots are reconstructed arithmetically from the
    decode position, so no position array is stored.
    """
    k: jax.Array
    v: jax.Array


def make_kv_cache(
    num_layers: int, m: int, b: int, s_cache: int, kvh: int, hd: int, dtype
) -> KVCache:
    shape = (num_layers, m, b, s_cache, kvh, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_slot_positions(pos: jax.Array, s_cache: int) -> jax.Array:
    """Absolute position held by each ring-buffer slot *after* writing the
    token at ``pos`` into slot ``pos % s_cache``.

    pos: (M,B) int32 -> (M,B,S_cache) int32, -1 where the slot is empty.
    """
    slots = jnp.arange(s_cache, dtype=jnp.int32)
    cur = pos[..., None] % s_cache
    base = pos[..., None] - cur                      # start of current wrap
    p = jnp.where(slots <= cur, base + slots, base - s_cache + slots)
    return jnp.where(p >= 0, p, -1)


def cache_positions_after(last_pos: jax.Array, s_cache: int, pin: int = 0) -> jax.Array:
    """Slot -> absolute-position map after writing every position up to
    ``last_pos`` (inclusive; -1 = nothing written yet), for a cache whose
    first ``pin`` slots are pinned (slot j holds position j forever —
    Hymba meta tokens) and whose remaining ``s_cache - pin`` slots ring
    over positions >= pin (``pin=0`` is the plain ring/full layout of
    :func:`cache_slot_positions`).

    last_pos: (M,B) int32 -> (M,B,S_cache) int32, -1 marking empty slots.
    This is the *mid-prompt* generalization of ``cache_slot_positions``:
    the chunked prefill uses it to label the cache as it stood BEFORE the
    chunk being processed (``last_pos = offset - 1``), including while
    the pinned prefix itself is still being filled.
    """
    slots = jnp.arange(s_cache, dtype=jnp.int32)
    last = last_pos[..., None]
    w = s_cache - pin
    pinned = jnp.where(slots <= last, slots, -1)
    if w <= 0:
        return pinned
    q = last - pin                                   # ring-relative last
    cur = q % w                                      # garbage when q < 0 (masked)
    base = q - cur
    i = slots - pin
    p = jnp.where(i <= cur, base + i, base - w + i) + pin
    ring = jnp.where((q >= 0) & (p >= pin), p, -1)
    return jnp.where(slots < pin, pinned, ring)


def cache_append_chunk(
    cache_layer: jax.Array, new: jax.Array, positions: jax.Array, pin: int = 0,
    valid: jax.Array | None = None,
) -> jax.Array:
    """Write a chunk of k/v rows into their cache slots.

    cache_layer: (M,B,S,KVH,hd); new: (M,B,C,KVH,hd); positions: (M,B,C)
    absolute positions.  Slot rule matches :func:`cache_positions_after`:
    position p lands at slot p when p < pin, else pin + (p - pin) % W.
    Positions inside one chunk must map to distinct slots (the serving
    runtime clamps the chunk size to the ring width), so the scatter has
    no duplicate indices.

    ``valid`` (M,B,C) bool masks the scatter: invalid rows (the junk
    suffix of a padded final chunk — tail folding) are routed to an
    out-of-range slot and dropped, so padding can neither occupy fresh
    slots nor wrap the ring over live entries.
    """
    m, b, s, kvh, hd = cache_layer.shape
    c = new.shape[2]
    w = max(s - pin, 1)
    slots = jnp.where(positions < pin, positions, pin + (positions - pin) % w)
    if valid is not None:
        slots = jnp.where(valid, slots, s)

    def upd(cl, x, sl):
        return cl.at[sl].set(x, mode="drop")

    out = jax.vmap(upd)(
        cache_layer.reshape(m * b, s, kvh, hd),
        new.astype(cache_layer.dtype).reshape(m * b, c, kvh, hd),
        slots.reshape(m * b, c).astype(jnp.int32),
    )
    return out.reshape(m, b, s, kvh, hd)


def cache_update_one(
    cache_k_layer: jax.Array,
    cache_v_layer: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    slot: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Insert one token's k/v at slot pos % S (or an explicit slot) for
    every (m, b).

    cache_*_layer: (M,B,S,KVH,hd); k_new/v_new: (M,B,1,KVH,hd); pos: (M,B).
    """
    m, b, s, kvh, hd = cache_k_layer.shape
    if slot is None:
        slot = pos % s
    slot = slot.astype(jnp.int32)

    rules = active_rules()
    if rules is not None:
        return _cache_update_sharded(
            rules, cache_k_layer, cache_v_layer, k_new, v_new, slot
        )

    def upd(c, x, i):
        return lax.dynamic_update_slice(c, x, (i, 0, 0))

    ck = jax.vmap(upd)(
        cache_k_layer.reshape(m * b, s, kvh, hd),
        k_new.astype(cache_k_layer.dtype).reshape(m * b, 1, kvh, hd),
        slot.reshape(m * b),
    ).reshape(m, b, s, kvh, hd)
    cv = jax.vmap(upd)(
        cache_v_layer.reshape(m * b, s, kvh, hd),
        v_new.astype(cache_v_layer.dtype).reshape(m * b, 1, kvh, hd),
        slot.reshape(m * b),
    ).reshape(m, b, s, kvh, hd)
    return ck, cv


def _cache_update_sharded(rules, ck, cv, k_new, v_new, slot):
    """Ring-buffer insert when the cache's context dim is sharded
    (cache_seq -> "model", §Perf tinyllama-decode iteration).

    A dynamic-update-slice along a sharded dim with a data-dependent slot
    would make GSPMD replicate the whole cache; instead each device checks
    whether the slot falls inside its local context shard and does a local
    DUS (no collectives beyond broadcasting the 1-token k/v)."""
    m, b, s, kvh, hd = ck.shape
    cache_logical = ("instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    cache_spec = rules.spec(cache_logical, ck.shape)
    new_spec = rules.spec(("instances", "batch", None, None, None), k_new.shape)
    slot_spec = rules.spec(("instances", "batch"), slot.shape)
    seq_axes = cache_spec[2]  # mesh axes carrying the context dim (or None)
    seq_axes = (
        (seq_axes,) if isinstance(seq_axes, str) else tuple(seq_axes or ())
    )

    def body(ck_l, cv_l, kn_l, vn_l, slot_l):
        s_l = ck_l.shape[2]
        shard = jnp.int32(0)
        for a in seq_axes:
            shard = shard * rules.mesh.shape[a] + lax.axis_index(a)
        start = shard * s_l
        loc = slot_l - start                       # (m_l, b_l) local slot
        ok = (loc >= 0) & (loc < s_l)
        idx = jnp.clip(loc, 0, s_l - 1).reshape(-1)
        ok = ok.reshape(-1)
        m_l, b_l = ck_l.shape[0], ck_l.shape[1]

        def upd(c, x, i, o):
            cur = lax.dynamic_slice(c, (i, 0, 0), (1,) + c.shape[1:])
            neww = jnp.where(o, x, cur)
            return lax.dynamic_update_slice(c, neww, (i, 0, 0))

        outs = []
        for c_l, x_l in ((ck_l, kn_l), (cv_l, vn_l)):
            r = jax.vmap(upd)(
                c_l.reshape(m_l * b_l, s_l, *c_l.shape[3:]),
                x_l.astype(c_l.dtype).reshape(m_l * b_l, 1, *c_l.shape[3:]),
                idx, ok,
            )
            outs.append(r.reshape(c_l.shape))
        return outs[0], outs[1]

    return jax.shard_map(
        body, mesh=rules.mesh,
        in_specs=(cache_spec, cache_spec, new_spec, new_spec, slot_spec),
        out_specs=(cache_spec, cache_spec),
        check_vma=False,
    )(ck, cv, k_new, v_new, slot)


# ---------------------------------------------------------------------------
# full GQA attention block (projection + rope + attention + out-proj)
# ---------------------------------------------------------------------------


def gqa_attention(
    x: jax.Array,
    p: dict,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    positions: jax.Array,
    window: int | jax.Array = 0,
    sink: int = 0,
    causal: bool = True,
    cache: tuple[jax.Array, jax.Array] | None = None,
    decode_pos: jax.Array | None = None,
    cache_slot: jax.Array | None = None,
    cache_kv_pos: jax.Array | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
    use_kernel: bool = False,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """Merged multi-instance GQA attention.

    x: (M,B,S,D). ``p`` holds wq (M,D,H*hd), wk/wv (M,D,KVH*hd),
    wo (M,H*hd,D) and optional bq/bk/bv.  Three modes:

    * train/prefill: cache is None — self-attention over x.
    * decode: cache=(k,v) for this layer, decode_pos (M,B) — S must be 1;
      returns the updated cache.
    * cross-attention: kv_override provides precomputed (k, v).
    """
    m, b, s, d = x.shape
    h, kvh, hd = num_heads, num_kv_heads, head_dim

    # constrain the FLAT projections before the head-split reshape: going
    # straight from a seq-sharded residual to a head-sharded 5-d tensor
    # trips SPMD's resharding fallback (full rematerialization); gathering
    # seq on the flat matmul output is the Megatron-SP transition point.
    q = constrain(
        linear(x, p["wq"], p.get("bq")), "instances", "batch", None, "heads_flat"
    ).reshape(m, b, s, h, hd)
    if kv_override is None:
        k = constrain(
            linear(x, p["wk"], p.get("bk")), "instances", "batch", None, "kv_flat"
        ).reshape(m, b, s, kvh, hd)
        v = constrain(
            linear(x, p["wv"], p.get("bv")), "instances", "batch", None, "kv_flat"
        ).reshape(m, b, s, kvh, hd)
    else:
        k, v = kv_override
    q = constrain(q, "instances", "batch", None, "heads", None)
    k = constrain(k, "instances", "batch", None, "kv_heads", None)
    v = constrain(v, "instances", "batch", None, "kv_heads", None)

    if rope_theta > 0 and kv_override is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    elif rope_theta > 0:
        q = rope(q, positions, rope_theta)

    new_cache = None
    if cache is not None:
        assert s == 1 and decode_pos is not None
        ck, cv = cache_update_one(cache[0], cache[1], k, v, decode_pos, slot=cache_slot)
        new_cache = (ck, cv)
        s_cache = ck.shape[2]
        kv_pos = (
            cache_kv_pos if cache_kv_pos is not None
            else cache_slot_positions(decode_pos, s_cache)
        )                                                      # (M,B,S_cache)
        q_pos = decode_pos[..., None]                          # (M,B,1)
        if (
            use_kernel
            and not isinstance(window, jax.Array)
            and (window <= 0 or window >= s_cache)
        ):
            # plain-ring no-window decode (window >= S includes hybrid's
            # GLOBAL_WINDOW sentinel — the mask never bites once the
            # ring itself caps history at S): slots [0, min(pos+1, S))
            # are exactly the valid set, which is the flash-decode
            # kernel's kv_len prefix contract (kernels/decode_attn.py).
            # use_kernel=True is the caller asserting the plain-ring
            # layout (slot = pos % S, kv positions = slot positions)
            from repro.kernels import ops as _K
            from repro.models.common import active_rules

            kv_len = jnp.minimum(decode_pos + 1, s_cache).astype(jnp.int32)
            o = _K.decode_attention(
                q[:, :, 0], ck, cv, kv_len, rules=active_rules()
            )[:, :, None]
        else:
            o = flash_attention(
                q, ck, cv, q_pos, kv_pos, window=window, sink=sink, causal=True
            )
    else:
        q_pos = positions
        if kv_override is not None:
            kv_pos = jnp.broadcast_to(
                jnp.arange(k.shape[2], dtype=jnp.int32), (m, b, k.shape[2])
            )
        else:
            kv_pos = positions
        o = flash_attention(
            q, k, v, q_pos, kv_pos, window=window, sink=sink, causal=causal
        )

    o = o.reshape(m, b, s, h * hd)
    out = linear(o, p["wo"], p.get("bo"))
    out = constrain(out, "instances", "batch", "seq", "act_embed")
    return out, new_cache
