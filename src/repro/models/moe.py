"""Token-choice top-k MoE decoder (olmoe-1b-7b, qwen3-moe-30b-a3b).

Fusion-aware like :mod:`repro.models.dense`.  NetFuse applicability
(DESIGN.md §4): the merged model is a *block-diagonal* MoE — instance m's
router only ever routes to instance m's E experts, which is exactly the
grouped-op structure of the paper generalized to E-way grouped weights;
merging M instances yields M*E experts in M routing groups.

Dispatch is sort-based with per-(instance, batch-row) token groups and a
static capacity (C = ceil(S*K/E * capacity_factor)):

  1. router top-k -> expert ids per token,
  2. per row: sort assignments by expert, position-in-expert via
     searchsorted segment starts (no T×E one-hot tensors — those would
     dominate HLO FLOPs/bytes and poison the roofline),
  3. scatter into a (E, C, D) buffer, batched expert einsum (this is the
     all-to-all producer under expert-parallel sharding),
  4. gather back, weight by router probs, scatter-add per token.

Tokens beyond capacity are dropped (standard capacity-factor semantics);
tests check zero drops at cf >= 1 with uniform routing and exact
per-instance isolation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory, active_rules, constrain, make_factory, param_axes, param_values,
    stack_layer_params,
)
from repro.models.layers import KVCache


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _layer_params(cfg: ModelConfig, f: Factory):
    m, d, h, kvh, hd = (
        cfg.num_instances, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
    )
    e, ff = cfg.num_experts, cfg.d_ff
    p = {
        "attn_norm": f((m, d), ("instances", None), init="ones"),
        "wq": f((m, d, h * hd), ("instances", "embed", "heads_flat"), init="fan_in"),
        "wk": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wv": f((m, d, kvh * hd), ("instances", "embed", "kv_flat"), init="fan_in"),
        "wo": f((m, h * hd, d), ("instances", "heads_flat", "embed"), init="fan_in"),
        "mlp_norm": f((m, d), ("instances", None), init="ones"),
        "router": f((m, d, e), ("instances", "embed", None), init="fan_in"),
        "we_gate": f((m, e, d, ff), ("instances", "experts", "embed", "expert_mlp"), init="fan_in"),
        "we_up": f((m, e, d, ff), ("instances", "experts", "embed", "expert_mlp"), init="fan_in"),
        "we_down": f((m, e, ff, d), ("instances", "experts", "expert_mlp", "embed"), init="fan_in"),
    }
    if cfg.qkv_bias:
        p["bq"] = f((m, h * hd), ("instances", "heads_flat"), init="zeros")
        p["bk"] = f((m, kvh * hd), ("instances", "kv_flat"), init="zeros")
        p["bv"] = f((m, kvh * hd), ("instances", "kv_flat"), init="zeros")
    return p


def build_params(cfg: ModelConfig, f: Factory):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    return {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "layers": stack_layer_params([_layer_params(cfg, f) for _ in range(cfg.num_layers)]),
        "final_norm": f((m, d), ("instances", None), init="ones"),
        "lm_head": f((m, d, v), ("instances", "embed", "vocab"), init="fan_in"),
    }


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def capacity(cfg: ModelConfig, s: int) -> int:
    return max(1, math.ceil(s * cfg.num_experts_per_tok / cfg.num_experts * cfg.capacity_factor))


def _sorted_keep(e_sorted, cap, num_experts, counts=None, limit=None):
    """The keep/drop rule over one row's sorted assignment stream —
    shared by the plain and the expert-window (ep) dispatch so the two
    paths cannot drift apart (ep-vs-plain routing parity is a tested
    invariant): local position-in-expert below the chunk-local ``cap``,
    sentinel (masked-token) assignments excluded, and — when ``counts``
    carries earlier chunks' per-expert totals — GLOBAL position below
    ``limit``, the request's exact-length capacity.  Returns (pos, keep)."""
    sk = e_sorted.shape[0]
    starts = jnp.searchsorted(e_sorted, jnp.arange(num_experts, dtype=e_sorted.dtype))
    eid = jnp.minimum(e_sorted, num_experts - 1)
    pos = jnp.arange(sk, dtype=jnp.int32) - starts[eid].astype(jnp.int32)
    keep = (e_sorted < num_experts) & (pos < cap)
    if counts is not None:
        keep = keep & (counts[eid].astype(jnp.int32) + pos < limit)
    return pos, keep


def _row_dispatch(x_row, e_sorted, order, cap, num_experts, counts=None, limit=None):
    """Per-(m,b) row: build the (E*C, D) dispatch buffer.

    x_row: (S, D); e_sorted: (S*K,) expert id per sorted assignment
    (``num_experts`` is the sentinel id for masked-out assignments —
    they sort last and are never kept); order: (S*K,) argsort
    permutation.  ``counts``/``limit`` switch :func:`_sorted_keep` to
    the chainable chunked form, so chunked prefill routes identically
    to one exact-length pass.  Returns (buffer (E*C, D), dest, keep,
    tok_sorted)."""
    sk = e_sorted.shape[0]
    k = sk // x_row.shape[0]
    pos, keep = _sorted_keep(e_sorted, cap, num_experts, counts, limit)
    dest = jnp.where(keep, e_sorted.astype(jnp.int32) * cap + pos, num_experts * cap)
    tok_sorted = (order // k).astype(jnp.int32)
    buf = jnp.zeros((num_experts * cap, x_row.shape[1]), x_row.dtype)
    buf = buf.at[dest].set(x_row[tok_sorted], mode="drop")
    return buf, dest, keep, tok_sorted


def _row_combine(y_buf, dest, keep, tok_sorted, w_sorted, s):
    """Per row: gather expert outputs back and scatter-add into tokens."""
    y = jnp.take(y_buf, jnp.minimum(dest, y_buf.shape[0] - 1), axis=0)
    y = y * (keep & (dest < y_buf.shape[0]))[:, None].astype(y.dtype)
    y = y * w_sorted[:, None].astype(y.dtype)
    out = jnp.zeros((s, y.shape[1]), y.dtype)
    return out.at[tok_sorted].add(y, mode="drop")


def _shmap_rows(fn, rules, in_args, in_logical, out_logical):
    """Run ``fn`` (a per-(instance,batch-row) vmapped dispatch/combine) under
    ``jax.shard_map`` over the batch mesh axes, so its data-dependent
    gathers/scatters are device-local and invisible to GSPMD.

    §Perf (qwen3-moe iteration 3): left to GSPMD, the sorted dispatch's
    gather/scatter lower to "replicate-then-repartition" — per-layer
    collective-permutes/all-reduces of the full dispatch buffers plus u32
    index broadcasts at payload width.  shard_map makes them free: every
    token row lives on exactly one device.

    ``in_logical``/``out_logical``: logical axis tuples per arg/output,
    resolved against the active Rules (so divisibility guards and the
    pod axis are handled exactly like the surrounding constraints).
    """
    specs_in = tuple(
        rules.spec(lg, a.shape) for lg, a in zip(in_logical, in_args)
    )

    def wrapped(*args):
        outs = fn(*args)
        return outs

    # out shapes are only known after tracing; rules.spec needs shapes for
    # divisibility checks.  Trace abstractly first.
    out_abs = jax.eval_shape(fn, *in_args)
    flat_abs, treedef = jax.tree.flatten(out_abs)
    specs_out = treedef.unflatten(
        [rules.spec(lg, a.shape) for lg, a in zip(out_logical, flat_abs)]
    )
    return jax.shard_map(
        wrapped, mesh=rules.mesh, in_specs=specs_in, out_specs=specs_out,
        check_vma=False,
    )(*in_args)


def _row_dispatch_window(x_row, e_sorted, order, cap, num_experts, lo, e_local,
                         counts=None, limit=None):
    """Like _row_dispatch but scatters only assignments whose destination
    falls in the expert window [lo·cap, (lo+e_local)·cap) — the local
    expert shard.  Returns (buffer (e_local·cap, D), dest, keep_l,
    tok_sorted); dest stays GLOBAL so the caller's combine can share it.
    The keep rule (incl. the masked/chainable chunked form) is the SAME
    :func:`_sorted_keep` the plain dispatch uses."""
    sk = e_sorted.shape[0]
    k = sk // x_row.shape[0]
    pos, keep = _sorted_keep(e_sorted, cap, num_experts, counts, limit)
    dest = jnp.where(keep, e_sorted.astype(jnp.int32) * cap + pos, num_experts * cap)
    tok_sorted = (order // k).astype(jnp.int32)
    local = keep & (dest >= lo * cap) & (dest < (lo + e_local) * cap)
    dest_l = jnp.where(local, dest - lo * cap, e_local * cap)
    buf = jnp.zeros((e_local * cap, x_row.shape[1]), x_row.dtype)
    buf = buf.at[dest_l].set(x_row[tok_sorted], mode="drop")
    return buf, dest_l, local, tok_sorted


def _moe_mlp_ep_shmap(rules, lp, x, e_sorted, order, w_sorted, cap, e, s,
                      counts=None, limit=None):
    """Canonical expert parallelism in ONE shard_map (§Perf qwen3-moe
    iteration 4).

    Key observation: the dispatch inputs (x, sorted assignments) are
    batch-sharded and *replicated over "model"* — every model-rank can
    rebuild its rows' dispatch state locally for free.  So each rank:
      1. scatters only the assignments that target its expert window
         (E/TP experts) — local, no wire,
      2. runs the expert einsums on its local expert slice of the
         experts->"model"-sharded weights — no wire,
      3. combines its experts' outputs back into token space (s, d) —
         local scatter-add,
      4. one psum over "model" sums the per-window partials.
    Wire per layer = token bytes (the psum) — ~K·cf× less than moving
    dispatch buffers, independent of E.
    """
    m, b, _, d = x.shape
    f = lp["we_gate"].shape[-1]
    mesh = rules.mesh
    nm = dict(mesh.shape).get("model", 1)
    x_spec = rules.spec(("instances", "batch", None, None), x.shape)
    row_spec = rules.spec(("instances", "batch", None), e_sorted.shape)
    # weights enter as explicit args so their experts->"model" sharding is
    # honored (a closure capture would lift them as replicated implicit
    # inputs = the weight all-gather this path exists to avoid).  The
    # embed/mlp dims are requested unsharded — that regather is the
    # standard FSDP per-layer weight gather, not an EP cost.
    wg_spec = rules.spec(("instances", "experts", None, None), (m, e, d, f))
    wd_spec = rules.spec(("instances", "experts", None, None), (m, e, f, d))
    # chunked extras ride as explicit batch-sharded inputs (replicated
    # over "model", like the dispatch rows) so every rank applies the
    # SAME global counts+limit keep rule to its expert window; the
    # non-chunked call passes neutral dummies (0 counts, INT32_MAX
    # limit), under which the chunked keep rule collapses to the plain
    # one — a single code path either way
    if counts is None:
        counts = jnp.zeros((m, x.shape[1], e), jnp.int32)
        limit = jnp.full((m, x.shape[1]), jnp.iinfo(jnp.int32).max, jnp.int32)

    def body(x_l, es_l, od_l, ws_l, ct_l, lm_l, wg, wu, wd):
        e_local = wg.shape[1]
        lo = lax.axis_index("model") * e_local if e_local != e else 0

        def row(xr, es, od, ct, lm):
            return _row_dispatch_window(xr, es, od, cap, e, lo, e_local,
                                        counts=ct, limit=lm)

        buf, dest_l, local, tok = jax.vmap(jax.vmap(row))(
            x_l, es_l, od_l, ct_l, lm_l)
        m_l, b_l = buf.shape[0], buf.shape[1]
        buf = buf.reshape(m_l, b_l, e_local, cap, d)

        h = jax.nn.silu(jnp.einsum("mbecd,medf->mbecf", buf, wg.astype(buf.dtype)))
        h = h * jnp.einsum("mbecd,medf->mbecf", buf, wu.astype(buf.dtype))
        y_buf = jnp.einsum("mbecf,mefd->mbecd", h, wd.astype(buf.dtype))
        y_buf = y_buf.reshape(m_l, b_l, e_local * cap, d)

        comb = jax.vmap(jax.vmap(
            lambda yb, de, ke, ts, ww: _row_combine(yb, de, ke, ts, ww, s)
        ))
        part = comb(y_buf, dest_l, local, tok, ws_l.astype(y_buf.dtype))
        if e_local != e:
            part = lax.psum(part, "model")          # sum expert-window partials
        return part                                  # (m_l, b_l, s, d)

    out_spec = rules.spec(("instances", "batch", None, None), (m, b, s, d))
    ct_spec = rules.spec(("instances", "batch", None), counts.shape)
    lm_spec = rules.spec(("instances", "batch"), limit.shape)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, row_spec, row_spec, row_spec, ct_spec, lm_spec,
                  wg_spec, wg_spec, wd_spec),
        out_specs=out_spec,
        check_vma=False,
    )(x, e_sorted, order, w_sorted, counts, limit,
      lp["we_gate"], lp["we_up"], lp["we_down"])


def moe_mlp(cfg: ModelConfig, lp, x, *, valid=None, counts=None, limit=None):
    """x: (M,B,S,D) -> (M,B,S,D), aux load-balance loss (scalar, f32).

    Chainable/masked routing (serving chunked prefill — DESIGN.md §6.2):

    * ``valid`` (M,B,S) bool masks padded/junk tokens out of routing
      entirely (their assignments take a sentinel expert id, sort last,
      never occupy capacity and combine to zero),
    * ``counts`` (M,B,E) int32 carries per-expert assignment counts from
      earlier chunks of the same request and ``limit`` (M,B) int32 is
      the exact-length capacity computed from the request's REAL token
      count — together they make the keep/drop decisions of a chunked
      prefill identical to one exact-length pass (position-in-expert is
      global, capacity comes from unpadded lengths).

    Returns (out, aux) — plus the updated counts as a third element when
    ``counts`` is given."""
    m, b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    chunked = counts is not None
    # chunk-local buffers never drop (S*K rows bound any expert's share);
    # all dropping is decided by the global counts+limit rule above
    cap = s * k if chunked else capacity(cfg, s)

    # §Perf (EXPERIMENTS.md qwen3-moe iteration 1): the sort-based dispatch
    # below is data-dependent gather/scatter along the token axis.  GSPMD
    # cannot partition such ops when the sliced dim (seq, under Megatron-SP)
    # or the gathered payload dim is sharded — it falls back to "replicate
    # then re-partition", i.e. per-layer all-reduces of the full (B,S·K,D)
    # buffer (~17 TB/step for qwen3-moe train_4k).  Constrain the whole
    # dispatch region to batch-only sharding: batched gathers/scatters over
    # sharded batch dims partition natively.
    x = constrain(x, "instances", "batch", None, "act_embed")

    logits = jnp.einsum(
        "mbsd,mde->mbse", x.astype(jnp.float32), lp["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)                    # (M,B,S,E)
    top_w, top_e = lax.top_k(probs, k)                         # (M,B,S,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    e_flat = top_e.reshape(m, b, s * k)
    w_flat = top_w.reshape(m, b, s * k)
    if valid is not None:
        v_flat = jnp.broadcast_to(valid[..., None], (m, b, s, k)).reshape(m, b, s * k)
        e_flat = jnp.where(v_flat, e_flat, e)      # sentinel: sorts last, never kept
    new_counts = None
    if chunked:
        # every (non-masked) assignment advances its expert's global
        # position, kept or dropped — matching the exact-length rule
        new_counts = counts + jax.nn.one_hot(e_flat, e, dtype=jnp.int32).sum(axis=2)
    order = jnp.argsort(e_flat, axis=-1).astype(jnp.int32)
    e_sorted = constrain(
        jnp.take_along_axis(e_flat, order, axis=-1), "instances", "batch", None
    )
    w_sorted = jnp.take_along_axis(w_flat, order, axis=-1)

    rules = active_rules()
    # experts_compute placement (§Perf qwen3-moe iterations 1–4):
    #   "model" — expert-parallel einsums; buf resharded batch->experts by
    #             GSPMD (costly replicate-repartition in practice),
    #   None    — DP-compute: buf stays batch-sharded, expert weights
    #             all-gathered per layer (wins when dispatched activations,
    #             ~K·cf× token bytes, outweigh the weights),
    #   "ep"    — canonical EP in one shard_map: per-rank expert-window
    #             dispatch + local einsums + token-space psum (wire per
    #             layer = token bytes; see _moe_mlp_ep_shmap).
    placement = rules.mapping.get("experts_compute") if rules is not None else None
    if placement == "ep":
        # masked/chainable routing works here too: the sentinel expert id
        # sorts masked tokens last (never kept in any window) and the
        # global counts+limit keep rule is applied per rank before the
        # window filter, so the ep path routes exactly like the plain one
        out = _moe_mlp_ep_shmap(
            rules, lp, x, e_sorted, order, w_sorted, cap, e, s,
            counts=counts, limit=limit,
        )
        out = constrain(out, "instances", "batch", "seq", "act_embed")
        frac = jnp.mean(
            (jax.nn.one_hot(top_e, e, dtype=jnp.float32)).sum(-2), axis=(1, 2)
        )
        pmean = probs.mean(axis=(1, 2))
        aux = (e * (frac / k * pmean).sum(-1)).mean()
        if chunked:
            return out, aux, new_counts
        return out, aux

    row2 = ("instances", "batch", None)
    row3 = ("instances", "batch", None, None)
    if chunked:
        disp = jax.vmap(jax.vmap(
            lambda xr, es, od, ct, lm: _row_dispatch(xr, es, od, cap, e, ct, lm)
        ))
        d_args = (x, e_sorted, order, counts, limit)
        d_logical = (row3, row2, row2, row2, ("instances", "batch"))
    else:
        disp = jax.vmap(jax.vmap(lambda xr, es, od: _row_dispatch(xr, es, od, cap, e)))
        d_args = (x, e_sorted, order)
        d_logical = (row3, row2, row2)
    if rules is None:
        buf, dest, keep, tok_sorted = disp(*d_args)
    else:
        buf, dest, keep, tok_sorted = _shmap_rows(
            disp, rules, d_args,
            in_logical=d_logical,
            out_logical=(row3, row2, row2, row2),
        )
    buf = buf.reshape(m, b, e, cap, d)
    buf = constrain(buf, "instances", "batch", "experts_compute", None, "act_embed")
    h = jax.nn.silu(jnp.einsum("mbecd,medf->mbecf", buf, lp["we_gate"].astype(buf.dtype)))
    h = h * jnp.einsum("mbecd,medf->mbecf", buf, lp["we_up"].astype(buf.dtype))
    h = constrain(h, "instances", "batch", "experts_compute", None, "expert_mlp")
    y_buf = jnp.einsum("mbecf,mefd->mbecd", h, lp["we_down"].astype(buf.dtype))
    y_buf = y_buf.reshape(m, b, e * cap, d)
    y_buf = constrain(y_buf, "instances", "batch", None, "act_embed")

    comb = jax.vmap(jax.vmap(
        lambda yb, de, ke, ts, ws: _row_combine(yb, de, ke, ts, ws, s)
    ))
    ws_cast = w_sorted.astype(y_buf.dtype)
    if rules is None:
        out = comb(y_buf, dest, keep, tok_sorted, ws_cast)
    else:
        out = _shmap_rows(
            comb, rules, (y_buf, dest, keep, tok_sorted, ws_cast),
            in_logical=(row3, row2, row2, row2, row2),
            out_logical=(row3,),
        )
    out = constrain(out, "instances", "batch", "seq", "act_embed")

    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    frac = jnp.mean(
        (jax.nn.one_hot(top_e, e, dtype=jnp.float32)).sum(-2), axis=(1, 2)
    )                                                          # (M,E) assignment frac * k
    pmean = probs.mean(axis=(1, 2))                            # (M,E)
    aux = (e * (frac / k * pmean).sum(-1)).mean()
    if chunked:
        return out, aux, new_counts
    return out, aux


# ---------------------------------------------------------------------------
# blocks / entry points
# ---------------------------------------------------------------------------


def _attn(cfg, lp, x, positions, *, cache=None, decode_pos=None):
    n = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    h, new_cache = L.gqa_attention(
        n, lp,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, window=cfg.sliding_window,
        cache=cache, decode_pos=decode_pos,
    )
    return x + h, new_cache


def _block(cfg, lp, x, positions, *, cache=None, decode_pos=None):
    x, new_cache = _attn(cfg, lp, x, positions, cache=cache, decode_pos=decode_pos)
    n = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_mlp(cfg, lp, n)
    return x + y, new_cache, aux


def _positions(tokens):
    m, b, s = tokens.shape
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))


def forward(cfg, params, tokens, *, remat: bool = False, return_aux: bool = False):
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    positions = _positions(tokens)

    def body(carry, lp):
        xc, aux_sum = carry
        out, _, aux = _block(cfg, lp, xc, positions)
        return (out, aux_sum + aux), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["lm_head"])
    if return_aux:
        return logits, aux / cfg.num_layers
    return logits


def prefill(cfg, params, tokens, *, cache_len: int | None = None):
    m, b, s = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    positions = _positions(tokens)
    window = cfg.sliding_window
    if cache_len is None:
        cache_len = window if window else s

    def body(xc, lp):
        n = L.rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q = L.linear(n, lp["wq"], lp.get("bq")).reshape(m, b, s, cfg.num_heads, cfg.head_dim)
        kk = L.linear(n, lp["wk"], lp.get("bk")).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        vv = L.linear(n, lp["wv"], lp.get("bv")).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        kk = L.rope(kk, positions, cfg.rope_theta)
        o = L.flash_attention(q, kk, vv, positions, positions, window=window)
        xc = xc + L.linear(o.reshape(m, b, s, -1), lp["wo"], lp.get("bo"))
        n = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_mlp(cfg, lp, n)
        xc = xc + y
        if cache_len >= s:
            pad = cache_len - s
            kc = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            kc, vc = kk[:, :, s - cache_len:], vv[:, :, s - cache_len:]
        return xc, (kc.astype(jnp.dtype(cfg.dtype)), vc.astype(jnp.dtype(cfg.dtype)))

    x, (ck, cv) = lax.scan(body, x, params["layers"])
    x = L.rms_norm(x[:, :, -1:], params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])[:, :, 0], KVCache(k=ck, v=cv)


def decode_step(cfg, params, cache: KVCache, tokens, pos):
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    positions = pos[..., None]

    def body(xc, xs):
        lp, ck, cv = xs
        out, new_cache, _ = _block(cfg, lp, xc, positions, cache=(ck, cv), decode_pos=pos)
        return out, new_cache

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache.k, cache.v))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])[:, :, 0], KVCache(k=nk, v=nv)


def make_cache(cfg, m, b, context_len):
    s_cache = cfg.sliding_window if cfg.sliding_window else context_len
    return L.make_kv_cache(
        cfg.num_layers, m, b, s_cache, cfg.num_kv_heads, cfg.head_dim, jnp.dtype(cfg.dtype)
    )


def cache_axes(cfg):
    ax = ("layers", "instances", "batch", "cache_seq", "kv_heads", "kv_hd")
    return KVCache(k=ax, v=ax)


def init_chunk_carry(cfg: ModelConfig, m: int, b: int, cache_len: int):
    return {
        "cache": make_cache(cfg, m, b, cache_len),
        # per-layer, per-expert assignment counts from earlier chunks:
        # routers are independent per layer, so the chainable capacity
        # rule needs one usage row per layer
        "counts": jnp.zeros((cfg.num_layers, m, b, cfg.num_experts), jnp.int32),
    }


def chunk_carry_axes(cfg: ModelConfig):
    return {
        "cache": cache_axes(cfg),
        "counts": ("layers", "instances", "batch", None),
    }


def prefill_chunk(cfg: ModelConfig, params, batch, carry, offset):
    """Chunked prefill with exact-length-equivalent expert routing.

    batch["moe_limit"]: (M,B) int32 — the capacity an exact-length
    prefill of this request's REAL token count would use; combined with
    the carried per-layer expert counts, chunked routing keeps/drops
    exactly the tokens the exact pass would (closes the bucketed-prefill
    capacity caveat)."""
    from repro.models.common import constrain_axes

    tokens, limit = batch["tokens"], batch["moe_limit"]
    cache, counts = carry["cache"], carry["counts"]
    valid = batch.get("valid")            # (M,B,C) tail-folding junk mask
    m, b, c = tokens.shape
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)
    window = cfg.sliding_window
    s_cache = cache.k.shape[3]
    before = L.cache_positions_after(offset - 1, s_cache, 0)
    kv_pos = jnp.concatenate([before, positions], axis=-1)
    kv_ax = ("instances", "batch", "cache_seq", "kv_heads", "kv_hd")

    def body(xc, xs):
        lp, ck, cv, cnt = xs
        n = L.rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q = L.linear(n, lp["wq"], lp.get("bq")).reshape(m, b, c, cfg.num_heads, cfg.head_dim)
        kk = L.linear(n, lp["wk"], lp.get("bk")).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
        vv = L.linear(n, lp["wv"], lp.get("bv")).reshape(m, b, c, cfg.num_kv_heads, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        kk = L.rope(kk, positions, cfg.rope_theta)
        o = L.flash_attention(
            q,
            jnp.concatenate([ck, kk.astype(ck.dtype)], axis=2),
            jnp.concatenate([cv, vv.astype(cv.dtype)], axis=2),
            positions, kv_pos, window=window,
        )
        xc = xc + L.linear(o.reshape(m, b, c, -1), lp["wo"], lp.get("bo"))
        n = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        y, _, new_cnt = moe_mlp(cfg, lp, n, valid=valid, counts=cnt, limit=limit)
        xc = xc + y
        nk = constrain_axes(L.cache_append_chunk(ck, kk, positions, 0, valid), kv_ax)
        nv = constrain_axes(L.cache_append_chunk(cv, vv, positions, 0, valid), kv_ax)
        return xc, (nk, nv, new_cnt)

    _, (nk, nv, ncnt) = lax.scan(body, x, (params["layers"], cache.k, cache.v, counts))
    return {"cache": KVCache(k=nk, v=nv), "counts": ncnt}
