"""xLSTM (sLSTM + mLSTM blocks) — xlstm-1.3b [arXiv:2405.04517].

TPU adaptation (DESIGN.md §2): the GPU reference implements mLSTM with
fused CUDA kernels over the full sequence; here the mLSTM is evaluated in
*chunkwise-parallel* form — an outer ``lax.scan`` over sequence chunks
carrying the (C, n, m) matrix-memory state, with the intra-chunk part
expressed as MXU-friendly masked matmuls (Cs x Cs score/decay matrices).
This keeps memory O(S/Cs · Cs²) instead of O(S²) and is the natural
mapping of linear-attention-style recurrences onto the MXU.

Exact exponential-gating stabilization (the paper's m-state) is carried
across chunks; tests assert the chunkwise path matches the per-step
recurrence to float tolerance, and that decode (single-step) continues
prefill exactly.

Layer layout: ``cfg.slstm_every = k`` makes layer i an sLSTM block when
``i % k == cfg.slstm_offset`` (xLSTM[7:1] ratio for the 1.3b config);
mLSTM runs between sLSTM layers are stacked and scanned.

NetFuse applicability: all projections are instance-batched einsums; the
recurrent state carries a leading instance axis — merged instances evolve
independent states (input-weight local by construction).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import (
    Factory, constrain, make_factory, param_axes, param_values,
    stack_layer_params,
)

# ---------------------------------------------------------------------------
# config helpers
# ---------------------------------------------------------------------------


def d_inner(cfg: ModelConfig) -> int:
    return int(cfg.mlstm_proj_factor * cfg.d_model)


def slstm_ff(cfg: ModelConfig) -> int:
    # xLSTM sLSTM blocks use a gated FFN with proj factor 4/3, rounded to 128.
    return max(128, int(round(cfg.d_model * 4 / 3 / 128)) * 128)


def is_slstm_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.slstm_every > 0 and i % cfg.slstm_every == cfg.slstm_offset


def layer_pattern(cfg: ModelConfig) -> list[str]:
    return ["slstm" if is_slstm_layer(cfg, i) else "mlstm" for i in range(cfg.num_layers)]


def mlstm_runs(cfg: ModelConfig) -> list[int]:
    """Lengths of contiguous mLSTM runs between sLSTM layers."""
    runs, cur = [], 0
    for kind in layer_pattern(cfg):
        if kind == "mlstm":
            cur += 1
        else:
            runs.append(cur)
            cur = 0
    runs.append(cur)
    return runs  # len == n_slstm + 1; entries may be 0


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _mlstm_layer_params(cfg: ModelConfig, f: Factory):
    m, d = cfg.num_instances, cfg.d_model
    di, h = d_inner(cfg), cfg.num_heads
    hd = di // h
    return {
        "norm": f((m, d), ("instances", None), init="ones"),
        "w_up": f((m, d, 2 * di), ("instances", "embed", "mlp"), init="fan_in"),
        "conv_w": f((m, cfg.conv_kernel, di), ("instances", None, "mlp"), init="fan_in"),
        "conv_b": f((m, di), ("instances", "mlp"), init="zeros"),
        # block-diagonal per-head q/k/v (the paper's BlockDiag projections)
        "wq": f((m, h, hd, hd), ("instances", "heads", None, None), init="fan_in"),
        "wk": f((m, h, hd, hd), ("instances", "heads", None, None), init="fan_in"),
        "wv": f((m, h, hd, hd), ("instances", "heads", None, None), init="fan_in"),
        "w_gates": f((m, di, 2 * h), ("instances", "mlp", None), init="fan_in"),
        "b_gates": f((m, 2 * h), ("instances", None), init="zeros"),
        "out_norm": f((m, di), ("instances", "mlp"), init="ones"),
        "w_down": f((m, di, d), ("instances", "mlp", "embed"), init="fan_in"),
    }


def _slstm_layer_params(cfg: ModelConfig, f: Factory):
    m, d, h = cfg.num_instances, cfg.d_model, cfg.num_heads
    hd = d // h
    ff = slstm_ff(cfg)
    return {
        "norm": f((m, d), ("instances", None), init="ones"),
        "w_in": f((m, d, 4 * d), ("instances", "embed", "mlp"), init="fan_in"),
        "b_in": f((m, 4 * d), ("instances", "mlp"), init="zeros"),
        # per-head block-diagonal recurrent weights
        "r": f((m, 4, h, hd, hd), ("instances", None, "heads", None, None), init="fan_in"),
        "out_norm": f((m, d), ("instances", None), init="ones"),
        "ffn_norm": f((m, d), ("instances", None), init="ones"),
        "w_ff_gate": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_ff_up": f((m, d, ff), ("instances", "embed", "mlp"), init="fan_in"),
        "w_ff_down": f((m, ff, d), ("instances", "mlp", "embed"), init="fan_in"),
    }


def build_params(cfg: ModelConfig, f: Factory):
    m, d, v = cfg.num_instances, cfg.d_model, cfg.vocab_size
    runs = mlstm_runs(cfg)
    p = {
        "embed": f((m, v, d), ("instances", "vocab", "embed")),
        "mlstm_runs": [
            stack_layer_params([_mlstm_layer_params(cfg, f) for _ in range(n)])
            if n else None
            for n in runs
        ],
        "slstm": [
            _slstm_layer_params(cfg, f) for _ in range(len(runs) - 1)
        ],
        "final_norm": f((m, d), ("instances", None), init="ones"),
        "lm_head": f((m, d, v), ("instances", "embed", "vocab"), init="fan_in"),
    }
    return p


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise-parallel sequence form + single-step form
# ---------------------------------------------------------------------------


def _mlstm_chunk(carry, blk, *, hd: int):
    """One chunk. carry: (C (..,hd,hd), n (..,hd), mstab (..,)) with
    leading dims (M,B,H).  blk: q,k,v (M,B,H,Cs,hd); lf, li (M,B,H,Cs) —
    log forget (<=0) and input-gate preactivations."""
    C0, n0, m0 = carry
    q, k, v, lf, li = blk
    cs = q.shape[-2]

    b = jnp.cumsum(lf, axis=-1)                                # (..,Cs) log decay to t
    g = lax.cummax(li - b, axis=li.ndim - 1)                   # running max of (li_s - b_s)
    mt = b + jnp.maximum(m0[..., None], g)                     # stabilizer per step
    a_inter = jnp.exp(b + m0[..., None] - mt)                  # (..,Cs)

    # D[t,s] = exp(li_s + b_t - b_s - m_t) for s<=t
    logD = (
        li[..., None, :] - b[..., None, :] + b[..., :, None] - mt[..., None]
    )                                                          # (..,Cs_t,Cs_s)
    tri = jnp.tril(jnp.ones((cs, cs), bool))
    D = jnp.where(tri, jnp.exp(logD), 0.0)

    # q/k/v stay in their storage dtype (bf16 in production — §Perf xlstm
    # iteration: the chunk-scan buffers dominate HBM traffic); every
    # contraction accumulates in f32, gates/state are always f32.
    f32 = jnp.float32
    s_qk = jnp.einsum(
        "...td,...sd->...ts", q, k, preferred_element_type=f32
    ) / math.sqrt(hd)
    w = s_qk * D                                               # (..,Cs,Cs) f32
    num = jnp.einsum("...ts,...sd->...td", w.astype(v.dtype), v,
                     preferred_element_type=f32)
    num = num + a_inter[..., None] * jnp.einsum(
        "...td,...de->...te", q.astype(f32), C0
    ) / math.sqrt(hd)
    den = w.sum(-1) + a_inter * jnp.einsum(
        "...td,...d->...t", q.astype(f32), n0
    ) / math.sqrt(hd)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mt))[..., None]

    # end-of-chunk state
    m_end = mt[..., -1]
    w_end = jnp.exp(li + b[..., -1:] - b - m_end[..., None])   # (..,Cs)
    decay0 = jnp.exp(b[..., -1] + m0 - m_end)                  # (..,)
    C_new = decay0[..., None, None] * C0 + jnp.einsum(
        "...s,...sd,...se->...de", w_end.astype(v.dtype), k, v,
        preferred_element_type=f32,
    )
    n_new = decay0[..., None] * n0 + jnp.einsum(
        "...s,...sd->...d", w_end.astype(k.dtype), k, preferred_element_type=f32
    )
    return (C_new, n_new, m_end), h.astype(v.dtype)


def mlstm_sequence(q, k, v, lf, li, *, chunk: int = 64, state=None):
    """Chunkwise mLSTM. q,k,v: (M,B,H,S,hd); lf,li: (M,B,H,S).
    Returns (h (M,B,H,S,hd), final state)."""
    m_, b_, h_, s, hd = q.shape
    cs = min(chunk, s)
    while s % cs:
        cs -= 1
    nc = s // cs
    if state is None:
        state = (
            jnp.zeros((m_, b_, h_, hd, hd), jnp.float32),
            jnp.zeros((m_, b_, h_, hd), jnp.float32),
            jnp.full((m_, b_, h_), -1e30, jnp.float32),
        )

    def to_chunks(x):
        if x.ndim == 5:
            xs = x.reshape(m_, b_, h_, nc, cs, x.shape[-1])
        else:
            xs = x.reshape(m_, b_, h_, nc, cs)
        return jnp.moveaxis(xs, 3, 0)

    xs = (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(lf), to_chunks(li))

    def step(carry, blk):
        return _mlstm_chunk(carry, blk, hd=hd)

    state, hs = lax.scan(step, state, xs)                      # hs (nc,M,B,H,Cs,hd)
    h = jnp.moveaxis(hs, 0, 3).reshape(m_, b_, h_, s, hd)
    return h, state


def mlstm_step(state, q, k, v, lf, li):
    """Single decode step. q,k,v: (M,B,H,hd); lf,li: (M,B,H)."""
    C0, n0, m0 = state
    hd = q.shape[-1]
    mt = jnp.maximum(m0 + lf, li)
    fp = jnp.exp(lf + m0 - mt)
    ip = jnp.exp(li - mt)
    kf = k.astype(jnp.float32)
    C = fp[..., None, None] * C0 + ip[..., None, None] * (
        kf[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    n = fp[..., None] * n0 + ip[..., None] * kf
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    num = jnp.einsum("...d,...de->...e", qf, C)
    den = jnp.einsum("...d,...d->...", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mt))[..., None]
    return (C, n, mt), h


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b, conv_state=None, nvalid=None):
    """Depthwise causal conv via shifted adds. x: (M,B,S,Di); w: (M,K,Di).
    conv_state: (M,B,K-1,Di) trailing inputs from the previous call.
    ``nvalid`` (M,B) int32 — count of VALID leading positions (serving
    tail folding): the carried window is gathered at the last valid
    inputs instead of the chunk's junk suffix."""
    k = w.shape[1]
    if conv_state is None and nvalid is not None:
        conv_state = jnp.zeros(x.shape[:2] + (k - 1, x.shape[3]), x.dtype)
    if conv_state is None:
        pads = [jnp.pad(x, ((0, 0), (0, 0), (j, 0), (0, 0)))[:, :, : x.shape[2]] for j in range(k)]
        new_state = x[:, :, -(k - 1):]
    else:
        ext = jnp.concatenate([conv_state.astype(x.dtype), x], axis=2)
        pads = [ext[:, :, k - 1 - j : k - 1 - j + x.shape[2]] for j in range(k)]
        if nvalid is None:
            new_state = ext[:, :, -(k - 1):]
        else:
            idx = nvalid[..., None] + jnp.arange(k - 1, dtype=jnp.int32)
            new_state = jnp.take_along_axis(
                ext, idx[..., None].astype(jnp.int32), axis=2
            )
    y = sum(w[:, j, :][:, None, None, :].astype(x.dtype) * pads[j] for j in range(k))
    return y + b[:, None, None, :].astype(x.dtype), new_state


def _head_proj(x, w):
    """Block-diagonal per-head projection. x: (M,B,S,H,hd); w: (M,H,hd,hd)."""
    return jnp.einsum("mbshd,mhde->mbshe", x, w.astype(x.dtype))


def mlstm_block(cfg: ModelConfig, lp, x, *, state=None, chunk: int = 64, valid=None):
    """x: (M,B,S,D). state (decode): dict(C,n,m,conv). Returns (y, state).
    ``valid`` (M,B,S) bool: junk suffix of a padded final chunk (serving
    tail folding) is made gate-neutral — log-forget 0, log-input -inf —
    so the (C, n, m) carry skips the padded steps exactly."""
    m, b, s, d = x.shape
    di, h = d_inner(cfg), cfg.num_heads
    hd = di // h
    res = x
    xn = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    up = L.linear(xn, lp["w_up"])                              # (M,B,S,2Di)
    xi, z = up[..., :di], up[..., di:]
    conv_state = state["conv"] if state is not None else None
    nvalid = valid.sum(-1).astype(jnp.int32) if valid is not None else None
    xc, new_conv = _causal_conv(xi, lp["conv_w"], lp["conv_b"], conv_state,
                                nvalid=nvalid)
    xc = jax.nn.silu(xc)

    xch = xc.reshape(m, b, s, h, hd)
    xih = xi.reshape(m, b, s, h, hd)
    q = _head_proj(xch, lp["wq"])
    k = _head_proj(xch, lp["wk"])
    v = _head_proj(xih, lp["wv"])
    gates = L.linear(xc, lp["w_gates"], lp["b_gates"]).astype(jnp.float32)  # (M,B,S,2H)
    li = gates[..., :h]
    lf = jax.nn.log_sigmoid(gates[..., h:])
    if valid is not None:
        li = jnp.where(valid[..., None], li, -1e30)
        lf = jnp.where(valid[..., None], lf, 0.0)

    # to (M,B,H,S,...) layout
    tr = lambda t: jnp.moveaxis(t, 3, 2)                       # (M,B,H,S,hd)
    if state is None or s > 1:
        if cfg.use_pallas_kernels and state is None:
            # matrix memory resident in VMEM across chunks
            # (kernels/mlstm_chunk.py — companion of the sLSTM cell kernel)
            from repro.kernels import ops as K
            hseq, new_cell = K.mlstm_chunkwise(
                tr(q), tr(k), tr(v),
                jnp.moveaxis(lf, 3, 2), jnp.moveaxis(li, 3, 2),
                chunk=chunk,
            )
        else:
            hseq, new_cell = mlstm_sequence(
                tr(q), tr(k), tr(v),
                jnp.moveaxis(lf, 3, 2), jnp.moveaxis(li, 3, 2),
                chunk=chunk,
                state=None if state is None else (state["C"], state["n"], state["m"]),
            )
        hs = jnp.moveaxis(hseq, 2, 3)                          # (M,B,S,H,hd)
    else:
        cell = (state["C"], state["n"], state["m"])
        new_cell, hstep = mlstm_step(
            cell, q[:, :, 0], k[:, :, 0], v[:, :, 0], lf[:, :, 0], li[:, :, 0]
        )
        hs = hstep[:, :, None]                                 # (M,B,1,H,hd)

    hs = hs.reshape(m, b, s, di).astype(x.dtype)
    # per-head group norm (xLSTM's multi-head layer norm), then gate
    hs = hs.reshape(m, b, s, h, hd)
    mu = hs.mean(-1, keepdims=True)
    var = hs.var(-1, keepdims=True)
    hs = ((hs - mu) * lax.rsqrt(var + cfg.norm_eps)).reshape(m, b, s, di)
    hs = hs * lp["out_norm"][:, None, None, :].astype(hs.dtype)
    out = L.linear(hs * jax.nn.silu(z), lp["w_down"])
    new_state = {"C": new_cell[0], "n": new_cell[1], "m": new_cell[2], "conv": new_conv}
    return res + out, new_state


def mlstm_state_shape(cfg: ModelConfig, m: int, b: int):
    di, h = d_inner(cfg), cfg.num_heads
    hd = di // h
    k = cfg.conv_kernel
    return {
        "C": ((m, b, h, hd, hd), jnp.float32),
        "n": ((m, b, h, hd), jnp.float32),
        "m": ((m, b, h), jnp.float32),
        "conv": ((m, b, k - 1, di), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_block(cfg: ModelConfig, lp, x, *, state=None, valid=None):
    """x: (M,B,S,D). Sequential scan over time (sLSTM is strictly
    recurrent through h). state: dict(c,n,h,m) each (M,B,D).
    ``valid`` (M,B,S) bool: junk steps (serving tail folding) get forced
    gate pre-activations (forget +inf, input -inf) so c/n/m are carried
    unchanged — works for the scan AND the Pallas cell, which both derive
    gates from ``pre``; the h carry is re-gathered at the last valid step
    afterwards (h is the one state the gates can't protect)."""
    m, b, s, d = x.shape
    h_heads = cfg.num_heads
    hd = d // h_heads
    res = x
    xn = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    # pre-activations stay in storage dtype (bf16 in production) and are
    # upcast per step — the (M,B,S,4D) f32 buffer otherwise dominates the
    # scan's HBM traffic (§Perf xlstm iteration).  Gate math is f32.
    pre = L.linear(xn, lp["w_in"], lp["b_in"]).reshape(m, b, s, 4, d)
    if valid is not None:
        # (z, i, f, o) slots: input gate -> -BIG (exp underflows to 0),
        # forget gate -> +BIG (log_sigmoid saturates to exactly 0)
        neutral = jnp.asarray([0.0, -1e30, 1e30, 0.0], pre.dtype)
        pre = jnp.where(valid[..., None, None],
                        pre, neutral.reshape(1, 1, 1, 4, 1))

    if state is None:
        st = tuple(jnp.zeros((m, b, d), jnp.float32) for _ in range(3)) + (
            jnp.full((m, b, d), -1e30, jnp.float32),
        )
    else:
        st = (state["c"], state["n"], state["h"], state["m"])
    # h carry in storage dtype (bf16 in production): h is only a matmul
    # input; c/n/m (the numerically sensitive gate state) stay f32.  The
    # f32 h chain is what the scan saves per step for backward — in bf16
    # that residual buffer halves (§Perf xlstm iteration 3).
    st = (st[0], st[1], st[2].astype(x.dtype), st[3])

    r = lp["r"].astype(jnp.float32)                            # (M,4,H,hd,hd)

    def step(carry, pre_t):
        c, n, hprev, mstab = carry                             # (M,B,D)
        hh = hprev.reshape(m, b, h_heads, hd)
        rec = jnp.einsum("mbhd,mghde->mbghe", hh, r).reshape(m, b, 4, d)
        zt, it, ft, ot = [pre_t[:, :, j].astype(jnp.float32) + rec[:, :, j]
                          for j in range(4)]
        lf = jax.nn.log_sigmoid(ft)
        mt = jnp.maximum(lf + mstab, it)
        ip = jnp.exp(it - mt)
        fp = jnp.exp(lf + mstab - mt)
        c_new = fp * c + ip * jnp.tanh(zt)
        n_new = fp * n + ip
        h_new = (jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)).astype(x.dtype)
        return (c_new, n_new, h_new, mt), h_new

    if cfg.use_pallas_kernels:
        # whole-sequence Pallas cell: (c,n,h,m) resident in VMEM scratch
        # across all S steps (kernels/slstm_cell.py — §Perf xlstm lever).
        from repro.kernels import ops as K
        hs, (c, n, hlast, mstab) = K.slstm_cell(
            pre, lp["r"], st, num_heads=h_heads
        )
    else:
        # checkpoint each step: backward then saves only the (c,n,h,m)
        # carry chain and recomputes the ~10 per-step gate intermediates —
        # those f32 (M,B,D)xS residual stacks dominate the sLSTM's HBM
        # traffic otherwise (§Perf xlstm iteration 4; iteration 3 showed
        # shrinking ONE of them doesn't move the term).
        (c, n, hlast, mstab), hs = lax.scan(
            jax.checkpoint(step), st, jnp.moveaxis(pre, 2, 0)
        )
        hs = jnp.moveaxis(hs, 0, 2)                            # (M,B,S,D)

    if valid is not None:
        # gate-neutral junk leaves c/n/m untouched but every step still
        # emits an h — re-select the h carry at the last VALID step (or
        # keep the incoming h when the whole chunk is junk)
        nv = valid.sum(-1).astype(jnp.int32)                   # (M,B)
        idx = jnp.clip(nv - 1, 0, s - 1)[..., None, None]
        h_sel = jnp.take_along_axis(
            hs, jnp.broadcast_to(idx, (m, b, 1, d)), axis=2
        )[:, :, 0]
        hlast = jnp.where((nv > 0)[..., None], h_sel, st[2])

    # per-head group norm + residual, then gated FFN
    hh = hs.reshape(m, b, s, h_heads, hd)
    mu = hh.mean(-1, keepdims=True)
    var = hh.var(-1, keepdims=True)
    hs = ((hh - mu) * lax.rsqrt(var + cfg.norm_eps)).reshape(m, b, s, d)
    hs = hs * lp["out_norm"][:, None, None, :].astype(hs.dtype)
    x = res + hs
    nrm = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    x = x + L.swiglu_mlp(nrm, lp["w_ff_gate"], lp["w_ff_up"], lp["w_ff_down"])
    new_state = {"c": c, "n": n, "h": hlast, "m": mstab}
    return x, new_state


def slstm_state_shape(cfg: ModelConfig, m: int, b: int):
    d = cfg.d_model
    return {
        "c": ((m, b, d), jnp.float32),
        "n": ((m, b, d), jnp.float32),
        "h": ((m, b, d), jnp.dtype(cfg.dtype)),   # matmul input only
        "m": ((m, b, d), jnp.float32),
    }


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def _trunk(cfg, params, x, *, states=None, chunk=None, remat=False, valid=None):
    """Run all blocks. states: None or dict(mlstm_runs=[...], slstm=[...]).
    ``valid`` (M,B,S): serving tail-folding junk mask, threaded to every
    recurrent cell.  Returns (x, new_states)."""
    runs = mlstm_runs(cfg)
    if chunk is None:
        chunk = cfg.mlstm_chunk
    new_states = {"mlstm_runs": [], "slstm": []}

    for ri, n in enumerate(runs):
        if n:
            run_params = params["mlstm_runs"][ri]
            run_state = states["mlstm_runs"][ri] if states is not None else None

            def body(xc, xs, _n=n):
                lp, st = xs
                out, new_st = mlstm_block(cfg, lp, xc, state=st, chunk=chunk,
                                          valid=valid)
                return out, new_st

            if remat:
                body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
            if run_state is None:
                m_, b_ = x.shape[0], x.shape[1]
                shapes = mlstm_state_shape(cfg, m_, b_)
                run_state = {
                    kk: jnp.zeros((n,) + sh, dt) if kk != "m" else
                        jnp.full((n,) + sh, -1e30, dt)
                    for kk, (sh, dt) in shapes.items()
                }
            x, new_st = lax.scan(body, x, (run_params, run_state))
            new_states["mlstm_runs"].append(new_st)
        else:
            new_states["mlstm_runs"].append(None)
        if ri < len(runs) - 1:
            s_state = states["slstm"][ri] if states is not None else None
            x, new_s = slstm_block(cfg, params["slstm"][ri], x, state=s_state,
                                   valid=valid)
            new_states["slstm"].append(new_s)
    return x, new_states


def forward(cfg, params, tokens, *, remat: bool = False):
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    x, _ = _trunk(cfg, params, x, remat=remat)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])


def prefill(cfg, params, tokens, *, state=None):
    """Returns (last logits, recurrent states) — the SSM 'cache'.

    ``state`` continues a previous prefill exactly (chunked prompt
    processing: the serving admission path feeds fixed-size chunks so one
    compile covers every prompt length — see serving/prefill.py)."""
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    m, b, s = tokens.shape
    states = make_state(cfg, m, b) if state is None else state
    x, states = _trunk(cfg, params, x, states=states)
    x = L.rms_norm(x[:, :, -1:], params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])[:, :, 0], states


def decode_step(cfg, params, states, tokens, pos=None):
    """One token. tokens (M,B,1). pos unused (state is positionless)."""
    x = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    x, states = _trunk(cfg, params, x, states=states)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["lm_head"])[:, :, 0], states


def make_state(cfg, m, b):
    runs = mlstm_runs(cfg)
    st = {"mlstm_runs": [], "slstm": []}
    for ri, n in enumerate(runs):
        if n:
            shapes = mlstm_state_shape(cfg, m, b)
            st["mlstm_runs"].append({
                kk: (jnp.zeros((n,) + sh, dt) if kk != "m"
                     else jnp.full((n,) + sh, -1e30, dt))
                for kk, (sh, dt) in shapes.items()
            })
        else:
            st["mlstm_runs"].append(None)
        if ri < len(runs) - 1:
            st["slstm"].append({
                kk: (jnp.zeros(sh, dt) if kk != "m" else jnp.full(sh, -1e30, dt))
                for kk, (sh, dt) in slstm_state_shape(cfg, m, b).items()
            })
    return st


def init_chunk_carry(cfg, m, b, cache_len):
    return {"cache": make_state(cfg, m, b)}


def chunk_carry_axes(cfg):
    return {"cache": state_axes(cfg)}


def prefill_chunk(cfg, params, batch, carry, offset):
    """One chunk of a state-carrying prefill.  The recurrent state is
    positionless, so ``offset`` is unused — chaining is pure state
    threading (this was already exact pre-refactor; the chunk-carry
    protocol just gives it the uniform serving signature).
    batch["valid"] (M,B,C) marks the junk suffix of a padded final chunk
    (tail folding): junk steps are gate-neutral in every cell, so the
    carried state equals the exact-length pass."""
    x = L.embed(batch["tokens"], params["embed"], jnp.dtype(cfg.dtype))
    _, states = _trunk(cfg, params, x, states=carry["cache"],
                       valid=batch.get("valid"))
    return {"cache": states}


def take_state(cfg, state, m, b):
    """Slice slot (m, b) out of an (M, B) recurrent-state grid, keeping
    singleton dims — the recurrent-family counterpart of KV-cache slot
    surgery (serving admission/eviction)."""
    from repro.models.common import tree_take_slot
    return tree_take_slot(state, state_axes(cfg), m, b)


def put_state(cfg, grid, one, m, b):
    """Write a single-slot state tree into grid slot (m, b)."""
    from repro.models.common import tree_put_slot
    return tree_put_slot(grid, state_axes(cfg), one, m, b)


def state_axes(cfg):
    """Logical axes for the recurrent state (for sharding rules)."""
    runs = mlstm_runs(cfg)
    ax = {"mlstm_runs": [], "slstm": []}
    for ri, n in enumerate(runs):
        ax["mlstm_runs"].append(
            {
                "C": ("layers", "instances", "batch", "heads", None, None),
                "n": ("layers", "instances", "batch", "heads", None),
                "m": ("layers", "instances", "batch", "heads"),
                "conv": ("layers", "instances", "batch", None, "mlp"),
            } if n else None
        )
        if ri < len(runs) - 1:
            ax["slstm"].append({k: ("instances", "batch", None) for k in ("c", "n", "h", "m")})
    return ax
