"""InternVL2-26B language backbone + stub vision frontend [arXiv:2404.16821].

Per the assignment spec, the vision encoder (InternViT-6B) is a STUB:
``input_specs`` provides precomputed patch embeddings of the right shape
(B, P, vision_embed_dim).  This module implements everything downstream:
the MLP projector and the InternLM2 decoder (llama-style GQA trunk,
reused from :mod:`repro.models.dense`).

This is also the paper §6 "common backbone" case in miniature: the
vision embeddings are shared inputs while the LM trunk is the per-task
fine-tuned (and therefore NetFuse-merged) part.

Sequence layout: [P image-patch positions][S_text token positions].
``shape.seq_len`` counts total positions, so text length = seq_len - P.
Decode: image patches live in the KV cache after prefill; decode_step is
exactly the dense decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dense
from repro.models import layers as L
from repro.models.common import make_factory, param_axes, param_values


def build_params(cfg: ModelConfig, f):
    p = dense.build_params(cfg, f)
    m = cfg.num_instances
    p["projector"] = {
        "w1": f((m, cfg.vision_embed_dim, cfg.d_model),
                ("instances", None, "embed"), init="fan_in"),
        "b1": f((m, cfg.d_model), ("instances", "embed"), init="zeros"),
        "norm": f((m, cfg.vision_embed_dim), ("instances", None), init="ones"),
    }
    return p


def init(cfg, key):
    return param_values(build_params(cfg, make_factory(cfg, key)))


def abstract_params(cfg):
    return param_values(build_params(cfg, make_factory(cfg, abstract=True)))


def axes(cfg):
    return param_axes(build_params(cfg, make_factory(cfg, abstract=True)))


def project_image(cfg, params, image_embeds):
    """Stub-ViT patch embeddings (M,B,P,vision_dim) -> LM space (M,B,P,D)."""
    pp = params["projector"]
    x = L.layer_norm(image_embeds.astype(jnp.dtype(cfg.dtype)), pp["norm"], None, cfg.norm_eps)
    return L.linear(x, pp["w1"], pp["b1"])


def _combined(cfg, params, tokens, image_embeds):
    tok = L.embed(tokens, params["embed"], jnp.dtype(cfg.dtype))
    img = project_image(cfg, params, image_embeds)
    return jnp.concatenate([img, tok], axis=2)


def forward(cfg, params, tokens, image_embeds, *, remat: bool = False):
    """Returns logits over ALL positions (image prefix + text); callers
    slice [:, :, P:] for text logits."""
    x = _combined(cfg, params, tokens, image_embeds)
    m, b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    return dense.forward(cfg, params, tokens, inputs_embeds=x, positions=positions, remat=remat)


def text_logits(cfg, params, tokens, image_embeds, **kw):
    p = image_embeds.shape[2]
    return forward(cfg, params, tokens, image_embeds, **kw)[:, :, p:]


def prefill(cfg, params, tokens, image_embeds, *, cache_len: int | None = None):
    """Prompt = image patches + text tokens; returns (last logits, cache)."""
    x = _combined(cfg, params, tokens, image_embeds)
    m, b, s, _ = x.shape
    # delegate to the dense prefill loop by substituting embeddings:
    # dense.prefill embeds tokens itself, so re-implement the thin shell.
    import jax.numpy as jnp
    from jax import lax

    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    window = cfg.sliding_window
    if cache_len is None:
        cache_len = window if window else s

    def body(xc, lp):
        n = L.rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q = L.linear(n, lp["wq"]).reshape(m, b, s, cfg.num_heads, cfg.head_dim)
        kk = L.linear(n, lp["wk"]).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        vv = L.linear(n, lp["wv"]).reshape(m, b, s, cfg.num_kv_heads, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        kk = L.rope(kk, positions, cfg.rope_theta)
        o = L.flash_attention(q, kk, vv, positions, positions, window=window)
        xc = xc + L.linear(o.reshape(m, b, s, -1), lp["wo"])
        nn_ = L.rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = xc + L.swiglu_mlp(nn_, lp["w_gate"], lp["w_up"], lp["w_down"])
        if cache_len >= s:
            pad = cache_len - s
            kc = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            kc, vc = kk[:, :, s - cache_len:], vv[:, :, s - cache_len:]
        return xc, (kc.astype(jnp.dtype(cfg.dtype)), vc.astype(jnp.dtype(cfg.dtype)))

    x, (ck, cv) = lax.scan(body, x, params["layers"])
    logits = dense._logits(cfg, params, x[:, :, -1:])[:, :, 0]
    return logits, L.KVCache(k=ck, v=cv)


def prefill_chunk(cfg, params, batch, carry, offset):
    """Chunked prefill: image-patch positions [0, P) and text positions
    [P, ...) ride one position stream.  For positions below P the input
    embedding is the projected patch embedding at that position (the
    token id is ignored); past P it is the token embedding — so mixed
    prompt lengths share the dense chunk body's two compiled shapes."""
    tokens, image_embeds = batch["tokens"], batch["image_embeds"]
    m, b, c = tokens.shape
    p = image_embeds.shape[2]
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)   # (M,B,C)
    tok_x = dense._embed_in(cfg, params, tokens)
    img = project_image(cfg, params, image_embeds)                   # (M,B,P,D)
    idx = jnp.clip(positions, 0, p - 1)[..., None]
    img_x = jnp.take_along_axis(img, jnp.broadcast_to(idx, idx.shape[:3] + (img.shape[-1],)), axis=2)
    x = jnp.where((positions < p)[..., None], img_x.astype(tok_x.dtype), tok_x)
    return dense._prefill_chunk_embeds(cfg, params, x, carry, offset,
                                       valid=batch.get("valid"))


decode_step = dense.decode_step
decode_step_sample = dense.decode_step_sample
make_cache = dense.make_cache
cache_axes = dense.cache_axes
init_chunk_carry = dense.init_chunk_carry
chunk_carry_axes = dense.chunk_carry_axes
