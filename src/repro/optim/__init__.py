from repro.optim.adamw import OptState, adamw_init, adamw_update, sgdm_init, sgdm_update
from repro.optim.schedules import constant, cosine_with_warmup, linear_warmup
