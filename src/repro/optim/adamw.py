"""Optimizers in pure JAX (no optax dependency): AdamW + SGD-momentum,
with global-norm gradient clipping.  Optimizer state mirrors the param
pytree so the launcher's sharding rules apply to it unchanged (moments
shard like their params — the FSDP memory story depends on this)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Pytree         # first moment (or momentum for SGD)
    nu: Pytree | None  # second moment (None for SGD)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def adamw_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), _zeros_like_f32(params))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    grads,
    state: OptState,
    params,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm}


def sgdm_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), None)


def sgdm_update(grads, state: OptState, params, *, lr, momentum=0.9, max_grad_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu
    )
    return new_params, OptState(state.step + 1, mu, None), {"grad_norm": gnorm}
