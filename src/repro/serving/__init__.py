from repro.serving.engine import MultiModelServer, Request, Result
