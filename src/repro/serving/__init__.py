from repro.serving.engine import MultiModelServer, SERVABLE_FAMILIES
from repro.serving.metrics import ServerMetrics
from repro.serving.prefill import ChunkedPrefill, PrefillOut
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (
    POLICIES,
    FIFOScheduler,
    Request,
    Result,
    RoundRobinScheduler,
    Scheduler,
    TokenBudgetScheduler,
    make_scheduler,
)
