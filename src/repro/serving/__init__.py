from repro.serving.engine import MultiModelServer, SERVABLE_FAMILIES
from repro.serving.frontend import (
    AsyncEngine,
    Backpressure,
    EngineClosed,
    TokenStream,
    start_http_server,
)
from repro.serving.metrics import ServerMetrics
from repro.serving.obs import (
    FlightRecorder,
    LogHistogram,
    SLOConfig,
    TenantAccounting,
    Tracer,
    render_prometheus,
)
from repro.serving.prefill import ChunkedPrefill, PrefillOut
from repro.serving.resilience import (
    BrownoutPolicy,
    FaultInjected,
    FaultInjector,
    FaultSpec,
    HealthMonitor,
    Supervisor,
    WatchdogTimeout,
)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (
    POLICIES,
    FIFOScheduler,
    Request,
    Result,
    RoundRobinScheduler,
    Scheduler,
    TokenBudgetScheduler,
    make_scheduler,
)
