"""Multi-model serving engine — the paper's deployment scenario.

M fine-tuned instances of one architecture are NetFuse-merged and served
from a single fused program.  The engine keeps one request queue per
instance (different tasks have different input streams — paper §2.1) and
a fixed (M, B) slot grid of KV-cache entries:

* incoming requests are prefilled one at a time (B'=1) and their KV
  written into a free slot of their instance's row,
* every engine step runs ONE fused decode for the whole (M, B) grid —
  this is the kernel-launch (dispatch) amortization the paper measures,
* slots finish independently (EOS / max_new_tokens) and are refilled
  from their instance's queue — continuous batching at slot granularity
  (per-slot positions; the decode path masks empty slots).

Families with uniform KVCache (dense / moe / vlm) get slot-level cache
surgery; recurrent-state families (ssm / hybrid) are served with
whole-batch admission (documented limitation — their state swap is a
different tree layout).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro import api
from repro.models.layers import KVCache


@dataclasses.dataclass
class Request:
    instance: int                  # which fine-tuned model (task) this targets
    prompt: list[int]
    max_new_tokens: int = 16
    request_id: int = -1


@dataclasses.dataclass
class Result:
    request_id: int
    instance: int
    tokens: list[int]              # generated tokens (excluding prompt)


def _write_slot(cache: KVCache, slot_cache: KVCache, m: int, b: int) -> KVCache:
    """Write a single-request cache (L,1,1,S,KVH,hd) into grid slot (m,b)."""
    def upd(grid, one):
        s = min(one.shape[3], grid.shape[3])
        return lax.dynamic_update_slice(
            grid, one[:, :, :, :s].astype(grid.dtype), (0, m, b, 0, 0, 0)
        )
    return KVCache(k=upd(cache.k, slot_cache.k), v=upd(cache.v, slot_cache.v))


class MultiModelServer:
    """Greedy/temperature decoding over an (M, B) slot grid."""

    def __init__(
        self,
        cfg,
        params,                    # merged params (instances axis = M)
        *,
        slots_per_instance: int,
        max_context: int,
        eos_id: int | None = None,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        assert cfg.family in ("dense", "moe", "vlm"), (
            "slot-level serving supports uniform-KVCache families; "
            "ssm/hybrid use whole-batch serving (see examples)"
        )
        self.cfg = cfg
        self.params = params
        self.m = cfg.num_instances
        self.b = slots_per_instance
        self.max_context = max_context
        self.eos_id = eos_id
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._req_counter = itertools.count()

        self.queues: list[deque[Request]] = [deque() for _ in range(self.m)]
        self.active: list[list[Request | None]] = [
            [None] * self.b for _ in range(self.m)
        ]
        self.generated: dict[int, list[int]] = {}
        self.cache = api.make_cache(cfg, self.m, self.b, max_context)
        self.pos = np.zeros((self.m, self.b), np.int32)
        self.cur_tok = np.zeros((self.m, self.b), np.int32)
        self.slot_busy = np.zeros((self.m, self.b), bool)
        self.steps = 0

        self._decode = jax.jit(
            lambda params, cache, tok, pos: api.decode_step(cfg, params, cache, tok, pos)
        )
        self._prefill = jax.jit(
            lambda params, batch: api.prefill(cfg, params, batch, cache_len=max_context),
            static_argnames=(),
        )

    # -- request admission ---------------------------------------------------

    def submit(self, req: Request) -> int:
        req.request_id = next(self._req_counter)
        self.queues[req.instance].append(req)
        return req.request_id

    def _admit(self):
        from repro.models import common as C
        fam = api.family_module(self.cfg)
        ax = fam.axes(self.cfg)
        for m in range(self.m):
            for b in range(self.b):
                if self.slot_busy[m, b] or not self.queues[m]:
                    continue
                req = self.queues[m].popleft()
                params_m = C.take_instance(self.params, ax, m)
                batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, None]}
                if self.cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros(
                        (1, 1, self.cfg.num_image_patches, self.cfg.vision_embed_dim),
                        jnp.dtype(self.cfg.dtype),
                    )
                last_logits, slot_cache = self._prefill(params_m, batch)
                self.cache = _write_slot(self.cache, slot_cache, m, b)
                first_tok = self._sample(last_logits[0, 0])
                plen = len(req.prompt) + (
                    self.cfg.num_image_patches if self.cfg.family == "vlm" else 0
                )
                self.pos[m, b] = plen
                self.cur_tok[m, b] = first_tok
                self.slot_busy[m, b] = True
                self.active[m][b] = req
                self.generated[req.request_id] = [int(first_tok)]

    def _sample(self, logits) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    # -- engine step ----------------------------------------------------------

    def step(self) -> list[Result]:
        """Admit pending requests, run ONE fused decode over the whole
        (M,B) grid, collect finished slots."""
        self._admit()
        if not self.slot_busy.any():
            return []
        tok = jnp.asarray(self.cur_tok)[..., None]
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        self.steps += 1
        logits = np.asarray(jax.device_get(logits))

        done: list[Result] = []
        for m in range(self.m):
            for b in range(self.b):
                if not self.slot_busy[m, b]:
                    continue
                req = self.active[m][b]
                nxt = (
                    int(np.argmax(logits[m, b])) if self.temperature <= 0
                    else self._sample(jnp.asarray(logits[m, b]))
                )
                gen = self.generated[req.request_id]
                gen.append(nxt)
                self.pos[m, b] += 1
                self.cur_tok[m, b] = nxt
                finished = (
                    len(gen) >= req.max_new_tokens
                    or (self.eos_id is not None and nxt == self.eos_id)
                    or int(self.pos[m, b]) >= self.max_context - 1
                )
                if finished:
                    done.append(Result(req.request_id, m, gen))
                    self.slot_busy[m, b] = False
                    self.active[m][b] = None
                    del self.generated[req.request_id]
        return done

    def run_until_drained(self, max_steps: int = 10_000) -> list[Result]:
        out: list[Result] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.slot_busy.any() and all(not q for q in self.queues):
                return out
        raise RuntimeError("serving did not drain")
