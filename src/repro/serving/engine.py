"""Multi-model serving engine — the paper's deployment scenario.

M fine-tuned instances of one architecture are NetFuse-merged and served
from a single fused program.  The engine owns a fixed (M, B) slot grid
of per-slot decode state and composes four subsystems:

* ``scheduler.py`` — policy-driven admission (fifo / round-robin /
  token-budget fairness) over per-instance request queues (different
  tasks have different input streams — paper §2.1),
* ``prefill.py`` — the unified chunked-prefill runtime: every prompt
  (any family, any length) streams through the family's chainable
  ``api.prefill_chunk`` in fixed-size chunks — ONE compiled shape per
  family (the final partial chunk is padded and masked per position:
  tail folding, DESIGN.md §6.3) — with up to ``prefill_lanes`` requests
  sharing one donated carry tree via an on-device weight-row gather.
  The engine grants the runtime a per-step ``chunk_budget``, so prefill
  work interleaves with decode steps instead of stalling the grid while
  a long prompt admits,
* ``sampling.py`` — greedy/temperature/top-k sampling over the whole
  (M, B) logits grid, fused into the SAME jitted program as the decode
  step: an engine step is exactly ONE device call, with zero per-slot
  host round-trips,
* ``metrics.py`` — per-instance throughput/latency/queue counters.

Multi-step decode (DESIGN.md §6.6): the fused device call is a
``lax.scan`` of up to ``decode_steps`` (K) decode+sample steps over the
whole grid — ONE dispatch returns a (K, M, B) token block, amortizing
per-launch overhead K-fold on top of the paper's M-fold merge.  Stop
conditions live on-device: the scan carries a per-slot alive mask, and
a lane that hits EOS / ``max_new_tokens`` / ``max_context`` mid-block
freezes — its token and position stop advancing and its cache writes
are masked (``tree_select_slots``, mirroring the tail-folding ``valid``
machinery) — so K=1 and K>1 greedy streams are bit-identical per
request.  The historical one-call-per-*step* invariant is thus now
one-call-per-*block*: ``step()`` still makes exactly one fused decode
dispatch, but unrolls the block on the host so per-token ``on_token``
callbacks, metrics, scheduler accounting and finish detection keep
their per-token semantics.  An adaptive policy shrinks the horizon
(k=1 while prefill lanes are in flight; the largest power of two that
no decoding slot overshoots while requests wait in queue) so
multi-step decode never starves the chunked-prefill interleave or
holds freed slots hostage — at most log2(K)+1 compiled block shapes.

Mesh-parametric execution: pass ``mesh=`` (and optionally ``rules=``) to
run the WHOLE serving path — slot surgery, chunked prefill, the fused
decode+sample step, metrics — under an explicit ``jax.sharding.Mesh``
with the instances/batch axes data-parallel and heads/cache_seq tensor-
parallel (the logical-axis rules in ``launch/shardings.py``).  Params
and the grid cache are ``jax.device_put`` once at init with per-leaf
``NamedSharding``; every jit traces under the mesh + rules context so
the model zoo's ``constrain`` calls and the shard-safe slot surgery
(``models/common.tree_take_slot``/``tree_put_slot``) pin layouts — no
host gathers anywhere in the steady state.  ``mesh=None`` (default) is
bit-for-bit today's single-device path.

Every servable family works at slot granularity: uniform-KVCache stacks
(dense / moe / vlm / audio) and recurrent-state families (ssm / hybrid)
both go through the axes-driven slot surgery in ``api.take_state`` /
``api.put_state``, so slots finish independently (EOS / max_new_tokens)
and are refilled from the queues — continuous batching at slot
granularity; the decode path masks stale cache positions and idle slots
simply sample into a discarded lane.

The loop is synchronous and single-caller by design; concurrent clients,
per-request token streams, cancellation and HTTP live one layer up in
``serving/frontend`` (the ``AsyncEngine`` owns this engine's step loop
on a background driver and consumes the ``on_token`` hook, ``cancel``
and ``try_submit`` — DESIGN.md §6.4).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.launch.compat import mesh_context
from repro.models import common as C
from repro.serving.metrics import ServerMetrics
from repro.serving.obs.accounting import TenantAccounting
from repro.serving.obs.flight import FlightRecorder
from repro.serving.obs.trace import Tracer
from repro.serving.prefill import ChunkedPrefill
from repro.serving.resilience.faults import FaultInjector
from repro.serving.resilience.health import HealthMonitor
from repro.serving.sampling import make_grid_sampler
from repro.serving.scheduler import Request, Result, Scheduler, make_scheduler

SERVABLE_FAMILIES = ("dense", "moe", "vlm", "audio", "ssm", "hybrid")


class MultiModelServer:
    """Continuous-batching decode over an (M, B) slot grid."""

    def __init__(
        self,
        cfg,
        params,                    # merged params (instances axis = M)
        *,
        slots_per_instance: int,
        max_context: int,
        eos_id: int | None = None,
        temperature: float = 0.0,
        top_k: int = 0,
        seed: int = 0,
        scheduler: str | Scheduler = "fifo",
        prefill_chunk: int = 32,
        prefill_lanes: int = 4,
        chunk_budget: int = 4,
        tail_fold: bool = True,
        decode_steps: int = 1,
        adaptive_horizon: bool = True,
        donate: bool | None = None,
        mesh=None,
        rules=None,
        tracer: Tracer | None = None,
        faults: FaultInjector | None = None,
        health: HealthMonitor | None = None,
        policy=None,
        accounting=None,
        flight=None,
        slo=None,
    ):
        assert cfg.family in SERVABLE_FAMILIES, cfg.family
        if cfg.family == "hybrid":
            from repro.models import hybrid as H
            need = H.min_serving_context(cfg)
            assert max_context >= need, (
                f"hybrid serving needs max_context >= meta+window = {need}, "
                f"got {max_context}"
            )
        self.cfg = cfg
        self.m = cfg.num_instances
        self.b = slots_per_instance
        self.max_context = max_context
        self.eos_id = eos_id

        from repro.launch.shardings import default_serve_rules
        self.mesh = mesh
        self.rules = default_serve_rules(mesh, rules)

        self.scheduler = (
            make_scheduler(scheduler, self.m, mesh=mesh, rules=self.rules)
            if isinstance(scheduler, str) else scheduler
        )
        # per-instance SLO objectives (§6.9) ride the metrics layer:
        # evaluation is lazy (snapshot-time only), so a configured SLO
        # costs nothing per token
        self.slo = slo
        self.metrics = ServerMetrics(self.m, mesh=mesh, slo=slo)
        # step tracer (DESIGN.md §6.5): always attached, OFF by default —
        # every hot-path call site guards on ``tracer.enabled``, so the
        # disabled path reads one attribute and constructs nothing
        self.tracer = tracer if tracer is not None else Tracer()
        # per-tenant attribution (§6.9): same discipline — always
        # attached, OFF until .start(), every site guards on .enabled
        self.accounting = (accounting if accounting is not None
                           else TenantAccounting(self.m))
        self.accounting.m = self.m
        # crash flight recorder (§6.9): enabled iff a directory is set
        self.flight = flight if flight is not None else FlightRecorder()
        # fault injection (DESIGN.md §6.8): same discipline as the tracer
        # — always attached, disarmed by default, and every call site
        # guards on ``faults.armed`` so the disarmed path runs zero
        # injector code
        self.faults = faults if faults is not None else FaultInjector()
        # per-instance health states (always on — plain counters)
        self.health = health if health is not None else HealthMonitor(self.m)
        # overload brownout policy (optional: None = no shedding/capping)
        self.policy = policy
        # terminal Results produced while an exception was propagating
        # (e.g. a donated scatter failure) — delivered on the next step
        self._pending_failures: list[Result] = []
        self.prefill = ChunkedPrefill(
            cfg, max_context=max_context, chunk=prefill_chunk,
            lanes=prefill_lanes, metrics=self.metrics,
            mesh=mesh, rules=self.rules,
            tail_fold=tail_fold, donate=donate, tracer=self.tracer,
            accounting=self.accounting,
        )
        self.metrics.compiled_shapes_fn = \
            lambda: self.prefill.compiled_shapes
        self.chunk_budget = max(1, chunk_budget)

        self.params = params
        self.cache = api.make_cache(cfg, self.m, self.b, max_context)
        self._grid_shard = self._rep_shard = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.shardings import tree_shardings
            # per-leaf NamedSharding for params and the grid cache, then
            # device_put ONCE — everything downstream consumes committed,
            # rules-conformant arrays
            self.params = jax.device_put(
                params, tree_shardings(self.rules, api.axes(cfg), params)
            )
            self.cache = jax.device_put(
                self.cache,
                tree_shardings(self.rules, api.cache_axes(cfg), self.cache),
            )
            self._grid_shard = NamedSharding(
                mesh, self.rules.spec(("instances", "batch"), (self.m, self.b))
            )
            self._rep_shard = NamedSharding(mesh, P())
        self.pos = np.zeros((self.m, self.b), np.int32)
        self.cur_tok = np.zeros((self.m, self.b), np.int32)
        self.slot_busy = np.zeros((self.m, self.b), bool)
        # reserved for a request still prefilling: busy (not admittable)
        # but not yet decoding — the fused grid step treats it as an
        # idle lane until the chunk runtime delivers its cache rows
        self.slot_prefilling = np.zeros((self.m, self.b), bool)
        self._reserved: dict[int, tuple[int, int]] = {}   # request_id -> slot
        self.active: list[list[Request | None]] = [
            [None] * self.b for _ in range(self.m)
        ]
        self.generated: dict[int, list[int]] = {}
        self.steps = 0
        self._req_counter = 0
        # per-token emission hook for streaming frontends: called as
        # on_token(request_id, token, finished) for every decoding slot
        # right after the fused step's tokens land on the host — the
        # async frontend buffers these and fans them out to per-request
        # streams.  Host-side only; the device program never changes
        self.on_token = None
        self._seed = seed
        self._key = jax.random.PRNGKey(seed)
        if mesh is not None:
            self._key = jax.device_put(self._key, self._rep_shard)
        self.metrics.health_fn = self.health.snapshot
        self.metrics.accounting_fn = self.accounting.snapshot
        # interference attribution: the accounting layer asks the
        # scheduler who is waiting at each settled device call
        self.accounting.queued_fn = self.scheduler.queued_instances
        # flight recorder on fresh quarantine transitions (§6.9); the
        # supervisor hooks crash/watchdog/give-up itself
        if self.flight.enabled:
            self.health.on_quarantine = lambda i: self.flight.dump(
                f"quarantine: instance {i}", server=self)

        self._sample = make_grid_sampler(temperature, top_k)
        # temperature<=0 sampling is key-independent argmax, so the
        # megakernel path may fuse it on-device (decode_step_sample: one
        # final-norm+logits+argmax kernel instead of a (M,B,V) logits
        # round-trip through the XLA sampler)
        self._greedy = temperature <= 0
        self._cache_ax = api.cache_axes(cfg)
        self.decode_steps = max(1, int(decode_steps))
        self.adaptive_horizon = adaptive_horizon

        # one compiled block program per horizon k actually used (full K
        # plus the adaptive policy's smaller powers of two: <= log2(K)+1)
        self._block_fns: dict[int, callable] = {}

        def _dispatch(params, cache, tok, pos, key, alive, remaining, k):
            fn = self._block_fns.get(k)
            if fn is None:
                fn = self._block_fns[k] = self._make_block(k)
            return fn(params, cache, tok, pos, key, alive, remaining)

        # ONE callable invoked exactly once per engine step — tests wrap
        # it to count device dispatches; it routes to the per-k jit
        self._step = _dispatch
        donate = self.prefill.donate
        self._scatter = jax.jit(
            lambda grid, src, i, mm, bb: api.put_state(
                cfg, grid, api.take_state(cfg, src, i, 0), mm, bb
            ),
            donate_argnums=(0,) if donate else (),
        )

    def _make_block(self, k: int):
        """Build the jitted K-step fused decode+sample block: a
        ``lax.scan`` of ``k`` decode steps over the (M, B) grid inside
        one device call, with on-device stop handling.

        Carry: (tok, pos, cache, key, alive, remaining).  Each scan step
        decodes + samples the whole grid, then masks dead lanes: their
        token/position/budget freeze (``jnp.where``) and — for k > 1 —
        their cache writes are reverted (``tree_select_slots``), so a
        lane stopping mid-block leaves cache and position exactly as the
        one-call-per-token protocol would.  Stop mirrors the host finish
        logic bit-for-bit: budget exhausted (remaining), EOS, or
        position reaching ``max_context - 1``.  Returns the (k, M, B)
        token block, the (k, M, B) emitted mask (alive at entry of each
        scan step — exactly the tokens the host unroll consumes), the
        (k, M, B) finite-logits mask (the NaN/Inf guard: False where an
        instance's logits went non-finite, so the host can quarantine
        that row instead of streaming garbage; the fused megakernel
        path never materializes logits, so it reports all-True), the
        cache, and the advanced key (one split per scan step, so K=1
        reproduces the historical per-call split sequence)."""
        cfg, eos_id, max_context = self.cfg, self.eos_id, self.max_context
        sample, cache_ax = self._sample, self._cache_ax
        # greedy + megakernel: decode and sample fused on-device; the key
        # split below still runs so the key sequence (and thus any
        # temperature>0 rerun from a checkpointed key) is path-invariant
        fused_sample = self._greedy and getattr(cfg, "use_pallas_kernels", False)

        def _block_impl(params, cache, tok, pos, key, alive, remaining):
            def body(carry, _):
                tok, pos, cache, key, alive, remaining = carry
                if fused_sample:
                    picked, new_cache = api.decode_step_sample(
                        cfg, params, cache, tok[..., None], pos
                    )
                    ok = jnp.ones_like(alive)
                else:
                    logits, new_cache = api.decode_step(
                        cfg, params, cache, tok[..., None], pos
                    )
                    ok = jnp.all(
                        jnp.isfinite(logits), axis=-1
                    ).reshape(alive.shape)
                if k > 1:
                    # freeze stopped lanes' state between scan steps (at
                    # k == 1 every junk write is overwritten by scatter
                    # before the slot decodes again — the historical
                    # protocol — so the masking would be dead weight)
                    new_cache = C.tree_select_slots(
                        alive, new_cache, cache, cache_ax
                    )
                # pin the grid cache to the rules' layout across steps
                # (no-op without active rules), so donation reuses the
                # buffers and the layout never drifts from the
                # init-time device_put
                new_cache = C.constrain_tree(new_cache, cache_ax)
                key, sub = jax.random.split(key)
                nxt = jnp.where(
                    alive, picked if fused_sample else sample(logits, sub), tok
                )
                new_pos = jnp.where(alive, pos + 1, pos)
                new_rem = jnp.where(alive, remaining - 1, remaining)
                stop = (new_rem <= 0) | (new_pos >= max_context - 1)
                if eos_id is not None:
                    stop = stop | (nxt == eos_id)
                new_carry = (nxt, new_pos, new_cache, key,
                             alive & ~stop, new_rem)
                return new_carry, (nxt, alive, ok)

            carry = (tok, pos, cache, key, alive, remaining)
            (_, _, cache, key, _, _), (toks, emitted, oks) = jax.lax.scan(
                body, carry, None, length=k
            )
            return toks, emitted, oks, cache, key

        # donate the grid cache so decode updates in place instead of
        # copying the whole (M, B, max_context) grid (skipped on CPU,
        # where XLA can't honor it and jit warns; ``donate=`` overrides —
        # the donation-parity tests force it on to prove the donated
        # program never reads an invalidated buffer)
        return jax.jit(
            _block_impl,
            donate_argnums=(1,) if self.prefill.donate else (),
        )

    def _ctx(self):
        """Mesh + rules context for every trace/dispatch (no-op without a
        mesh — jit still traces, just with no active rules)."""
        return mesh_context(self.mesh, self.rules)

    # -- request admission ---------------------------------------------------

    def validate(self, req: Request) -> str | None:
        """The ONE admission-validation path: every reason a request can
        never be served is decided here, before it touches a queue, so
        both submit flavors (raise vs terminal Result) agree exactly."""
        if not 0 <= req.instance < self.m:
            return f"instance {req.instance} out of range [0, {self.m})"
        if not req.prompt:
            return "empty prompt"
        # chunked prefill is length-agnostic: anything whose positions
        # (learned prefix + prompt) fit the serving context is accepted;
        # past that the cache physically cannot hold the prompt
        if len(req.prompt) > self.prefill.max_prompt_len():
            return (
                f"prompt of {len(req.prompt)} tokens exceeds the serving "
                f"context: at most {self.prefill.max_prompt_len()} prompt "
                f"tokens fit max_context={self.max_context}"
            )
        if req.max_new_tokens < 1:
            return f"max_new_tokens must be >= 1, got {req.max_new_tokens}"
        return None

    def try_submit(self, req: Request, *,
                   submit_time: float | None = None) -> int | Result:
        """Queue ``req`` and return its request_id, or — when validation
        fails — return a terminal ``Result(status="rejected")`` instead
        of raising.  Rejected requests still get a request_id and a
        Result, exactly like cancelled/expired ones, so a frontend can
        answer every submission with the same terminal object.

        ``submit_time`` lets a frontend that queues commands ahead of
        the engine (AsyncEngine) pass the CLIENT's clock, so
        TTFT/latency include backpressure parking and command-queue
        wait; without it the stamp is taken here (always — a reused
        Request object never carries a stale epoch into the metrics)."""
        req.request_id = self._req_counter
        self._req_counter += 1
        req.submit_time = (
            submit_time if submit_time is not None else time.perf_counter()
        )
        err = self.validate(req)
        if err is not None:
            self.metrics.note_reject(req.instance)
            return Result(
                req.request_id, req.instance, [],
                prompt_len=len(req.prompt) if req.prompt else 0,
                status="rejected", error=err,
            )
        # a quarantined instance row 503s only its own tenant: the other
        # M-1 instances keep admitting (DESIGN.md §6.8)
        if not self.health.admissible(req.instance):
            self.metrics.note_reject(req.instance)
            return Result(
                req.request_id, req.instance, [],
                prompt_len=len(req.prompt),
                status="unavailable",
                error=f"instance {req.instance} is quarantined "
                      f"({self.health.state(req.instance)}); retry later",
            )
        if self.policy is not None:
            self.policy.cap_request(req)     # brownout: shorter answers
        self.scheduler.submit(req)
        self.metrics.note_submit(req.instance)
        if self.tracer.enabled:
            self.tracer.request_event(req.request_id, "submit",
                                      instance=req.instance)
        return req.request_id

    def submit(self, req: Request) -> int:
        out = self.try_submit(req)
        if isinstance(out, Result):
            raise ValueError(out.error)
        return out

    # -- cancellation / eviction ---------------------------------------------

    def cancel(self, request_id: int, *, status: str = "cancelled") -> Result | None:
        """Abort a request wherever it is in its lifecycle and return its
        terminal Result (partial tokens included), or None if it is not
        live (already finished, rejected, or unknown).

        * queued      — removed from its scheduler queue (never charged),
        * prefilling  — its prefill lane is evicted and its reserved grid
                        slot freed; both are reusable on the next step,
        * decoding    — its slot is freed (the fused grid step treats it
                        as an idle lane; its stale cache rows are masked)
                        and refilled from the queues on the next step.

        Host-side bookkeeping only: no device call, no new compiled
        shape, and the one-device-call-per-step invariant is untouched.
        """
        req = self.scheduler.cancel(request_id)
        if req is not None:                      # still queued
            self.metrics.note_cancel(req.instance, queued=True,
                                     request_id=request_id)
            if self.tracer.enabled:
                self.tracer.request_event(request_id, "cancel",
                                          instance=req.instance, status=status)
            return Result(
                request_id, req.instance, [], prompt_len=len(req.prompt),
                latency_s=time.perf_counter() - req.submit_time,
                status=status,
            )
        if request_id in self._reserved:         # mid-prefill
            m, b = self._reserved.pop(request_id)
            req = self.active[m][b]
            self.prefill.abort(request_id)
            self.slot_busy[m, b] = False
            self.slot_prefilling[m, b] = False
            self.active[m][b] = None
            self.metrics.note_cancel(m, queued=False, request_id=request_id)
            if self.tracer.enabled:
                self.tracer.request_event(request_id, "cancel",
                                          instance=m, status=status)
            return Result(
                request_id, m, [], prompt_len=len(req.prompt),
                latency_s=time.perf_counter() - req.submit_time,
                status=status,
            )
        for m in range(self.m):                  # mid-decode
            for b in range(self.b):
                req = self.active[m][b]
                if req is not None and req.request_id == request_id:
                    gen = self.generated.pop(request_id, [])
                    self.slot_busy[m, b] = False
                    self.active[m][b] = None
                    self.metrics.note_cancel(m, queued=False,
                                             request_id=request_id)
                    if self.tracer.enabled:
                        self.tracer.request_event(request_id, "cancel",
                                                  instance=m, status=status)
                    return Result(
                        request_id, m, gen, prompt_len=len(req.prompt),
                        latency_s=time.perf_counter() - req.submit_time,
                        status=status,
                    )
        return None

    def _admit(self):
        """Move pending requests into prefill lanes, reserving a grid
        slot for each (the slot starts decoding once its chunks land)."""
        lanes = self.prefill.free_lanes()
        # a quarantined row offers zero free slots: the scheduler stops
        # admitting to it, its queue simply waits out the quarantine
        free = {
            i: (int(self.b - self.slot_busy[i].sum())
                if self.health.admissible(i) else 0)
            for i in range(self.m)
        }
        if lanes == 0 or not any(free.values()) \
                or self.scheduler.total_pending() == 0:
            return
        admits = self.scheduler.select(free, limit=lanes)
        for req in admits:
            m = req.instance
            b = next(bb for bb in range(self.b) if not self.slot_busy[m, bb])
            self.slot_busy[m, b] = True
            self.slot_prefilling[m, b] = True
            self._reserved[req.request_id] = (m, b)
            self.active[m][b] = req
            self.prefill.start(req)
            self.metrics.note_admit(m, len(req.prompt))
            if self.accounting.enabled and req.submit_time > 0:
                wait = time.perf_counter() - req.submit_time
                if wait >= 0:
                    self.accounting.note_queue_wait(m, wait)
            if self.tracer.enabled:
                self.tracer.request_event(req.request_id, "admit",
                                          instance=m)

    def _fail_slot(self, req: Request, m: int, b: int, exc,
                   *, poisoned: bool = False) -> Result:
        """Terminally fail an admitted request and restore its slot/lane
        bookkeeping — a failed device call either frees the slot or
        fails the request, never leaks either (exception-safe ``step``,
        DESIGN.md §6.8)."""
        rid = req.request_id
        self._reserved.pop(rid, None)
        if self.slot_prefilling[m, b]:
            self.prefill.abort(rid)
        self.slot_busy[m, b] = False
        self.slot_prefilling[m, b] = False
        self.active[m][b] = None
        gen = self.generated.pop(rid, [])
        if poisoned:
            before = self.health.state(m)
            self.health.note_poisoned(m)
        else:
            before = self.health.state(m)
            self.health.note_failure(m)
        self.metrics.note_failed(m, request_id=rid)
        if self.tracer.enabled:
            self.tracer.request_event(rid, "finish", instance=m,
                                      status="error")
            if before != "quarantined" and self.health.state(m) == \
                    "quarantined":
                self.tracer.request_event(
                    rid, "quarantine", instance=m,
                    status="poisoned" if poisoned else "failures")
        return Result(
            rid, m, gen, prompt_len=len(req.prompt),
            latency_s=time.perf_counter() - req.submit_time,
            status="error", error=f"{type(exc).__name__}: {exc}",
        )

    def _fail_prefilling(self, exc) -> list[Result]:
        """A chunked-prefill pass failed.  Chunks are lane-batched into
        one device call, so the failure cannot be attributed to a single
        lane: every mid-prefill request fails terminally and the lane
        runtime is rebuilt (the failed call may have invalidated the
        donated chunk carry)."""
        failures = []
        rids = sorted(
            rid for rid, (m, b) in self._reserved.items()
            if self.slot_prefilling[m, b]
        )
        for rid in rids:
            m, b = self._reserved[rid]
            failures.append(self._fail_slot(self.active[m][b], m, b, exc))
        self.prefill.reset()
        return failures

    def _finish_prefills(self, completed) -> list[Result]:
        """Scatter completed prefill lanes into their reserved slots and
        flip them to decoding.  Returns terminal Results for requests
        whose scatter failed (their slots are freed, not leaked)."""
        tr = self.tracer
        acct = self.accounting
        failures: list[Result] = []
        for req, out in completed:
            m, b = self._reserved[req.request_id]
            trace_on = tr.enabled
            # accounting shares the tracer's settle (timing-only: the
            # scatter's result is consumed by this step's decode anyway,
            # so numerics — and greedy streams — are untouched)
            obs_on = trace_on or acct.enabled
            if obs_on:
                t0 = time.perf_counter()
            try:
                if self.faults.armed:
                    self.faults.on_call("scatter")
                with self._ctx():
                    self.cache = self._scatter(
                        self.cache, out.cache, out.index, m, b)
            except Exception as exc:
                failures.append(self._fail_slot(req, m, b, exc))
                if self.prefill.donate:
                    # the failed donated call may have invalidated the
                    # grid cache buffer — not locally recoverable; the
                    # supervisor rebuilds it via reset_serving_state
                    self._pending_failures.extend(failures)
                    raise
                continue
            self._reserved.pop(req.request_id)
            self.metrics.note_scatter()
            if obs_on:
                t1 = time.perf_counter()
                # settle so the recorded device time is real execution,
                # not dispatch
                jax.block_until_ready(self.cache)
                t_settled = time.perf_counter()
                if trace_on:
                    tr.device_call(
                        "scatter", t0, t1, t_settled,
                        step=self.steps, capacity=self.m * self.b,
                        active=int((self.slot_busy
                                    & ~self.slot_prefilling).sum()),
                    )
                    tr.request_event(req.request_id, "prefill_done",
                                     instance=m)
                if acct.enabled:
                    # a scatter admits exactly one request: whole wall
                    # to its tenant
                    acct.note_scatter(t_settled - t0, m)
            self.pos[m, b] = out.pos
            self.cur_tok[m, b] = out.last_token
            self.slot_prefilling[m, b] = False
            self.generated[req.request_id] = []
        return failures

    # -- engine step ----------------------------------------------------------

    def _decode_horizon(self) -> int:
        """Steps the next fused block runs (the adaptive-horizon policy,
        DESIGN.md §6.6).  Full ``decode_steps`` when the engine is in
        pure-decode steady state; shrunk to keep the host loop
        responsive when there is admission work to interleave:

        * lanes mid-prefill -> 1, so chunk-budgeted prefill keeps its
          per-step interleave with decode (TTFT is not held behind a
          K-step block),
        * requests waiting in queue -> the largest power of two no
          decoding slot overshoots (its remaining budget), so a slot
          about to finish frees up and refills promptly instead of
          riding out junk steps while the backlog waits.

        Powers of two keep the compiled-shape count at log2(K)+1."""
        K = self.decode_steps
        if K <= 1 or not self.adaptive_horizon:
            return K
        if self.prefill.in_flight():
            return 1
        if self.scheduler.total_pending() > 0:
            rem = [
                self.active[m][b].max_new_tokens
                - len(self.generated[self.active[m][b].request_id])
                for m in range(self.m) for b in range(self.b)
                if self.slot_busy[m, b] and not self.slot_prefilling[m, b]
            ]
            cap = min([K] + rem) if rem else 1
            k = 1
            while k * 2 <= cap:
                k *= 2
            return k
        return K

    def step(self) -> list[Result]:
        """Admit pending requests into prefill lanes, advance prefill by
        at most ``chunk_budget`` device calls, run ONE fused k-step
        decode+sample block over the whole (M, B) grid, unroll its
        (k, M, B) tokens on the host, collect finished slots.
        Prefilling slots ride the grid as idle (masked) lanes, so long
        prompts admit without stalling decode."""
        out: list[Result] = self._pending_failures
        self._pending_failures = []
        if self.policy is not None:
            out.extend(self._apply_policy())
        self._admit()
        if self.prefill.in_flight():
            t0 = time.perf_counter()
            try:
                if self.faults.armed:
                    self.faults.on_call("prefill")
                completed = self.prefill.advance(
                    self.params, self.chunk_budget, step=self.steps)
            except Exception as exc:
                out.extend(self._fail_prefilling(exc))
                completed = []
            stall = time.perf_counter() - t0
            # decode-ready slots sat idle for this long while admission
            # chunks ran — the quantity the chunk budget bounds
            if (self.slot_busy & ~self.slot_prefilling).any():
                self.metrics.note_admission_stall(stall)
            out.extend(self._finish_prefills(completed))
        decoding = self.slot_busy & ~self.slot_prefilling
        if not decoding.any():
            self.health.note_step()
            return out
        k = self._decode_horizon()
        # per-slot decode budget for the on-device stop mask: a lane
        # whose budget (or EOS / context) hits mid-block freezes there
        remaining = np.zeros((self.m, self.b), np.int32)
        for m in range(self.m):
            for b in range(self.b):
                if decoding[m, b]:
                    req = self.active[m][b]
                    remaining[m, b] = (
                        req.max_new_tokens
                        - len(self.generated[req.request_id])
                    )
        if self.mesh is not None:
            # one host->device transfer each, straight to the grid sharding
            def grid_put(x):
                return jax.device_put(x, self._grid_shard)
        else:
            grid_put = jnp.asarray
        tok_dev, pos_dev = grid_put(self.cur_tok), grid_put(self.pos)
        alive_dev, rem_dev = grid_put(decoding), grid_put(remaining)
        # fault hook BEFORE the dispatch: an injected raise/stall lands
        # while host state is still consistent (no half-applied block),
        # so a supervisor reset + requeue replays cleanly
        poison = (
            self.faults.on_call("decode") if self.faults.armed else ()
        )
        tr = self.tracer
        trace_on = tr.enabled
        t0 = time.perf_counter()
        with self._ctx():
            toks, emitted, oks, self.cache, self._key = self._step(
                self.params, self.cache, tok_dev, pos_dev, self._key,
                alive_dev, rem_dev, k,
            )
        # jit return = host dispatch done (device still computing): the
        # per-call cost a K-step block amortizes K-fold
        t_dispatch = time.perf_counter()
        self.steps += 1
        # device_get blocks until the fused block's tokens land: the
        # settled timestamp is end-to-end device-call wall time
        toks, emitted, oks = jax.device_get((toks, emitted, oks))
        t_settled = time.perf_counter()
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        oks = np.array(oks)
        for i in poison:
            # injected NaN: flip the guard for row i exactly as real
            # non-finite logits would (real NaN in the cache would
            # poison every later step — the guard flip is the faithful,
            # recoverable stand-in)
            oks[:, i, :] = False
        block_tokens = int(emitted.sum())
        self.metrics.note_decode_call(steps=k, tokens=block_tokens,
                                      wall_s=t_settled - t0,
                                      dispatch_s=t_dispatch - t0)
        if trace_on:
            tr.device_call(
                "decode", t0, t_dispatch, t_settled,
                step=self.steps,
                active=int(decoding.sum()),
                capacity=self.m * self.b,
                lanes_busy=self.prefill.in_flight(),
                lanes=self.prefill.lanes,
                tokens=block_tokens,
                pending=self.scheduler.total_pending(),
                decode_steps=k,
            )
        acct = self.accounting
        acct_on = acct.enabled
        if acct_on:
            # split this call's settled wall across the tenants occupying
            # the grid, slot-weighted; empty slots bill to idle (§6.9)
            acct.note_decode(
                t_settled - t0,
                [int(c) for c in decoding.sum(axis=1)],
                self.m * self.b,
            )
            replay_counts: dict[int, int] = {}

        # host unroll of the (k, M, B) block: every per-token hook
        # (metrics, scheduler accounting, on_token streaming, finish
        # detection) fires per token, exactly as k separate one-token
        # steps would — only the dispatch count changed
        done: list[Result] = []
        for j in range(k):
            for m in range(self.m):
                for b in range(self.b):
                    # `decoding` is the block-entry mask; slot_busy drops
                    # when a lane finishes mid-unroll, after which its
                    # remaining rows are device-frozen junk — skip them
                    if not (decoding[m, b] and self.slot_busy[m, b]):
                        continue
                    req = self.active[m][b]
                    if not oks[j, m, b]:
                        # NaN/Inf guard tripped for this row: fail the
                        # request and quarantine the instance — the
                        # other M-1 rows stream on untouched
                        done.append(self._fail_slot(
                            req, m, b,
                            RuntimeError("non-finite logits "
                                         "(NaN/Inf token guard)"),
                            poisoned=True,
                        ))
                        continue
                    t = int(toks[j, m, b])
                    gen = self.generated[req.request_id]
                    # recovery replay (DESIGN.md §6.8): the first
                    # ``emit_skip`` tokens were already delivered to the
                    # client before a crash — greedy decode regenerates
                    # them bit-identically, and the engine suppresses
                    # their re-emission so the client-visible stream has
                    # no duplicates
                    replay = len(gen) < req.emit_skip
                    if replay:
                        exp = req.replay_expect
                        if exp is not None and exp[len(gen)] != t:
                            self.metrics.replay_mismatches += 1
                        self.metrics.note_replay(m)
                        if acct_on:
                            replay_counts[m] = replay_counts.get(m, 0) + 1
                    else:
                        self.metrics.note_token(
                            m, first=not gen and not req.emit_skip,
                            submit_time=req.submit_time,
                            request_id=req.request_id,
                        )
                    self.scheduler.note_generated(m, 1)
                    gen.append(t)
                    self.pos[m, b] += 1
                    self.cur_tok[m, b] = t
                    hit_eos = self.eos_id is not None and t == self.eos_id
                    finished = (
                        len(gen) >= req.max_new_tokens
                        or hit_eos
                        or int(self.pos[m, b]) >= self.max_context - 1
                    )
                    if self.on_token is not None and not replay:
                        self.on_token(req.request_id, t, finished)
                    if finished:
                        done.append(Result(
                            req.request_id, m, gen,
                            prompt_len=len(req.prompt),
                            latency_s=time.perf_counter() - req.submit_time,
                            finish_reason="stop" if hit_eos else "length",
                        ))
                        self.metrics.note_complete(m, req.submit_time,
                                                   request_id=req.request_id)
                        self.health.note_success(m)
                        if trace_on:
                            tr.request_event(req.request_id, "finish",
                                             instance=m, status="ok")
                        self.slot_busy[m, b] = False
                        self.active[m][b] = None
                        del self.generated[req.request_id]
        if acct_on and replay_counts:
            # replay view (§6.8/§6.9): token-weighted share of this
            # call's wall spent regenerating already-delivered tokens
            acct.note_replay(replay_counts, t_settled - t0, block_tokens)
        self.health.note_step()
        out.extend(done)
        return out

    # -- overload brownout (DESIGN.md §6.8) -----------------------------------

    def _apply_policy(self) -> list[Result]:
        """One step's brownout bookkeeping: feed queue depth to the
        degraded-mode hysteresis and shed queued requests older than the
        policy's age cutoff (their clients have likely given up)."""
        pol = self.policy
        pol.note_depth(self.scheduler.total_pending())
        if pol.shed_age_s is None:
            return []
        now = time.perf_counter()
        out = []
        for req in self.scheduler.shed_older_than(now - pol.shed_age_s):
            pol.shed_total += 1
            self.metrics.note_shed(req.instance)
            if self.tracer.enabled:
                self.tracer.request_event(req.request_id, "shed",
                                          instance=req.instance)
            out.append(Result(
                req.request_id, req.instance, [],
                prompt_len=len(req.prompt),
                latency_s=now - req.submit_time, status="shed",
                error=f"queued longer than {pol.shed_age_s}s under "
                      f"overload; retry later",
            ))
        return out

    # -- crash recovery (DESIGN.md §6.8) --------------------------------------

    def reset_serving_state(self) -> list[tuple[Request, list[int]]]:
        """Post-crash recovery: tear the serving state back to empty —
        fresh grid cache, zeroed slot bookkeeping, cleared prefill
        lanes, reseeded sampling key — WITHOUT touching compiled
        programs, the request-id counter, or cumulative metrics.
        Returns every live (queued, prefilling, or decoding) request
        with its generated-token prefix, sorted by request_id, for the
        supervisor to ``requeue``."""
        live: list[tuple[Request, list[int]]] = []
        for m in range(self.m):
            for b in range(self.b):
                req = self.active[m][b]
                if req is not None:
                    live.append(
                        (req, list(self.generated.get(req.request_id, []))))
                self.active[m][b] = None
        for req in self.scheduler.drain_all():
            live.append((req, []))
        live.sort(key=lambda t: t[0].request_id)
        self._reserved.clear()
        self.generated.clear()
        self._pending_failures = []
        self.pos[:] = 0
        self.cur_tok[:] = 0
        self.slot_busy[:] = False
        self.slot_prefilling[:] = False
        self.prefill.reset()
        self.metrics.reset_queue_depths()
        with self._ctx():
            cache = api.make_cache(self.cfg, self.m, self.b,
                                   self.max_context)
        key = jax.random.PRNGKey(self._seed)
        if self.mesh is not None:
            from repro.launch.shardings import tree_shardings
            cache = jax.device_put(
                cache, tree_shardings(self.rules, self._cache_ax, cache))
            key = jax.device_put(key, self._rep_shard)
        self.cache = cache
        self._key = key
        return live

    def requeue(self, req: Request, *,
                emitted: list[int] | None = None) -> int:
        """Re-enter a recovered request under its ORIGINAL request_id
        and submit_time (no re-validation — it was validated once).
        ``emitted`` is the token prefix the client already received:
        greedy decode regenerates it bit-identically (a greedy stream
        depends only on its own prompt) and the engine suppresses its
        re-emission via ``emit_skip``, so the client-visible stream
        resumes exactly where it broke — no duplication, no loss."""
        assert req.request_id >= 0, "requeue() needs a submitted request"
        if emitted:
            req.emit_skip = len(emitted)
            req.replay_expect = list(emitted)
        else:
            req.emit_skip = 0
            req.replay_expect = None
        self.scheduler.submit(req)
        self.metrics.note_requeue(req.instance)
        if self.tracer.enabled:
            self.tracer.request_event(req.request_id, "requeue",
                                      instance=req.instance)
        return req.request_id

    def reset_metrics(self) -> ServerMetrics:
        """Fresh counters/sample windows (e.g. after a compile warmup,
        so recorded percentiles carry no warmup outliers); re-points
        every subsystem holding the metrics object."""
        old = self.metrics
        self.metrics = ServerMetrics(self.m, mesh=self.mesh, slo=old.slo)
        self.metrics.compiled_shapes_fn = \
            lambda: self.prefill.compiled_shapes
        self.metrics.health_fn = self.health.snapshot
        self.metrics.resilience_fn = old.resilience_fn
        self.metrics.accounting_fn = self.accounting.snapshot
        self.prefill.metrics = self.metrics
        return self.metrics

    def busy(self) -> bool:
        """Any live work: queued, prefilling, or decoding requests (what
        the async frontend's driver polls between steps)."""
        return bool(
            self.slot_busy.any() or self.prefill.in_flight() > 0
            or self.scheduler.total_pending() > 0
        )

    def run_until_drained(self, max_steps: int = 10_000) -> list[Result]:
        out: list[Result] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.busy():
                return out
        raise RuntimeError("serving did not drain")
