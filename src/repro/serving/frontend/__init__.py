"""Async streaming frontend over the fused (M, B) serving engine.

``async_engine`` owns the synchronous ``MultiModelServer`` step loop on
a background driver task and fans tokens out to concurrent per-request
async streams (cancellation, backpressure, TTL, graceful drain);
``http`` serves it over HTTP/SSE with an OpenAI-style completions route
(stdlib ``asyncio.start_server`` — no new dependencies).  DESIGN.md
§6.4.
"""
from repro.serving.frontend.async_engine import (
    AsyncEngine,
    Backpressure,
    EngineClosed,
    TokenStream,
)
from repro.serving.frontend.http import default_model_map, start_http_server

__all__ = [
    "AsyncEngine",
    "Backpressure",
    "EngineClosed",
    "TokenStream",
    "default_model_map",
    "start_http_server",
]
