"""AsyncEngine — the concurrency layer over the synchronous step loop.

The paper's deployment scenario (§2.1) is M fine-tuned instances serving
*different input streams from different clients*; ``MultiModelServer``
is a synchronous ``step()`` loop only one caller can drive.  This module
is the front door: an asyncio wrapper (stdlib only) that owns the step
loop on a background **driver task** and exposes

* ``submit()`` — returns a per-request :class:`TokenStream`, an async
  iterator yielding tokens as each fused engine step lands, terminated
  by the request's :class:`~repro.serving.scheduler.Result`,
* **cancellation** — ``stream.cancel()`` / ``engine.cancel(rid)`` abort
  a request at ANY lifecycle stage (queued / prefilling / decoding); the
  engine frees its queue entry, prefill lane or grid slot so the next
  step refills it from the queues,
* **backpressure** — ``max_queue_depth`` bounds each instance's queue;
  ``submit(wait=True)`` awaits space, ``wait=False`` raises
  :class:`Backpressure` carrying the observed depth (HTTP maps it to
  429),
* **deadline/TTL** — ``submit(ttl_s=...)``: the driver expires overdue
  requests between steps (terminal ``status="expired"``),
* **graceful drain** — ``drain()`` stops intake and awaits in-flight
  work; ``aclose(drain=False)`` aborts live requests instead.

Concurrency model — single-writer, no locks:

* ALL engine state mutations happen on the driver: client coroutines
  never touch the engine; ``submit``/``cancel`` enqueue commands which
  the driver applies strictly BETWEEN steps, in arrival order.
* The blocking device step runs in the event loop's default executor,
  so the loop stays responsive (HTTP accepts, stream reads) while the
  fused program runs — still exactly ONE device call per engine step
  (under multi-step decode, DESIGN.md §6.6, that one call covers up to
  ``decode_steps`` scan steps; the engine unrolls the token block
  host-side, so ``on_token`` still fires per token and streams flush
  up to K tokens per step).
* Token fan-out: the engine's ``on_token`` hook appends to a buffer
  from the executor thread (GIL-atomic list append); after the step
  future resolves, the driver — back on the loop thread — flushes the
  buffer into each stream's queue and delivers terminal Results.
* Cancellation under multi-step decode keeps its semantics: commands
  apply between steps, so a cancel landing while a K-step block is in
  flight takes effect at the next step boundary — the client keeps the
  partial tokens already unrolled, and the slot frees before the next
  block dispatches.

Determinism: with greedy sampling a stream depends only on its own
prompt (exact chunked prefill + independent slots), so N concurrent
clients receive token streams bit-identical to the same requests pushed
through the synchronous ``run_until_drained`` path, regardless of how
client coroutines interleave (tests/test_serving_async.py, no-mesh and
8-device mesh).
"""
from __future__ import annotations

import asyncio
import time
from collections import deque

from repro.serving.engine import MultiModelServer
from repro.serving.scheduler import Request, Result


class Backpressure(RuntimeError):
    """An instance's bounded queue is full; carries the depth signal."""

    def __init__(self, instance: int, depth: int, limit: int):
        super().__init__(
            f"instance {instance} queue depth {depth} >= limit {limit}"
        )
        self.instance = instance
        self.depth = depth
        self.limit = limit


class EngineClosed(RuntimeError):
    """submit() after drain()/aclose() began."""


class TokenStream:
    """One request's async token stream.

    ``async for tok in stream`` yields generated token ids as the fused
    engine steps land; iteration ends when the request reaches ANY
    terminal state (complete / cancelled / expired / rejected), after
    which ``await stream.result()`` returns the terminal
    :class:`Result` (full token list, status, error).
    """

    def __init__(self, request_id: int, instance: int, engine: "AsyncEngine"):
        self.request_id = request_id
        self.instance = instance
        self._engine = engine
        self._q: asyncio.Queue = asyncio.Queue()
        self._result: Result | None = None
        self._done = asyncio.Event()
        self._exhausted = False
        # every token delivered to this client, in order — the exact
        # client-visible prefix crash recovery must not re-send: the
        # Supervisor requeues from it (DESIGN.md §6.8)
        self.emitted: list[int] = []

    # -- driver side ---------------------------------------------------------

    def _push_token(self, tok: int) -> None:
        self.emitted.append(tok)
        self._q.put_nowait(tok)

    def _push_terminal(self, res: Result) -> None:
        self._result = res
        self._q.put_nowait(res)      # queued AFTER all tokens: ends iteration
        self._done.set()

    # -- client side ---------------------------------------------------------

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        if self._exhausted:
            raise StopAsyncIteration
        item = await self._q.get()
        if isinstance(item, Result):
            self._exhausted = True
            raise StopAsyncIteration
        return item

    async def result(self) -> Result:
        """Await the terminal Result (without requiring iteration)."""
        await self._done.wait()
        return self._result

    def done(self) -> bool:
        return self._done.is_set()

    async def cancel(self) -> bool:
        """Abort this request; True if it was still live."""
        return await self._engine.cancel(self.request_id)


class AsyncEngine:
    """Owns a :class:`MultiModelServer`'s step loop on a driver task and
    fans its token flow out to concurrent per-request streams."""

    def __init__(self, server: MultiModelServer, *, max_queue_depth: int = 0):
        self.server = server
        # per-instance queue bound; 0 = unbounded (no backpressure)
        self.max_queue_depth = max_queue_depth
        # ONE bound-method object, kept for the detach identity checks
        # (each `self._on_token` attribute access builds a fresh bound
        # method, so `is` would never match without this)
        self._hook = self._on_token
        server.on_token = self._hook
        self._tok_buf: list[tuple[int, int]] = []
        self._commands: deque = deque()
        self._streams: dict[int, TokenStream] = {}
        self._deadlines: dict[int, float] = {}
        # pending submit commands per instance: counted into the depth
        # signal so racing submits can't overshoot the bound before the
        # driver applies them
        self._pending_submits: dict[int, int] = {}
        self._loop: asyncio.AbstractEventLoop | None = None
        self._wake: asyncio.Event | None = None
        self._space: asyncio.Condition | None = None
        self._driver: asyncio.Task | None = None
        self._closing = False
        # live Request objects by id — what crash recovery requeues
        # (the engine's own bookkeeping dies with the crash)
        self._requests: dict[int, Request] = {}
        # supervised lifecycle (resilience/supervisor.py): when True the
        # Supervisor owns driver death — the driver leaves streams,
        # commands and request records intact for recovery instead of
        # failing them, and only the Supervisor restarts it
        self.supervised = False
        self._supervisor = None
        # watchdog instrumentation: loop-clock timestamp when the
        # current device step entered the executor (None between steps),
        # and the step's concurrent.futures handle (recovery awaits it —
        # a stalled executor thread cannot be killed, only waited out)
        self._step_started: float | None = None
        self._step_future = None

    # -- lifecycle -----------------------------------------------------------

    def _ensure_started(self) -> None:
        if self._loop is None:
            self._loop = asyncio.get_running_loop()
            self._wake = asyncio.Event()
            self._space = asyncio.Condition()
        # never resurrect a closed/failed driver (its finally sets
        # _closing): submit raises EngineClosed, cancel returns False.
        # Under supervision a dead driver is the Supervisor's to restart
        # — resurrecting it here would race the recovery requeue
        if self._closing:
            return
        if self._driver is None or (self._driver.done()
                                    and not self.supervised):
            self._driver = self._loop.create_task(
                self._drive(), name="engine-driver")

    def _restart_driver(self) -> None:
        """(Supervisor-only) start a fresh driver task after recovery."""
        self._driver = self._loop.create_task(
            self._drive(), name="engine-driver")
        self._wake.set()

    async def __aenter__(self) -> "AsyncEngine":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose(drain=exc == (None, None, None))

    async def _await_stopped(self) -> None:
        """Wait for the step loop to be truly over.  A driver that died
        on an exception already delivered it to every waiter (terminal
        ``status="error"`` Results, ``EngineClosed`` futures), so drain/
        aclose RETURN instead of re-raising — nobody hangs on a queue no
        driver drains, and nobody gets the failure twice.  Under
        supervision, "over" means the Supervisor stopped (clean drain or
        gave up), not any single driver incarnation's death."""
        if self._supervisor is not None and self._supervisor.stopped is not None:
            await self._supervisor.stopped.wait()
            return
        if self._driver is not None:
            try:
                await self._driver
            except BaseException:
                pass

    async def drain(self) -> None:
        """Stop accepting submissions; wait until every in-flight request
        reached its terminal Result and the driver exited."""
        self._ensure_started()
        self._closing = True
        self._wake.set()
        await self._await_stopped()

    async def aclose(self, *, drain: bool = True) -> None:
        """Shut the frontend down: graceful (default — in-flight work
        finishes) or immediate (``drain=False`` — live requests are
        cancelled, their streams end with ``status="cancelled"``)."""
        if self._driver is None or (self._driver.done()
                                    and not self.supervised):
            self._closing = True
            if self.server.on_token is self._hook:
                self.server.on_token = None
            await self._await_stopped()
            return
        self._closing = True
        if not drain:
            # routed through the command queue: the driver applies it
            # between steps, never while the engine is mid-device-call
            self._commands.append(("abort_all",))
        self._wake.set()
        await self._await_stopped()

    # -- client API ----------------------------------------------------------

    def queue_depth(self, instance: int) -> int:
        """The backpressure signal: queued + not-yet-applied submissions
        for this instance (admitted/decoding requests are not queued)."""
        return (self.server.scheduler.depth(instance)
                + self._pending_submits.get(instance, 0))

    async def submit(self, request: Request, *, ttl_s: float | None = None,
                     wait: bool = True) -> TokenStream:
        """Submit a request; returns its :class:`TokenStream`.

        Invalid requests (empty prompt, prompt past the serving context,
        bad instance) do NOT raise: they return a stream that is already
        terminal with ``status="rejected"`` — the same shape every other
        outcome has.  ``ttl_s`` bounds the request's total lifetime;
        overdue requests are expired between steps wherever they are.
        Under a bounded queue (``max_queue_depth``), ``wait=True`` awaits
        space and ``wait=False`` raises :class:`Backpressure`."""
        self._ensure_started()
        if self._closing:
            raise EngineClosed("submit() after drain()/aclose()")
        # client-perceived epoch, taken BEFORE any backpressure parking:
        # TTFT/latency metrics and the TTL deadline both count the wait
        # for queue space and the command-queue delay, not just
        # time-in-engine
        epoch = time.perf_counter()
        deadline = None if ttl_s is None else self._loop.time() + ttl_s
        inst = request.instance
        if self.max_queue_depth and 0 <= inst < self.server.m:
            while self.queue_depth(inst) >= self.max_queue_depth:
                if not wait:
                    raise Backpressure(
                        inst, self.queue_depth(inst), self.max_queue_depth
                    )
                async with self._space:
                    # re-check under the condition lock: the driver's
                    # notify also takes it, so a wakeup between the
                    # outer check and wait() cannot be lost
                    if self._closing:
                        raise EngineClosed(
                            "engine closed while awaiting queue space")
                    if self.queue_depth(inst) < self.max_queue_depth:
                        continue
                    await self._space.wait()
                if self._closing:
                    raise EngineClosed("engine closed while awaiting queue space")
        fut = self._loop.create_future()
        self._pending_submits[inst] = self._pending_submits.get(inst, 0) + 1
        self._commands.append(("submit", request, epoch, deadline, fut))
        self._wake.set()
        return await fut

    def driver_status(self) -> str:
        """Liveness of the driver task (the /healthz signal):
        ``not-started`` / ``running`` / ``recovering`` (died under
        supervision — a restart is coming) / ``stopped`` (clean exit) /
        ``failed`` (died unsupervised — the engine is wedged and the
        HTTP layer serves 503)."""
        if self._driver is None:
            return "not-started"
        if not self._driver.done():
            return "running"
        sup = self._supervisor
        if (self.supervised and sup is not None and sup.stopped is not None
                and not sup.stopped.is_set()):
            return "recovering"
        if self._driver.cancelled():
            return "failed"
        return "failed" if self._driver.exception() is not None else "stopped"

    def in_flight(self) -> int:
        """Requests with live streams (queued + prefilling + decoding)."""
        return len(self._streams)

    async def run_in_step_gap(self, fn):
        """Run ``fn()`` on the driver task strictly BETWEEN engine steps
        and return its result — the single-writer-safe way to mutate
        engine state (reset metrics, toggle tracing) from a client
        coroutine.  When no driver is running (never started, drained,
        or dead) the call runs directly: with the step loop stopped
        there is no device step to race."""
        self._ensure_started()
        if self._closing and (self._driver is None or self._driver.done()):
            return fn()
        fut = self._loop.create_future()
        self._commands.append(("call", fn, fut))
        self._wake.set()
        return await fut

    async def reset_metrics(self) -> None:
        """Zero the metrics window (applied between steps)."""
        await self.run_in_step_gap(self.server.reset_metrics)

    async def set_tracing(self, on: bool) -> dict:
        """Toggle step-trace capture on the live engine (applied between
        steps, so no device call is half-traced).  Starting clears the
        ring; stopping returns the capture's aggregate summary."""
        tracer = self.server.tracer
        if on:
            def fn():
                tracer.start()
                return {"tracing": True}
        else:
            def fn():
                summary = tracer.summary()
                tracer.stop()
                return {"tracing": False, "summary": summary}
        return await self.run_in_step_gap(fn)

    async def set_accounting(self, on: bool) -> dict:
        """Toggle per-tenant attribution (§6.9) on the live engine —
        applied between steps so no device call is half-attributed
        (which would break the conservation invariant).  Stopping
        returns the final ledger snapshot."""
        acct = self.server.accounting
        if on:
            def fn():
                acct.start()
                return {"accounting": True}
        else:
            def fn():
                snap = acct.snapshot()
                acct.stop()
                return {"accounting": False, "snapshot": snap}
        return await self.run_in_step_gap(fn)

    async def cancel(self, request_id: int, *, status: str = "cancelled") -> bool:
        """Abort a live request (queued / prefilling / decoding); its
        stream ends with the partial tokens and the given terminal
        status.  False if the request already reached a terminal state."""
        if request_id not in self._streams:
            return False
        self._ensure_started()
        if self._closing and (self._driver is None or self._driver.done()):
            return False
        fut = self._loop.create_future()
        self._commands.append(("cancel", request_id, status, fut))
        self._wake.set()
        return await fut

    # -- driver --------------------------------------------------------------

    def _on_token(self, request_id: int, token: int, finished: bool) -> None:
        # called from the executor thread mid-step; list.append is
        # GIL-atomic and the driver only reads AFTER the step resolves
        self._tok_buf.append((request_id, token))

    def _finish(self, res: Result) -> None:
        self._deadlines.pop(res.request_id, None)
        self._requests.pop(res.request_id, None)
        stream = self._streams.pop(res.request_id, None)
        if stream is not None:
            stream._push_terminal(res)

    def _fail_pending_commands(self, err: str) -> None:
        """Fail every queued command's future (driver death / supervisor
        give-up): submit/cancel/call waiters get :class:`EngineClosed`
        instead of hanging on a future no driver will ever resolve."""
        while self._commands:
            cmd = self._commands.popleft()
            fut = cmd[-1]
            if asyncio.isfuture(fut) and not fut.done():
                fut.set_exception(EngineClosed(err))
        self._pending_submits.clear()

    def _apply_commands(self) -> None:
        while self._commands:
            cmd = self._commands.popleft()
            if cmd[0] == "submit":
                _, request, epoch, deadline, fut = cmd
                inst = request.instance
                n = self._pending_submits.get(inst, 0) - 1
                if n > 0:
                    self._pending_submits[inst] = n
                else:
                    self._pending_submits.pop(inst, None)
                if fut.cancelled():
                    # the caller gave up (e.g. asyncio.wait_for timeout)
                    # before the command was applied: don't queue a
                    # request nobody holds a stream for
                    continue
                out = self.server.try_submit(request, submit_time=epoch)
                if isinstance(out, Result):          # rejected: born terminal
                    stream = TokenStream(out.request_id, inst, self)
                    stream._push_terminal(out)
                else:
                    stream = TokenStream(out, inst, self)
                    self._streams[out] = stream
                    self._requests[out] = request
                    if deadline is not None:
                        self._deadlines[out] = deadline
                if not fut.cancelled():
                    fut.set_result(stream)
            elif cmd[0] == "cancel":
                _, request_id, status, fut = cmd
                res = self.server.cancel(request_id, status=status)
                if res is not None:
                    self._finish(res)
                if not fut.cancelled():
                    fut.set_result(res is not None)
            elif cmd[0] == "call":
                _, fn, fut = cmd
                if fut.cancelled():
                    continue
                try:
                    out = fn()
                except BaseException as e:   # surfaced to the caller only
                    fut.set_exception(e)
                else:
                    fut.set_result(out)
            elif cmd[0] == "abort_all":
                for rid in list(self._streams):
                    res = self.server.cancel(rid)
                    if res is not None:
                        self._finish(res)

    def _expire(self) -> None:
        now = self._loop.time()
        for rid, deadline in list(self._deadlines.items()):
            if now >= deadline:
                res = self.server.cancel(rid, status="expired")
                if res is not None:
                    res.error = "deadline exceeded"
                    self._finish(res)
                else:
                    self._deadlines.pop(rid, None)

    async def _notify_space(self) -> None:
        async with self._space:
            self._space.notify_all()

    async def _drive(self) -> None:
        loop = self._loop
        try:
            while True:
                self._apply_commands()
                self._expire()
                if not self.server.busy():
                    await self._notify_space()
                    if self._commands:
                        continue
                    if self._closing:
                        return
                    self._wake.clear()
                    # re-check: a command may have arrived between the
                    # busy() check and clearing the wake flag
                    if self._commands or self.server.busy():
                        continue
                    await self._wake.wait()
                    continue
                del self._tok_buf[:]
                # driver-site fault hook: counted once per device step
                # (not per loop iteration — idle wakeups depend on event
                # loop timing and would break schedule determinism) and
                # fired BEFORE dispatch, so a crash here leaves host
                # state consistent for replay
                inj = getattr(self.server, "faults", None)
                if inj is not None and inj.armed:
                    inj.on_call("driver")
                # the ONLY device work in the frontend: one synchronous
                # engine step, off the loop thread.  _step_started feeds
                # the Supervisor's watchdog; _step_future lets recovery
                # wait out a step already in flight (an executor thread
                # cannot be killed, only awaited)
                self._step_started = loop.time()
                self._step_future = loop.run_in_executor(
                    None, self.server.step)
                try:
                    done = await self._step_future
                finally:
                    self._step_started = None
                for rid, tok in self._tok_buf:
                    stream = self._streams.get(rid)
                    if stream is not None:
                        stream._push_token(tok)
                for res in done:
                    self._finish(res)
                await self._notify_space()
        except BaseException as e:
            if self.supervised:
                # the Supervisor owns driver death: leave streams,
                # request records and queued commands intact — recovery
                # requeues every live request with its emitted prefix
                # and the restarted driver applies the surviving
                # commands
                raise
            # unsupervised: fail loudly but leave no waiter hanging —
            # pending commands and live streams all observe the error,
            # each stream keeping the tokens already delivered
            err = f"engine driver failed: {e!r}"
            self._fail_pending_commands(err)
            for rid in list(self._streams):
                stream = self._streams[rid]
                self._finish(Result(
                    rid, stream.instance, list(stream.emitted),
                    status="error", error=err,
                ))
            raise
        finally:
            if not self.supervised:
                self._closing = True
                # detach the token hook however the driver exits (drain,
                # aclose, failure): a dead engine's _tok_buf must not
                # keep accumulating tokens from later synchronous
                # serving, and the identity guard never silences a NEWER
                # AsyncEngine attached to the same server.  Supervised
                # drivers keep both — the Supervisor restarts the loop
                # and detaches only on final shutdown/give-up
                if self.server.on_token is self._hook:
                    self.server.on_token = None
            await self._notify_space()
