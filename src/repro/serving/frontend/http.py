"""HTTP serving layer over :class:`AsyncEngine` — stdlib asyncio only.

A deliberately small HTTP/1.1 server (``asyncio.start_server``; no
aiohttp/uvicorn — the container bakes no web framework) exposing the
fused (M, B) engine to network clients:

* ``POST /v1/completions`` — OpenAI-style completion over token ids
  (this repro has no tokenizer: ``prompt`` is a list of ints, responses
  carry token ids).  ``model`` routes to the merged instance row — an
  int, a digit string, or a name in the server's model map (default
  ``model-<i>``).  ``"stream": true`` answers with Server-Sent Events:
  one ``data:`` JSON chunk per generated token as each fused engine
  step lands, a final chunk with ``finish_reason``, then ``data:
  [DONE]``.  Client disconnect mid-stream cancels the request — the
  engine frees its queue entry / prefill lane / decode slot on the next
  step.  (A half-close — ``shutdown(SHUT_WR)`` while still reading —
  is indistinguishable from abandonment at this layer and is treated
  as a disconnect too: keep the write side open for the whole stream.)
* ``GET /v1/models`` — the instance-row routing table.
* ``GET /metrics`` — the full ``ServerMetrics.snapshot()`` JSON,
  including per-instance TTFT/ITL p50/p95/p99 and the multi-step
  decode amortization figures (``decode_device_calls``,
  ``tokens_per_device_call`` — DESIGN.md §6.6).  ``Accept: text/plain``
  (or any ``openmetrics`` media type) negotiates Prometheus text
  exposition instead — same counters, scrapable.
* ``POST /metrics/reset`` — zero the metrics window (applied between
  engine steps; cumulative compiled-shape counts survive).
* ``GET /healthz`` — driver-task liveness, per-instance queue depths
  and health states (healthy/degraded/quarantined/probation, §6.8),
  in-flight request count, and supervision counters; answers 503 once
  the driver task has died unsupervised (a supervised driver mid-
  recovery reports ``"recovering"`` and stays 200).  Requests routed
  to a quarantined instance answer 503 + ``Retry-After`` — the other
  M−1 instances are unaffected.
* ``GET /debug/trace`` — the step tracer's capture as Chrome-trace
  JSON (load in Perfetto / chrome://tracing); ``POST
  /debug/trace/start`` / ``/debug/trace/stop`` toggle capture on the
  live engine (stop returns the aggregate summary).

Backpressure maps to HTTP: a full bounded queue answers ``429`` with
the queue depth in the body and a ``Retry-After`` hint (the engine-side
``submit(wait=False)`` path); invalid requests (empty prompt, prompt
past the serving context, unknown model) answer ``400``/``404`` from
the SAME validation that governs the Python API (terminal
``status="rejected"`` Results).

One request per connection (``Connection: close``) keeps the parser
trivial; SSE responses are delimited by connection close, so no chunked
framing is needed.
"""
from __future__ import annotations

import asyncio
import json

from repro.serving.frontend.async_engine import (
    AsyncEngine,
    Backpressure,
    EngineClosed,
)
from repro.serving.scheduler import Request

MAX_BODY_BYTES = 8 << 20
MAX_HEADER_LINES = 100


async def _watch_eof(reader) -> None:
    """Resolve only at client EOF, discarding (not buffering) anything
    the client keeps sending — the disconnect signal must not be an
    unbounded memory sink."""
    while await reader.read(4096):
        pass


def default_model_map(num_instances: int) -> dict[str, int]:
    return {f"model-{i}": i for i in range(num_instances)}


# -- tiny HTTP plumbing ------------------------------------------------------


async def _read_request(reader):
    """Parse one HTTP/1.1 request: (method, path, headers, body) or None
    on EOF/garbage."""
    try:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers = {}
        for _ in range(MAX_HEADER_LINES):
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        else:
            return None                   # header flood: drop the request
        n = int(headers.get("content-length", 0))
        if n > MAX_BODY_BYTES:
            return None
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body
    except (asyncio.IncompleteReadError, ValueError, UnicodeDecodeError):
        return None


def _write_response(writer, status: int, payload, *,
                    ctype: str = "application/json", extra=()) -> None:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              405: "Method Not Allowed", 429: "Too Many Requests",
              500: "Internal Server Error",
              503: "Service Unavailable"}.get(status, "OK")
    body = payload if isinstance(payload, bytes) else (
        json.dumps(payload).encode() + b"\n")
    head = (
        f"HTTP/1.1 {status} {reason}\r\n"
        f"Content-Type: {ctype}\r\n"
        f"Content-Length: {len(body)}\r\n"
        f"Connection: close\r\n"
        + "".join(f"{k}: {v}\r\n" for k, v in extra)
        + "\r\n"
    )
    writer.write(head.encode("latin-1") + body)


def _error(writer, status: int, message: str, extra=(), **fields) -> None:
    _write_response(
        writer, status,
        {"error": {"message": message, "type": "invalid_request_error"
                   if status < 500 else "server_error", **fields}},
        extra=extra,
    )


# -- /v1/completions ---------------------------------------------------------


def _retry_after(engine: AsyncEngine) -> str:
    """Retry-After hint (seconds, integer-formatted) from the engine's
    brownout policy; 1s when no policy is wired."""
    pol = getattr(engine.server, "policy", None)
    secs = pol.retry_after_s if pol is not None else 1.0
    return str(max(1, int(round(secs))))


def _resolve_instance(model, model_map: dict[str, int], m: int):
    if isinstance(model, bool):        # JSON true/false is an int subclass
        return None
    if isinstance(model, int):
        return model if 0 <= model < m else None
    if isinstance(model, str):
        if model in model_map:
            return model_map[model]
        if model.isdigit() and int(model) < m:
            return int(model)
    return None


def _chunk(res_id: int, model, token=None, finish_reason=None) -> bytes:
    payload = {
        "id": f"cmpl-{res_id}",
        "object": "text_completion.chunk",
        "model": model,
        "choices": [{
            "index": 0,
            "token": token,
            "finish_reason": finish_reason,
        }],
    }
    return b"data: " + json.dumps(payload).encode() + b"\n\n"


def _finish_reason(res) -> str:
    # OpenAI vocabulary where it exists ("stop" = EOS, "length" =
    # max_tokens/context cap); our terminal statuses otherwise
    if res.status == "ok":
        return res.finish_reason or "length"
    return res.status


async def _completions(engine: AsyncEngine, model_map, payload,
                       reader, writer) -> None:
    model = payload.get("model", 0)
    instance = _resolve_instance(model, model_map, engine.server.m)
    if instance is None:
        _error(writer, 404, f"unknown model {model!r}; see GET /v1/models")
        return
    prompt = payload.get("prompt")
    if isinstance(prompt, str):
        _error(writer, 400,
               "this server decodes token ids (no tokenizer): send "
               "'prompt' as a list of ints")
        return
    if not isinstance(prompt, list) or not all(
            isinstance(t, int) for t in prompt):
        _error(writer, 400, "'prompt' must be a list of token ids (ints)")
        return
    try:
        max_tokens = int(payload.get("max_tokens", 16))
        ttl_s = payload.get("ttl_s")
        ttl_s = float(ttl_s) if ttl_s is not None else None
    except (TypeError, ValueError):
        _error(writer, 400, "'max_tokens'/'ttl_s' must be numeric")
        return
    try:
        stream = await engine.submit(
            Request(instance=instance, prompt=prompt,
                    max_new_tokens=max_tokens),
            ttl_s=ttl_s, wait=False,
        )
    except Backpressure as e:
        _error(writer, 429, str(e), queue_depth=e.depth,
               queue_limit=e.limit,
               extra=(("Retry-After", _retry_after(engine)),))
        return
    except EngineClosed as e:
        # connection accepted during graceful shutdown (or after a
        # driver failure): answer, don't drop the socket
        _error(writer, 503, str(e))
        return

    # quarantine / brownout rejections are born terminal: answer 503
    # with a Retry-After BEFORE committing to a 200/SSE response, so
    # load balancers see a retryable signal while the other M-1
    # instances keep serving 200s
    if stream.done():
        res = await stream.result()
        if res.status in ("unavailable", "shed"):
            _error(writer, 503, res.error, request_id=res.request_id,
                   reason=res.status,
                   extra=(("Retry-After", _retry_after(engine)),))
            return

    if not payload.get("stream", False):
        # same abandonment policy as the SSE branch: a client that went
        # away must not hold a decode slot to max_tokens — under the
        # bounded-queue/429 regime zombie requests would steal capacity
        # live clients get rejected for
        eof_watch = asyncio.ensure_future(_watch_eof(reader))
        result_t = asyncio.ensure_future(stream.result())
        try:
            await asyncio.wait({eof_watch, result_t},
                               return_when=asyncio.FIRST_COMPLETED)
            if not result_t.done():
                await stream.cancel()
            res = await result_t
        finally:
            eof_watch.cancel()
        if res.status == "cancelled":
            return                       # nobody is listening
        if res.status == "rejected":
            _error(writer, 400, res.error, request_id=res.request_id)
            return
        _write_response(writer, 200, {
            "id": f"cmpl-{res.request_id}",
            "object": "text_completion",
            "model": model,
            "instance": res.instance,
            "choices": [{
                "index": 0,
                "tokens": res.tokens,
                "finish_reason": _finish_reason(res),
            }],
            "usage": {
                "prompt_tokens": res.prompt_len,
                "completion_tokens": len(res.tokens),
            },
            "status": res.status,
            "latency_s": res.latency_s,
        })
        return

    # SSE: headers first, then one data: chunk per token as steps land.
    # A rejected request still streams — exactly one terminal chunk.
    writer.write(
        b"HTTP/1.1 200 OK\r\n"
        b"Content-Type: text/event-stream\r\n"
        b"Cache-Control: no-cache\r\n"
        b"Connection: close\r\n\r\n"
    )
    # watch for client disconnect: a client that closed its socket can't
    # receive more tokens — reading EOF is the portable signal (write
    # errors may lag the close by a full socket buffer).  _watch_eof
    # resolves only at EOF, so pipelined junk can't trigger it (and is
    # discarded, not buffered); a half-close is deliberately treated as
    # abandonment (see module doc)
    eof_watch = asyncio.ensure_future(_watch_eof(reader))
    try:
        it = stream.__aiter__()
        while True:
            # race the next token against client EOF: a disconnect is
            # noticed even while the request is still queued/prefilling
            # (no tokens flowing yet), so zombies never hold capacity
            next_t = asyncio.ensure_future(it.__anext__())
            await asyncio.wait({next_t, eof_watch},
                               return_when=asyncio.FIRST_COMPLETED)
            if eof_watch.done():
                next_t.cancel()
                raise ConnectionResetError("client disconnected")
            try:
                tok = await next_t
            except StopAsyncIteration:
                break
            writer.write(_chunk(stream.request_id, model, token=tok))
            await writer.drain()
        res = await stream.result()
        writer.write(_chunk(res.request_id, model,
                            finish_reason=_finish_reason(res)))
        writer.write(b"data: [DONE]\n\n")
        await writer.drain()
    except (ConnectionResetError, ConnectionAbortedError, BrokenPipeError):
        await stream.cancel()
    finally:
        eof_watch.cancel()


# -- server ------------------------------------------------------------------


async def _handle(engine: AsyncEngine, model_map, reader, writer) -> None:
    try:
        parsed = await _read_request(reader)
        if parsed is not None:
            method, path, _headers, body = parsed
            path = path.split("?", 1)[0]
            if path == "/v1/completions" and method == "POST":
                try:
                    payload = json.loads(body or b"{}")
                    assert isinstance(payload, dict)
                except (json.JSONDecodeError, AssertionError):
                    _error(writer, 400, "body must be a JSON object")
                else:
                    await _completions(engine, model_map, payload,
                                       reader, writer)
            elif path == "/v1/models" and method == "GET":
                # per-instance SLO state (ok/burning/violated) rides the
                # model rows when objectives are configured (§6.9)
                slo_states = engine.server.metrics.slo_states()
                _write_response(writer, 200, {
                    "object": "list",
                    "data": [
                        {"id": name, "object": "model", "instance": idx,
                         "health": engine.server.health.state(idx),
                         "slo": (slo_states[idx] if slo_states is not None
                                 else None)}
                        for name, idx in sorted(model_map.items(),
                                                key=lambda kv: kv[1])
                    ],
                })
            elif path == "/v1/slo" and method == "GET":
                _write_response(writer, 200,
                                engine.server.metrics.slo_report())
            elif path == "/metrics" and method == "GET":
                snap = engine.server.metrics.snapshot()
                accept = _headers.get("accept", "")
                if "text/plain" in accept or "openmetrics" in accept:
                    from repro.serving.obs.prometheus import render
                    _write_response(
                        writer, 200, render(snap).encode(),
                        ctype="text/plain; version=0.0.4; charset=utf-8",
                    )
                else:
                    _write_response(writer, 200, snap)
            elif path == "/metrics/reset" and method == "POST":
                await engine.reset_metrics()
                _write_response(writer, 200, {"status": "reset"})
            elif path == "/healthz" and method == "GET":
                status = engine.driver_status()
                # a failed driver means no step will ever run again:
                # the load balancer must stop routing here.  A
                # "recovering" driver (died under supervision, restart
                # pending) is NOT dead — keep answering 200 so the
                # blip stays client-invisible
                dead = status == "failed"
                sup = engine._supervisor
                _write_response(writer, 503 if dead else 200, {
                    "status": "error" if dead else "ok",
                    "driver": status,
                    "busy": engine.server.busy(),
                    "in_flight": engine.in_flight(),
                    "queue_depths": engine.server.scheduler.depths(),
                    "tracing": engine.server.tracer.enabled,
                    # multi-step decode horizon (DESIGN.md §6.6): scan
                    # steps fused per decode device call
                    "decode_steps": engine.server.decode_steps,
                    # per-instance health lifecycle (§6.8): healthy /
                    # degraded / quarantined / probation
                    "instance_health": engine.server.health.states(),
                    # per-instance SLO state next to health (§6.9);
                    # None when no objectives are configured
                    "slo": engine.server.metrics.slo_states(),
                    "resilience": (sup.snapshot() if sup is not None
                                   else None),
                })
            elif path == "/debug/trace" and method == "GET":
                _write_response(writer, 200,
                                engine.server.tracer.export_chrome())
            elif path == "/debug/trace/start" and method == "POST":
                _write_response(writer, 200,
                                await engine.set_tracing(True))
            elif path == "/debug/trace/stop" and method == "POST":
                _write_response(writer, 200,
                                await engine.set_tracing(False))
            elif path == "/debug/flight" and method == "GET":
                flight = engine.server.flight
                _write_response(writer, 200, {
                    "enabled": flight.enabled,
                    "directory": flight.directory,
                    "count": len(flight),
                    "dumps": flight.latest(),
                })
            elif path in ("/v1/completions", "/v1/models", "/v1/slo",
                          "/metrics", "/metrics/reset", "/healthz",
                          "/debug/trace", "/debug/trace/start",
                          "/debug/trace/stop", "/debug/flight"):
                _error(writer, 405, f"method {method} not allowed on {path}")
            else:
                _error(writer, 404, f"no route for {method} {path}")
        await writer.drain()
    except (ConnectionResetError, ConnectionAbortedError, BrokenPipeError):
        pass
    except Exception as e:        # noqa: BLE001 — a handler bug must
        # answer 500, not silently drop the socket + log an unretrieved
        # task exception
        try:
            _error(writer, 500, f"{type(e).__name__}: {e}")
            await writer.drain()
        except Exception:
            pass
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, ConnectionAbortedError, BrokenPipeError):
            pass


async def start_http_server(engine: AsyncEngine, host: str = "127.0.0.1",
                            port: int = 8000, *,
                            model_map: dict[str, int] | None = None):
    """Serve the engine over HTTP; returns the ``asyncio.Server`` (use
    ``server.sockets[0].getsockname()`` for the bound port, ``async with
    server: await server.serve_forever()`` to run)."""
    mm = dict(model_map) if model_map is not None else default_model_map(
        engine.server.m)

    async def handler(reader, writer):
        await _handle(engine, mm, reader, writer)

    return await asyncio.start_server(handler, host, port)
