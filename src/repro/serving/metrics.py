"""Per-instance serving metrics.

The paper's deployment scenario is M task streams through one fused
program; operators need to see each task's share.  ``ServerMetrics``
keeps cheap host-side counters per instance — throughput, latency,
time-to-first-token, inter-token latency, queue depth — plus engine-wide
counters (fused decode steps, prefill batches/compiles).  TTFT and ITL
percentiles come from always-on log-bucketed histograms
(``obs/slo.py``): unlike the old bounded sample windows — which evict
the oldest samples and so report the tail of the last few minutes, not
of the run — histogram p50/p95/p99 are unbiased over the whole window
at O(buckets) memory, and export as real Prometheus ``histogram``
families.  The bounded deques remain as a last-N DEBUG view
(``ttft_recent_ms``) and as the sliding window the SLO burn-rate math
wants (§6.9).  ``snapshot()`` returns plain dicts (JSON-able, used by
benchmarks/serve_bench.py); ``format_table()`` renders the
per-instance report printed by ``repro.launch.serve``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable

from repro.serving.obs.slo import (
    LogHistogram,
    SLOConfig,
    evaluate_availability,
    evaluate_objective,
    worst_state,
)

# per-instance last-N latency window: the recent/debug view and the SLO
# burn-rate window — percentiles come from the histograms
MAX_LATENCY_SAMPLES = 4096


def percentiles(samples, scale: float = 1e3) -> dict | None:
    """p50/p95/p99 of ``samples`` (nearest-rank), scaled (default s->ms);
    None when there are no samples — JSON-able either way."""
    if not samples:
        return None
    xs = sorted(samples)
    n = len(xs)

    def q(p):
        return scale * xs[min(n - 1, max(0, -(-p * n // 100) - 1))]

    return {"p50": q(50), "p95": q(95), "p99": q(99)}


@dataclasses.dataclass
class InstanceStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    cancelled: int = 0             # client cancel / disconnect / expiry
    rejected: int = 0              # failed submit-time validation (also
    #                              # counts quarantine 503s)
    failed: int = 0                # terminally errored after admission
    #                              # (device-call failure / NaN guard)
    shed: int = 0                  # dropped from queue by brownout
    requeued: int = 0              # crash-recovery re-submissions
    prompt_tokens: int = 0
    generated_tokens: int = 0
    queue_depth: int = 0           # current, updated on submit/admit
    queue_peak: int = 0
    ttft_sum: float = 0.0          # submit -> first generated token
    ttft_n: int = 0
    latency_sum: float = 0.0       # submit -> completion
    latency_n: int = 0
    ttft_samples: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=MAX_LATENCY_SAMPLES))
    itl_samples: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=MAX_LATENCY_SAMPLES))
    # unbounded-run percentiles + Prometheus histogram exposition
    ttft_hist: LogHistogram = dataclasses.field(default_factory=LogHistogram)
    itl_hist: LogHistogram = dataclasses.field(default_factory=LogHistogram)


class ServerMetrics:
    def __init__(self, num_instances: int,
                 clock: Callable[[], float] = time.perf_counter, mesh=None,
                 slo: SLOConfig | None = None):
        self.m = num_instances
        self.clock = clock
        # per-instance SLO objectives (§6.9); None = not configured
        # (snapshot carries no "slo" block, /v1/slo reports unconfigured)
        self.slo = slo
        self.per_instance = [InstanceStats() for _ in range(num_instances)]
        self.decode_steps = 0        # fused (M, B)-grid decode+sample steps
        self.decode_calls = 0        # fused decode device calls (blocks of
                                     # up to K scan steps — DESIGN.md §6.6;
                                     # == decode_steps when K == 1)
        self.decode_tokens = 0       # real tokens emitted by those calls
        self.decode_wall_s = 0.0     # settled wall inside those calls
                                     # (dispatch -> tokens on host)
        self.decode_dispatch_s = 0.0  # host dispatch slice of that wall
                                      # (call -> jit return) — the cost
                                      # K-step blocks amortize K-fold
        self.prefill_batches = 0     # chunk/tail prefill device calls
        self.prefill_requests = 0    # lane-steps served by them
        self.prefill_tokens = 0      # real (non-padded) positions prefilled
        self.prefill_wall_s = 0.0    # settled wall time inside advance()
        self.scatter_calls = 0       # prefill-lane -> grid-slot scatters
        self.admitted = 0            # requests bound to a prefill lane
        # live view of the prefill runtime's compiled-shape count (the
        # engine wires a callable so snapshots can spot a recompile
        # regression without serve_bench's out-of-band bookkeeping; a
        # fresh window after reset_metrics still reads the true
        # cumulative count)
        self.compiled_shapes_fn: Callable[[], int] | None = None
        # wall time decode-ready slots sat idle while admission chunks
        # ran — what the engine's chunk_budget bounds per step
        self.admission_stall_s = 0.0
        # resilience (DESIGN.md §6.8): the Supervisor wires a snapshot
        # callable (restarts/retries/watchdog counters); the health
        # monitor likewise.  Unwired, snapshots carry zeros/None so the
        # Prometheus rows are always present
        self.resilience_fn: Callable[[], dict] | None = None
        self.health_fn: Callable[[], dict] | None = None
        # per-tenant attribution (§6.9): the engine wires
        # TenantAccounting.snapshot; unwired or disabled, snapshots
        # carry no "accounting" block
        self.accounting_fn: Callable[[], dict] | None = None
        self.replayed_tokens = 0     # regenerated with emission suppressed
        self.replay_mismatches = 0   # replayed token != delivered prefix
        self.started = clock()
        # per-request arrival time of the previous token (ITL deltas);
        # entries live exactly as long as the request decodes
        self._last_token_t: dict[int, float] = {}
        # the async frontend runs the step loop (note_token appends) on
        # an executor thread while snapshot() may serve GET /metrics on
        # the event-loop thread — guard the sample windows so iteration
        # never races an append
        self._lock = threading.Lock()
        # mesh-parametric serving: record the grid's mesh geometry so
        # snapshots carry per-device throughput (serve_bench JSON)
        self.mesh_shape = dict(mesh.shape) if mesh is not None else None
        self.num_devices = mesh.size if mesh is not None else 1

    # -- engine hooks --------------------------------------------------------

    def note_submit(self, instance: int) -> None:
        st = self.per_instance[instance]
        st.submitted += 1
        st.queue_depth += 1
        st.queue_peak = max(st.queue_peak, st.queue_depth)

    def note_reject(self, instance: int) -> None:
        if 0 <= instance < self.m:
            self.per_instance[instance].rejected += 1

    def note_admit(self, instance: int, prompt_len: int) -> None:
        st = self.per_instance[instance]
        st.admitted += 1
        st.queue_depth -= 1
        st.prompt_tokens += prompt_len
        self.admitted += 1

    def note_prefill_batch(self, num_requests: int, num_tokens: int = 0) -> None:
        self.prefill_batches += 1
        self.prefill_requests += num_requests
        self.prefill_tokens += num_tokens

    def note_prefill_wall(self, seconds: float) -> None:
        self.prefill_wall_s += seconds

    def note_decode_call(self, steps: int = 1, tokens: int = 0,
                         wall_s: float = 0.0,
                         dispatch_s: float = 0.0) -> None:
        """One fused decode device call covering ``steps`` scan steps
        and emitting ``tokens`` real (non-frozen-lane) tokens over
        ``wall_s`` seconds of settled dispatch-to-host wall time, of
        which ``dispatch_s`` was spent on host-side dispatch."""
        self.decode_calls += 1
        self.decode_steps += steps
        self.decode_tokens += tokens
        self.decode_wall_s += wall_s
        self.decode_dispatch_s += dispatch_s

    def note_scatter(self) -> None:
        self.scatter_calls += 1

    def note_admission_stall(self, seconds: float) -> None:
        self.admission_stall_s += seconds

    def note_token(self, instance: int, *, first: bool, submit_time: float,
                   request_id: int | None = None) -> None:
        st = self.per_instance[instance]
        st.generated_tokens += 1
        now = self.clock()
        with self._lock:
            if first:
                ttft = now - submit_time
                st.ttft_sum += ttft
                st.ttft_n += 1
                st.ttft_samples.append(ttft)
                st.ttft_hist.record(ttft)
            elif request_id is not None and request_id in self._last_token_t:
                itl = now - self._last_token_t[request_id]
                st.itl_samples.append(itl)
                st.itl_hist.record(itl)
            if request_id is not None:
                self._last_token_t[request_id] = now

    def note_complete(self, instance: int, submit_time: float,
                      request_id: int | None = None) -> None:
        st = self.per_instance[instance]
        st.completed += 1
        st.latency_sum += self.clock() - submit_time
        st.latency_n += 1
        if request_id is not None:
            self._last_token_t.pop(request_id, None)

    def note_cancel(self, instance: int, *, queued: bool,
                    request_id: int | None = None) -> None:
        """A request left the system without completing (client cancel,
        disconnect, deadline expiry) — from the queue (``queued=True``,
        still counted in queue_depth) or from a prefill lane / decode
        slot (already admitted)."""
        if 0 <= instance < self.m:
            st = self.per_instance[instance]
            st.cancelled += 1
            if queued:
                st.queue_depth -= 1
        if request_id is not None:
            self._last_token_t.pop(request_id, None)

    def note_failed(self, instance: int,
                    request_id: int | None = None) -> None:
        """A request failed terminally after admission (device-call
        failure or NaN/Inf guard)."""
        if 0 <= instance < self.m:
            self.per_instance[instance].failed += 1
        if request_id is not None:
            self._last_token_t.pop(request_id, None)

    def note_shed(self, instance: int) -> None:
        """A queued request was dropped by overload brownout."""
        st = self.per_instance[instance]
        st.shed += 1
        st.queue_depth -= 1

    def note_requeue(self, instance: int) -> None:
        """A recovered request re-entered its queue after a restart."""
        st = self.per_instance[instance]
        st.requeued += 1
        st.queue_depth += 1

    def note_replay(self, instance: int) -> None:
        """One already-delivered token regenerated with emission
        suppressed during recovery replay."""
        self.replayed_tokens += 1

    def reset_queue_depths(self) -> None:
        """Crash recovery: queues were drained wholesale, gauges follow
        (requeues re-increment them)."""
        for st in self.per_instance:
            st.queue_depth = 0

    # -- reporting -----------------------------------------------------------

    def slo_report(self) -> dict:
        """Per-instance SLO evaluation (the ``/v1/slo`` payload and the
        snapshot's ``"slo"`` block).  Lazy by construction: nothing is
        computed until someone asks, so configuring SLOs adds ZERO
        hot-path work — the inputs (histograms, recent windows,
        completion counters) are recorded regardless."""
        if self.slo is None:
            return {"configured": False}
        cfg = self.slo
        instances = []
        for st in self.per_instance:
            with self._lock:
                ttft_hist = st.ttft_hist
                itl_hist = st.itl_hist
                recent_ttft = list(st.ttft_samples)
                recent_itl = list(st.itl_samples)
                objectives = {}
                if cfg.ttft_ms is not None:
                    objectives["ttft"] = evaluate_objective(
                        ttft_hist, recent_ttft, cfg.ttft_ms, cfg.target)
                if cfg.itl_ms is not None:
                    objectives["itl"] = evaluate_objective(
                        itl_hist, recent_itl, cfg.itl_ms, cfg.target)
            objectives["availability"] = evaluate_availability(
                st.completed, st.failed, cfg.availability_target)
            instances.append({
                "objectives": objectives,
                "state": worst_state(o["state"] for o in objectives.values()),
            })
        return {
            "configured": True,
            "config": {"ttft_ms": cfg.ttft_ms, "itl_ms": cfg.itl_ms,
                       "target": cfg.target,
                       "availability_target": cfg.availability_target},
            "instances": instances,
        }

    def slo_states(self) -> list | None:
        """Per-instance worst-objective state, or None when no SLOs are
        configured (the /healthz and /v1/models summary)."""
        if self.slo is None:
            return None
        return [i["state"] for i in self.slo_report()["instances"]]

    def snapshot(self) -> dict:
        dt = max(self.clock() - self.started, 1e-9)
        inst = []
        agg_ttft = LogHistogram()
        agg_itl = LogHistogram()
        for st in self.per_instance:
            with self._lock:
                ttft_samples = list(st.ttft_samples)
                itl_samples = list(st.itl_samples)
                ttft_pct = st.ttft_hist.percentiles()
                itl_pct = st.itl_hist.percentiles()
                ttft_hist = st.ttft_hist.snapshot()
                itl_hist = st.itl_hist.snapshot()
                agg_ttft.merge(st.ttft_hist)
                agg_itl.merge(st.itl_hist)
            inst.append({
                "submitted": st.submitted,
                "admitted": st.admitted,
                "completed": st.completed,
                "cancelled": st.cancelled,
                "rejected": st.rejected,
                "failed": st.failed,
                "shed": st.shed,
                "requeued": st.requeued,
                "queue_depth": st.queue_depth,
                "queue_peak": st.queue_peak,
                "prompt_tokens": st.prompt_tokens,
                "generated_tokens": st.generated_tokens,
                "tok_per_s": st.generated_tokens / dt,
                "mean_ttft_s": st.ttft_sum / st.ttft_n if st.ttft_n else None,
                "mean_latency_s": st.latency_sum / st.latency_n if st.latency_n else None,
                # unbiased whole-run percentiles (log-bucketed histogram)
                "ttft_ms": ttft_pct,
                "itl_ms": itl_pct,
                # Prometheus histogram exposition source
                "ttft_hist": ttft_hist,
                "itl_hist": itl_hist,
                # last-N debug view (the OLD windowed estimator, kept for
                # "what happened just now" — biased on long runs by design)
                "ttft_recent_ms": percentiles(ttft_samples),
                "itl_recent_ms": percentiles(itl_samples),
            })
        gen = sum(s.generated_tokens for s in self.per_instance)
        # split throughput over each phase's own settled device wall:
        # prefill rate over advance()'s wall, decode rate over the decode
        # blocks' dispatch->host wall (engine times every fused call) —
        # scheduler/scatter/host-unroll time belongs to neither phase.
        # Fallback for synthetic windows with no timed calls: the
        # pre-§6.6 wall split (everything-but-prefill)
        decode_wall = (self.decode_wall_s if self.decode_wall_s > 0
                       else max(dt - self.prefill_wall_s, 1e-9))
        out = {
            "wall_s": dt,
            "decode_steps": self.decode_steps,
            # multi-step decode (DESIGN.md §6.6): device calls vs scan
            # steps vs tokens — tokens_per_device_call is the K*occupancy
            # dispatch-amortization figure /metrics exposes
            "decode_device_calls": self.decode_calls,
            "tokens_per_device_call": (
                self.decode_tokens / self.decode_calls
                if self.decode_calls else 0.0
            ),
            "prefill_batches": self.prefill_batches,
            "prefill_requests": self.prefill_requests,
            "prefill_tokens": self.prefill_tokens,
            "prefill_wall_s": self.prefill_wall_s,
            "prefill_tok_per_s": (
                self.prefill_tokens / self.prefill_wall_s
                if self.prefill_wall_s > 0 else 0.0
            ),
            "decode_wall_s": self.decode_wall_s,
            "decode_tok_per_s": (self.decode_tokens if self.decode_wall_s > 0
                                 else gen) / decode_wall,
            # host-dispatch cost per emitted token — the figure multi-step
            # blocks shrink ~K-fold (DESIGN.md §6.6)
            "decode_dispatch_ms_per_token": (
                1e3 * self.decode_dispatch_s / self.decode_tokens
                if self.decode_tokens else 0.0
            ),
            "device_calls_per_admission": (
                self.prefill_batches / self.admitted if self.admitted else 0.0
            ),
            # cumulative device-call + compiled-shape counters: /metrics
            # alone is enough to spot a recompile or dispatch regression
            "scatter_calls": self.scatter_calls,
            "device_calls": (self.decode_calls + self.prefill_batches
                             + self.scatter_calls),
            "prefill_compiled_shapes": (
                self.compiled_shapes_fn() if self.compiled_shapes_fn
                is not None else None
            ),
            "admission_stall_ms": 1e3 * self.admission_stall_s,
            "generated_tokens": gen,
            "tok_per_s": gen / dt,
            "cancelled": sum(s.cancelled for s in self.per_instance),
            "rejected": sum(s.rejected for s in self.per_instance),
            "failed": sum(s.failed for s in self.per_instance),
            "shed": sum(s.shed for s in self.per_instance),
            "requeued": sum(s.requeued for s in self.per_instance),
            "replayed_tokens": self.replayed_tokens,
            "replay_mismatches": self.replay_mismatches,
            # supervision counters: zeros when no Supervisor is wired, so
            # the Prometheus exposition always carries the rows
            "resilience": (
                self.resilience_fn() if self.resilience_fn is not None
                else {"driver_restarts": 0, "request_retries": 0,
                      "watchdog_timeouts": 0, "tokens_replayed": 0,
                      "retry_budget_exhausted": 0,
                      "last_recovery_s": None, "recoveries": []}
            ),
            "health": (
                self.health_fn() if self.health_fn is not None else None
            ),
            "ttft_ms": agg_ttft.percentiles(),
            "itl_ms": agg_itl.percentiles(),
            "instances": inst,
        }
        if self.slo is not None:
            out["slo"] = self.slo_report()
        if self.accounting_fn is not None:
            acct = self.accounting_fn()
            # carried once there is (or was) a capture window — an
            # engine whose accounting never started adds no block
            if acct.get("enabled") or acct.get("settled_s", 0.0) > 0:
                out["accounting"] = acct
        if self.mesh_shape is not None:
            out["mesh"] = {
                "shape": self.mesh_shape, "devices": self.num_devices,
            }
            out["tok_per_s_per_device"] = gen / dt / self.num_devices
        return out

    def format_table(self) -> str:
        snap = self.snapshot()
        hdr = (
            f"{'inst':>4} {'done':>5} {'can':>4} {'queue':>5} {'peak':>5} "
            f"{'prompt':>7} {'gen':>7} {'tok/s':>8} "
            f"{'ttft50':>7} {'ttft95':>7} {'itl50':>7} {'itl95':>7} {'lat_ms':>8}"
        )
        rows = [hdr, "-" * len(hdr)]

        def pct(d, key):
            return f"{d[key]:.1f}" if d is not None else "-"

        for i, st in enumerate(snap["instances"]):
            lat = f"{1e3 * st['mean_latency_s']:.1f}" if st["mean_latency_s"] is not None else "-"
            rows.append(
                f"{i:>4} {st['completed']:>5} {st['cancelled']:>4} "
                f"{st['queue_depth']:>5} {st['queue_peak']:>5} "
                f"{st['prompt_tokens']:>7} {st['generated_tokens']:>7} "
                f"{st['tok_per_s']:>8.1f} "
                f"{pct(st['ttft_ms'], 'p50'):>7} {pct(st['ttft_ms'], 'p95'):>7} "
                f"{pct(st['itl_ms'], 'p50'):>7} {pct(st['itl_ms'], 'p95'):>7} "
                f"{lat:>8}"
            )
        rows.append(
            f"total: {snap['generated_tokens']} tokens in {snap['wall_s']:.2f}s "
            f"({snap['tok_per_s']:.1f} tok/s) — {snap['decode_steps']} fused decode "
            f"steps in {snap['decode_device_calls']} device calls "
            f"({snap['tokens_per_device_call']:.1f} tok/call), "
            f"{snap['prefill_batches']} prefill chunk calls "
            f"({snap['prefill_requests']} lane-steps, "
            f"{snap['device_calls_per_admission']:.2f} calls/admission), "
            f"prefill {snap['prefill_tok_per_s']:.1f} tok/s / "
            f"decode {snap['decode_tok_per_s']:.1f} tok/s, "
            f"{snap['admission_stall_ms']:.1f} ms admission stall"
        )
        if snap["ttft_ms"] is not None:
            t, it = snap["ttft_ms"], snap["itl_ms"]
            itl = (
                f"itl p50/p95/p99 {it['p50']:.1f}/{it['p95']:.1f}/{it['p99']:.1f} ms"
                if it is not None else "itl -"
            )
            rows.append(
                f"tails: ttft p50/p95/p99 "
                f"{t['p50']:.1f}/{t['p95']:.1f}/{t['p99']:.1f} ms, {itl}"
            )
        return "\n".join(rows)
