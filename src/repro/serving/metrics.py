"""Per-instance serving metrics.

The paper's deployment scenario is M task streams through one fused
program; operators need to see each task's share.  ``ServerMetrics``
keeps cheap host-side counters per instance — throughput, latency,
time-to-first-token, queue depth — plus engine-wide counters (fused
decode steps, prefill batches/compiles).  ``snapshot()`` returns plain
dicts (JSON-able, used by benchmarks/serve_bench.py); ``format_table()``
renders the per-instance report printed by ``repro.launch.serve``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class InstanceStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    queue_depth: int = 0           # current, updated on submit/admit
    queue_peak: int = 0
    ttft_sum: float = 0.0          # submit -> first generated token
    ttft_n: int = 0
    latency_sum: float = 0.0       # submit -> completion
    latency_n: int = 0


class ServerMetrics:
    def __init__(self, num_instances: int,
                 clock: Callable[[], float] = time.perf_counter, mesh=None):
        self.m = num_instances
        self.clock = clock
        self.per_instance = [InstanceStats() for _ in range(num_instances)]
        self.decode_steps = 0        # fused (M, B)-grid decode+sample calls
        self.prefill_batches = 0     # chunk/tail prefill device calls
        self.prefill_requests = 0    # lane-steps served by them
        self.prefill_tokens = 0      # real (non-padded) positions prefilled
        self.prefill_wall_s = 0.0    # settled wall time inside advance()
        self.admitted = 0            # requests bound to a prefill lane
        # wall time decode-ready slots sat idle while admission chunks
        # ran — what the engine's chunk_budget bounds per step
        self.admission_stall_s = 0.0
        self.started = clock()
        # mesh-parametric serving: record the grid's mesh geometry so
        # snapshots carry per-device throughput (serve_bench JSON)
        self.mesh_shape = dict(mesh.shape) if mesh is not None else None
        self.num_devices = mesh.size if mesh is not None else 1

    # -- engine hooks --------------------------------------------------------

    def note_submit(self, instance: int) -> None:
        st = self.per_instance[instance]
        st.submitted += 1
        st.queue_depth += 1
        st.queue_peak = max(st.queue_peak, st.queue_depth)

    def note_admit(self, instance: int, prompt_len: int) -> None:
        st = self.per_instance[instance]
        st.admitted += 1
        st.queue_depth -= 1
        st.prompt_tokens += prompt_len
        self.admitted += 1

    def note_prefill_batch(self, num_requests: int, num_tokens: int = 0) -> None:
        self.prefill_batches += 1
        self.prefill_requests += num_requests
        self.prefill_tokens += num_tokens

    def note_prefill_wall(self, seconds: float) -> None:
        self.prefill_wall_s += seconds

    def note_decode_step(self) -> None:
        self.decode_steps += 1

    def note_admission_stall(self, seconds: float) -> None:
        self.admission_stall_s += seconds

    def note_token(self, instance: int, *, first: bool, submit_time: float) -> None:
        st = self.per_instance[instance]
        st.generated_tokens += 1
        if first:
            st.ttft_sum += self.clock() - submit_time
            st.ttft_n += 1

    def note_complete(self, instance: int, submit_time: float) -> None:
        st = self.per_instance[instance]
        st.completed += 1
        st.latency_sum += self.clock() - submit_time
        st.latency_n += 1

    # -- reporting -----------------------------------------------------------

    def snapshot(self) -> dict:
        dt = max(self.clock() - self.started, 1e-9)
        inst = []
        for st in self.per_instance:
            inst.append({
                "submitted": st.submitted,
                "admitted": st.admitted,
                "completed": st.completed,
                "queue_depth": st.queue_depth,
                "queue_peak": st.queue_peak,
                "prompt_tokens": st.prompt_tokens,
                "generated_tokens": st.generated_tokens,
                "tok_per_s": st.generated_tokens / dt,
                "mean_ttft_s": st.ttft_sum / st.ttft_n if st.ttft_n else None,
                "mean_latency_s": st.latency_sum / st.latency_n if st.latency_n else None,
            })
        gen = sum(s.generated_tokens for s in self.per_instance)
        # split throughput: prefill rate over the settled admission wall
        # time, decode rate over the remainder — the two phases interleave
        # inside one step loop, so the denominators partition wall_s
        decode_wall = max(dt - self.prefill_wall_s, 1e-9)
        out = {
            "wall_s": dt,
            "decode_steps": self.decode_steps,
            "prefill_batches": self.prefill_batches,
            "prefill_requests": self.prefill_requests,
            "prefill_tokens": self.prefill_tokens,
            "prefill_wall_s": self.prefill_wall_s,
            "prefill_tok_per_s": (
                self.prefill_tokens / self.prefill_wall_s
                if self.prefill_wall_s > 0 else 0.0
            ),
            "decode_tok_per_s": gen / decode_wall,
            "device_calls_per_admission": (
                self.prefill_batches / self.admitted if self.admitted else 0.0
            ),
            "admission_stall_ms": 1e3 * self.admission_stall_s,
            "generated_tokens": gen,
            "tok_per_s": gen / dt,
            "instances": inst,
        }
        if self.mesh_shape is not None:
            out["mesh"] = {
                "shape": self.mesh_shape, "devices": self.num_devices,
            }
            out["tok_per_s_per_device"] = gen / dt / self.num_devices
        return out

    def format_table(self) -> str:
        snap = self.snapshot()
        hdr = (
            f"{'inst':>4} {'done':>5} {'queue':>5} {'peak':>5} "
            f"{'prompt':>7} {'gen':>7} {'tok/s':>8} {'ttft_ms':>8} {'lat_ms':>8}"
        )
        rows = [hdr, "-" * len(hdr)]
        for i, st in enumerate(snap["instances"]):
            ttft = f"{1e3 * st['mean_ttft_s']:.1f}" if st["mean_ttft_s"] is not None else "-"
            lat = f"{1e3 * st['mean_latency_s']:.1f}" if st["mean_latency_s"] is not None else "-"
            rows.append(
                f"{i:>4} {st['completed']:>5} {st['queue_depth']:>5} "
                f"{st['queue_peak']:>5} {st['prompt_tokens']:>7} "
                f"{st['generated_tokens']:>7} {st['tok_per_s']:>8.1f} "
                f"{ttft:>8} {lat:>8}"
            )
        rows.append(
            f"total: {snap['generated_tokens']} tokens in {snap['wall_s']:.2f}s "
            f"({snap['tok_per_s']:.1f} tok/s) — {snap['decode_steps']} fused decode "
            f"steps, {snap['prefill_batches']} prefill chunk calls "
            f"({snap['prefill_requests']} lane-steps, "
            f"{snap['device_calls_per_admission']:.2f} calls/admission), "
            f"prefill {snap['prefill_tok_per_s']:.1f} tok/s / "
            f"decode {snap['decode_tok_per_s']:.1f} tok/s, "
            f"{snap['admission_stall_ms']:.1f} ms admission stall"
        )
        return "\n".join(rows)
