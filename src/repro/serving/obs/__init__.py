"""Observability for the fused serving engine (DESIGN.md §6.5).

``trace``          — ring-buffered step tracer: per-device-call events
                     (wall + settled time, dispatch gap, grid occupancy,
                     chunk validity) and request-lifecycle spans;
                     Chrome-trace/Perfetto export + aggregate summaries.
``prometheus``     — Prometheus text exposition of
                     ``ServerMetrics.snapshot()`` (Accept-negotiated on
                     ``GET /metrics``).
``kernel_profile`` — achieved-vs-roofline timing of the serving Pallas
                     kernels at serving shapes.
"""
from repro.serving.obs.kernel_profile import (
    KERNELS,
    format_table,
    profile_kernel,
    profile_serving_kernels,
    serving_shapes,
    validate_profile,
)
from repro.serving.obs.prometheus import render as render_prometheus
from repro.serving.obs.trace import DeviceCallEvent, RequestEvent, Tracer

__all__ = [
    "DeviceCallEvent",
    "KERNELS",
    "RequestEvent",
    "Tracer",
    "format_table",
    "profile_kernel",
    "profile_serving_kernels",
    "render_prometheus",
    "serving_shapes",
    "validate_profile",
]
