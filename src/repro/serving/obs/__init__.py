"""Observability for the fused serving engine (DESIGN.md §6.5).

``trace``          — ring-buffered step tracer: per-device-call events
                     (wall + settled time, dispatch gap, grid occupancy,
                     chunk validity) and request-lifecycle spans;
                     Chrome-trace/Perfetto export + aggregate summaries.
``prometheus``     — Prometheus text exposition of
                     ``ServerMetrics.snapshot()`` (Accept-negotiated on
                     ``GET /metrics``).
``kernel_profile`` — achieved-vs-roofline timing of the serving Pallas
                     kernels at serving shapes.
``slo``            — log-bucketed latency histograms (unbiased tail
                     percentiles) + per-instance TTFT/ITL/availability
                     objectives with error-budget burn rate (§6.9).
``accounting``     — per-tenant device-time attribution with a
                     conservation invariant, plus head-of-line
                     interference reporting (§6.9).
``flight``         — flight recorder: crash/watchdog/quarantine dumps
                     of the last-N trace events + metrics + queue
                     depths + SLO state to JSON artifacts (§6.9).
"""
from repro.serving.obs.accounting import TenantAccounting
from repro.serving.obs.flight import FlightRecorder
from repro.serving.obs.kernel_profile import (
    KERNELS,
    format_table,
    profile_kernel,
    profile_serving_kernels,
    serving_shapes,
    validate_profile,
)
from repro.serving.obs.prometheus import render as render_prometheus
from repro.serving.obs.slo import (
    LogHistogram,
    SLOConfig,
    evaluate_availability,
    evaluate_objective,
    worst_state,
)
from repro.serving.obs.trace import DeviceCallEvent, RequestEvent, Tracer

__all__ = [
    "DeviceCallEvent",
    "FlightRecorder",
    "KERNELS",
    "LogHistogram",
    "RequestEvent",
    "SLOConfig",
    "TenantAccounting",
    "Tracer",
    "evaluate_availability",
    "evaluate_objective",
    "format_table",
    "profile_kernel",
    "profile_serving_kernels",
    "render_prometheus",
    "serving_shapes",
    "validate_profile",
    "worst_state",
]
