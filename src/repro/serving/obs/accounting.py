"""Per-tenant wall-time attribution for the fused (M, B) grid (§6.9).

The engine's whole design concentrates M tenants' work into ONE device
call per step — which is exactly why per-call telemetry (§6.5) cannot
answer the question a multi-tenant operator actually asks: *how much of
the device did tenant i consume, and who made tenant j wait?*
:class:`TenantAccounting` splits every settled device call's wall time
across the instances occupying that call:

* **decode** — a fused (M, B) decode block costs ``wall`` regardless of
  occupancy, so each of the ``capacity = M*B`` slot-shares costs
  ``wall / capacity``: instance i is charged ``active_i`` shares into
  its ``decode_s`` account and its ``B - active_i`` empty slots into
  ``idle_s`` (the waste an idle lane still rides — the paper's
  utilization argument, priced per tenant);
* **prefill chunk** — lane-weighted the same way (``wall / lanes`` per
  lane); lanes nobody occupied are shared idle, split evenly across
  the M tenants (unused shared capacity is a cost of the fused design,
  not of any one tenant);
* **scatter** — a slot-admission call serves exactly one request:
  whole wall to its instance;
* **queue wait / replay** — host-side accounts: time a request sat
  queued before admission, and the token-weighted share of decode wall
  spent regenerating already-delivered tokens after a crash (§6.8
  replay).  Replay is a *view* over decode time (those calls are also
  attributed normally), so it is excluded from conservation;
* **interference** — while tenant w had requests queued, every settled
  call's wall is attributed to the tenants occupying the grid at that
  moment, occupancy-weighted: "w waited 3.1 s; 2.9 s of that the grid
  was running tenant 0" — the head-of-line report.

**Conservation invariant** (the correctness handle, asserted in tests
and bench-smoke): ``sum_i(decode_s + prefill_s + scatter_s + idle_s)
== settled_s`` — every attributed call's wall re-sums exactly, so a
wrong weighting scheme cannot hide.

Same zero-cost-when-off discipline as the tracer: every engine call
site guards on ``accounting.enabled`` (one attribute read), so the
disabled path builds no lists, takes no locks, reads no clocks —
proven by a bombed-methods test."""
from __future__ import annotations

import threading


class TenantAccounting:
    """Per-instance device-time ledger; disabled until :meth:`start`.

    Methods assume capture is on (call sites guard on ``enabled``).
    ``queued_fn`` — set by the engine to ``scheduler.queued_instances``
    — supplies the waiters for interference attribution; attribution
    itself is mutation-free with respect to the engine."""

    def __init__(self, num_instances: int = 0):
        self.enabled = False
        self.m = num_instances
        self.queued_fn = None        # () -> list of instances with queued work
        self._lock = threading.Lock()
        self._reset()

    def _reset(self) -> None:
        m = self.m
        self.decode_s = [0.0] * m
        self.prefill_s = [0.0] * m
        self.scatter_s = [0.0] * m
        self.idle_s = [0.0] * m
        self.queue_wait_s = [0.0] * m
        self.replay_s = [0.0] * m
        self.replay_tokens = [0] * m
        self.settled_s = 0.0
        self.device_calls = 0
        # interference[w][o] = seconds the grid ran tenant o's work
        # while tenant w had requests queued
        self.interference: list[dict] = [dict() for _ in range(m)]

    # -- lifecycle -----------------------------------------------------------

    def start(self, num_instances: int | None = None) -> None:
        """Begin (or restart) accounting; the ledger resets so a fresh
        window never mixes with a previous one."""
        with self._lock:
            if num_instances is not None:
                self.m = num_instances
            self._reset()
            self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    # -- attribution (call only when ``enabled``) ----------------------------

    def _interfere(self, wall_s: float, shares, total: float) -> None:
        # shares: per-instance occupancy weights for this call
        fn = self.queued_fn
        if fn is None or total <= 0:
            return
        for w in fn():
            acc = self.interference[w]
            for i, s in enumerate(shares):
                if s:
                    acc[i] = acc.get(i, 0.0) + wall_s * s / total

    def note_decode(self, wall_s: float, active_counts, capacity: int) -> None:
        """One settled fused decode call: ``active_counts[i]`` decoding
        slots for instance i, out of ``capacity = M*B`` total."""
        with self._lock:
            self.settled_s += wall_s
            self.device_calls += 1
            per = wall_s / capacity if capacity else 0.0
            b = capacity // self.m if self.m else 0
            for i, a in enumerate(active_counts):
                self.decode_s[i] += per * a
                self.idle_s[i] += per * (b - a)
            self._interfere(wall_s, active_counts, sum(active_counts))

    def note_prefill(self, wall_s: float, lane_instances, lanes: int) -> None:
        """One settled prefill chunk call: ``lane_instances`` lists the
        owning instance of each busy lane (repeats allowed)."""
        with self._lock:
            self.settled_s += wall_s
            self.device_calls += 1
            per = wall_s / lanes if lanes else 0.0
            shares = [0] * self.m
            for inst in lane_instances:
                self.prefill_s[inst] += per
                shares[inst] += 1
            idle = wall_s - per * len(lane_instances)
            if self.m and idle > 0:
                for i in range(self.m):
                    self.idle_s[i] += idle / self.m
            self._interfere(wall_s, shares, len(lane_instances))

    def note_scatter(self, wall_s: float, instance: int) -> None:
        """One prefill→grid slot scatter: serves exactly one request."""
        with self._lock:
            self.settled_s += wall_s
            self.device_calls += 1
            self.scatter_s[instance] += wall_s
            shares = [0] * self.m
            shares[instance] = 1
            self._interfere(wall_s, shares, 1)

    def note_queue_wait(self, instance: int, wait_s: float) -> None:
        with self._lock:
            self.queue_wait_s[instance] += wait_s

    def note_replay(self, counts: dict, wall_s: float, tokens: int) -> None:
        """Replayed (suppressed re-emission, §6.8) tokens this decode
        call, per instance; charged a token-weighted share of the
        call's wall.  A view over decode time — NOT part of
        conservation."""
        with self._lock:
            for i, n in counts.items():
                self.replay_tokens[i] += n
                if tokens:
                    self.replay_s[i] += wall_s * n / tokens

    # -- report --------------------------------------------------------------

    def attributed_s(self) -> float:
        return (sum(self.decode_s) + sum(self.prefill_s)
                + sum(self.scatter_s) + sum(self.idle_s))

    def conservation(self) -> dict:
        """The invariant: attributed time re-sums to settled time."""
        with self._lock:
            attributed = self.attributed_s()
            settled = self.settled_s
        denom = max(settled, 1e-12)
        return {"attributed_s": attributed, "settled_s": settled,
                "rel_err": abs(attributed - settled) / denom}

    def snapshot(self) -> dict:
        with self._lock:
            per_tenant = {
                str(i): {
                    "decode_s": self.decode_s[i],
                    "prefill_s": self.prefill_s[i],
                    "scatter_s": self.scatter_s[i],
                    "idle_s": self.idle_s[i],
                    "device_s": (self.decode_s[i] + self.prefill_s[i]
                                 + self.scatter_s[i]),
                    "queue_wait_s": self.queue_wait_s[i],
                    "replay_s": self.replay_s[i],
                    "replay_tokens": self.replay_tokens[i],
                }
                for i in range(self.m)
            }
            attributed = self.attributed_s()
            settled = self.settled_s
            interference = {
                str(w): {str(o): s for o, s in acc.items()}
                for w, acc in enumerate(self.interference) if acc
            }
        return {
            "enabled": self.enabled,
            "device_calls": self.device_calls,
            "settled_s": settled,
            "attributed_s": attributed,
            "idle_total_s": sum(v["idle_s"] for v in per_tenant.values()),
            "conservation_rel_err": (abs(attributed - settled)
                                     / max(settled, 1e-12)),
            "per_tenant": per_tenant,
            "interference": interference,
        }

    def format_table(self) -> str:
        """Human-readable end-of-run attribution report (serve.py)."""
        snap = self.snapshot()
        lines = ["per-tenant device-time attribution",
                 f"  settled {snap['settled_s']:.3f} s over "
                 f"{snap['device_calls']} device calls, conservation "
                 f"rel err {snap['conservation_rel_err']:.2e}",
                 "  inst   decode_s  prefill_s  scatter_s    idle_s  "
                 "queue_wait_s  replay_s"]
        for i, t in sorted(snap["per_tenant"].items(), key=lambda kv: int(kv[0])):
            lines.append(
                f"  {i:>4}  {t['decode_s']:9.3f}  {t['prefill_s']:9.3f}  "
                f"{t['scatter_s']:9.3f}  {t['idle_s']:8.3f}  "
                f"{t['queue_wait_s']:12.3f}  {t['replay_s']:8.3f}")
        if snap["interference"]:
            lines.append("  head-of-line interference (waiter <- occupant):")
            for w, acc in sorted(snap["interference"].items()):
                causes = ", ".join(f"inst {o}: {s:.3f} s"
                                   for o, s in sorted(acc.items()))
                lines.append(f"    inst {w} waited under  {causes}")
        return "\n".join(lines)
