"""Crash flight recorder (§6.9): the black box for the fused engine.

When the driver crashes, the watchdog fires, or an instance is
quarantined (§6.8), the post-mortem questions are always the same —
what was in flight, what did the last N device calls look like, how
deep were the queues, which tenant was burning its budget — and by the
time anyone asks, the recovering engine has already moved on.
:class:`FlightRecorder` freezes that state AT the event: one JSON
artifact per incident (``flight-0001.json``, ...) containing the
tracer's last-N events, the full metrics snapshot (which embeds SLO
state and tenant attribution when configured), and the scheduler
depths, plus a bounded in-memory ring served by ``GET /debug/flight``.

Discipline matches the tracer: disabled (no ``--flight-dir``) means the
hook sites read ONE attribute and skip; ``dump`` itself is best-effort
per component (a recorder must never turn an incident into a second
incident), tagging any component that failed to serialize instead of
raising."""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque

SCHEMA = "flight/v1"
DEFAULT_LAST_N = 512


class FlightRecorder:
    """Dump-on-incident recorder; enabled iff a directory is set."""

    def __init__(self, directory: str | None = None, *,
                 last_n: int = DEFAULT_LAST_N, keep: int = 4):
        self.directory = directory
        self.enabled = directory is not None
        self.last_n = last_n
        self._seq = 0
        self._lock = threading.Lock()
        # most recent dumps, newest last — the /debug/flight payload
        self.dumps: deque = deque(maxlen=keep)

    def __len__(self) -> int:
        return self._seq

    def _component(self, record: dict, key: str, fn) -> None:
        # best-effort: a failed component becomes {"error": ...}, the
        # rest of the record still lands on disk
        try:
            record[key] = fn()
        except BaseException as e:
            record[key] = {"error": repr(e)}

    def dump(self, reason: str, *, server=None, extra: dict | None = None) -> str | None:
        """Freeze the server's observable state into one artifact.

        Callable from any thread (supervisor loop, engine executor
        thread via the quarantine hook); returns the artifact path, or
        None if the write itself failed."""
        with self._lock:
            self._seq += 1
            seq = self._seq
        record = {"schema": SCHEMA, "seq": seq, "reason": reason,
                  "unix_time": time.time()}
        if extra:
            record["extra"] = dict(extra)
        if server is not None:
            tracer = getattr(server, "tracer", None)
            if tracer is not None:
                self._component(record, "trace_events", lambda: [
                    dict(dataclasses.asdict(ev), event=type(ev).__name__)
                    for ev in tracer._snapshot()[-self.last_n:]])
            metrics = getattr(server, "metrics", None)
            if metrics is not None:
                # embeds "slo" and "accounting" blocks when configured
                self._component(record, "metrics", metrics.snapshot)
            sched = getattr(server, "scheduler", None)
            if sched is not None:
                self._component(record, "queue_depths", sched.depths)
        path = None
        try:
            os.makedirs(self.directory, exist_ok=True)
            path = os.path.join(self.directory, f"flight-{seq:04d}.json")
            with open(path, "w") as f:
                json.dump(record, f, indent=1, default=repr)
        except OSError:
            path = None
        record["path"] = path
        with self._lock:
            self.dumps.append(record)
        return path

    def latest(self) -> list:
        """The in-memory ring, oldest first (``GET /debug/flight``)."""
        with self._lock:
            return list(self.dumps)
