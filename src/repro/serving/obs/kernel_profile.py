"""Achieved-vs-roofline profiling of the serving Pallas kernels.

The dry-run roofline (``launch/hlo_analysis.py``) predicts what each
compiled program *should* cost from first-order FLOP/byte counts; this
module closes the loop by **timing the actual kernels** at serving
shapes and reporting achieved FLOP/s and bytes/s against the same
roofline envelope (``launch/mesh.py`` peaks), so a block-shape tune or
a kernel rewrite is a measured win, not a vibe.

Seven kernels — the fused serving hot spots:

* ``fused_matmul``       — the merged (M, T, D) @ (M, D, F) projection,
* ``decode_attn``        — one fused grid decode step's attention,
* ``chunk_prefill_attn`` — flash attention over [cache, chunk],
* ``mlstm_chunk``        — chunkwise mLSTM admission scan,
* ``slstm_cell``         — the sLSTM recurrent cell scan,
* ``decode_layer``       — the whole-dense-decode-layer megakernel
  (QKV+RoPE, cache append, flash decode, out-proj, both norms, SwiGLU),
* ``logits_sample``      — fused final-norm + unembed + greedy argmax.

Shapes derive from a ``ModelConfig`` + serving geometry
(:func:`serving_shapes`), so the profile measures what the engine
actually launches.  On non-TPU backends the kernels execute in the
Pallas **interpreter** — the achieved numbers then characterize the
interpreter, not silicon; every record carries ``backend``/``interpret``
flags so a table can never pass off CPU figures as TPU ones.

FLOP/byte models are first-order and dense-equivalent (masked attention
positions count; see each ``_model_*``), matching the philosophy of the
HLO cost model: a roofline tool, not a cycle simulator.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

KERNELS = ("fused_matmul", "decode_attn", "chunk_prefill_attn",
           "mlstm_chunk", "slstm_cell", "decode_layer", "logits_sample")


def _nbytes(*arrays) -> int:
    return int(sum(a.size * a.dtype.itemsize for a in arrays))


def serving_shapes(cfg, *, slots: int = 4, max_context: int = 128,
                   chunk: int = 32, prefill_lanes: int = 4) -> dict:
    """Kernel input geometry at this config's serving shapes: M from the
    merged instance count, B from the grid slots, S from the serving
    context, C from the prefill chunk."""
    m = max(cfg.num_instances, 1)
    hd = cfg.head_dim
    # recurrent families project to an inner width (ssm.d_inner); attn
    # families have no mlstm/slstm path but still get well-formed shapes
    di = int((cfg.mlstm_proj_factor or 2.0) * cfg.d_model)
    return {
        "fused_matmul": dict(m=m, t=slots, d=cfg.d_model,
                             f=cfg.d_ff or 4 * cfg.d_model),
        "decode_attn": dict(m=m, b=slots, h=cfg.num_heads,
                            kvh=cfg.num_kv_heads, hd=hd, s=max_context),
        "chunk_prefill_attn": dict(m=m, b=prefill_lanes, c=chunk,
                                   h=cfg.num_heads, kvh=cfg.num_kv_heads,
                                   hd=hd, s_cache=max_context - chunk),
        "mlstm_chunk": dict(m=m, b=prefill_lanes, h=cfg.num_heads,
                            s=chunk, hd=di // cfg.num_heads,
                            chunk=min(cfg.mlstm_chunk or 64, chunk)),
        "slstm_cell": dict(m=m, b=prefill_lanes, s=chunk,
                           d=di, h=cfg.num_heads),
        "decode_layer": dict(m=m, b=slots, d=cfg.d_model, h=cfg.num_heads,
                             kvh=cfg.num_kv_heads, hd=hd, s=max_context,
                             ff=cfg.d_ff or 4 * cfg.d_model,
                             window=cfg.sliding_window or 0),
        "logits_sample": dict(m=m, b=slots, d=cfg.d_model,
                              v=cfg.vocab_size),
    }


# -- per-kernel builders: (callable, flops, bytes, shape string) -------------


def _mk_fused_matmul(m, t, d, f, dtype):
    from repro.kernels.fused_matmul import fused_matmul
    x = jnp.ones((m, t, d), dtype)
    w = jnp.ones((m, d, f), dtype)
    interpret = jax.default_backend() != "tpu"
    return (lambda: fused_matmul(x, w, interpret=interpret),
            2.0 * m * t * d * f,
            _nbytes(x, w) + m * t * f * x.dtype.itemsize,
            f"({m},{t},{d})@({m},{d},{f})", interpret)


def _mk_decode_attn(m, b, h, kvh, hd, s, dtype):
    from repro.kernels.decode_attn import decode_attention
    q = jnp.ones((m, b, h, hd), dtype)
    k = jnp.ones((m, b, s, kvh, hd), dtype)
    v = jnp.ones((m, b, s, kvh, hd), dtype)
    kv_len = jnp.full((m, b), s, jnp.int32)
    interpret = jax.default_backend() != "tpu"
    return (lambda: decode_attention(q, k, v, kv_len, interpret=interpret),
            4.0 * m * b * h * s * hd,
            _nbytes(q, k, v) + q.size * q.dtype.itemsize,
            f"q({m},{b},{h},{hd}) kv S={s}", interpret)


def _mk_chunk_prefill_attn(m, b, c, h, kvh, hd, s_cache, dtype):
    from repro.kernels.chunk_prefill_attn import chunk_prefill_attention
    t = s_cache + c
    q = jnp.ones((m, b, c, h, hd), dtype)
    k = jnp.ones((m, b, t, kvh, hd), dtype)
    v = jnp.ones((m, b, t, kvh, hd), dtype)
    offset = jnp.full((m, b), s_cache, jnp.int32)
    interpret = jax.default_backend() != "tpu"
    return (lambda: chunk_prefill_attention(
                q, k, v, offset, s_cache=s_cache, interpret=interpret),
            4.0 * m * b * c * h * t * hd,       # dense-equivalent
            _nbytes(q, k, v) + q.size * q.dtype.itemsize,
            f"q({m},{b},{c},{h},{hd}) cache S={s_cache}", interpret)


def _mk_mlstm_chunk(m, b, h, s, hd, chunk, dtype):
    from repro.kernels.mlstm_chunk import mlstm_chunkwise
    q = jnp.ones((m, b, h, s, hd), dtype)
    k = jnp.ones((m, b, h, s, hd), dtype)
    v = jnp.ones((m, b, h, s, hd), dtype)
    lf = jnp.zeros((m, b, h, s), jnp.float32)
    li = jnp.zeros((m, b, h, s), jnp.float32)
    interpret = jax.default_backend() != "tpu"
    # per chunk cs: intra-chunk qk^T + a.v (4 cs^2 hd) and inter-chunk
    # q@C + k^T v state update (4 cs hd^2) -> S * 4 hd (cs + hd)
    cs = min(chunk, s)
    return (lambda: mlstm_chunkwise(q, k, v, lf, li, chunk=cs,
                                    interpret=interpret),
            m * b * h * s * 4.0 * hd * (cs + hd),
            _nbytes(q, k, v, lf, li) + q.size * q.dtype.itemsize
            + m * b * h * (hd * hd + hd + 1) * 4,
            f"qkv({m},{b},{h},{s},{hd}) chunk={cs}", interpret)


def _mk_slstm_cell(m, b, s, d, h, dtype):
    from repro.kernels.slstm_cell import slstm_cell
    hd = d // h
    pre = jnp.ones((m, b, s, 4, d), dtype)
    r = jnp.ones((m, 4, h, hd, hd), dtype)
    state = (jnp.zeros((m, b, d), jnp.float32),
             jnp.zeros((m, b, d), jnp.float32),
             jnp.zeros((m, b, d), dtype),
             jnp.zeros((m, b, d), jnp.float32))
    interpret = jax.default_backend() != "tpu"
    # per step: 4 recurrent head matmuls (8 H hd^2) + ~16 D elementwise
    return (lambda: slstm_cell(pre, r, state, num_heads=h,
                               interpret=interpret),
            m * b * s * (8.0 * h * hd * hd + 16.0 * d),
            _nbytes(pre, r) + m * b * s * d * pre.dtype.itemsize,
            f"pre({m},{b},{s},4,{d}) H={h}", interpret)


def _mk_decode_layer(m, b, d, h, kvh, hd, s, ff, window, dtype):
    from repro.kernels.decode_layer import decode_layer
    lp = {
        "attn_norm": jnp.ones((m, d), dtype),
        "wq": jnp.ones((m, d, h * hd), dtype),
        "wk": jnp.ones((m, d, kvh * hd), dtype),
        "wv": jnp.ones((m, d, kvh * hd), dtype),
        "wo": jnp.ones((m, h * hd, d), dtype),
        "mlp_norm": jnp.ones((m, d), dtype),
        "w_gate": jnp.ones((m, d, ff), dtype),
        "w_up": jnp.ones((m, d, ff), dtype),
        "w_down": jnp.ones((m, ff, d), dtype),
    }
    x = jnp.ones((m, b, d), dtype)
    ck = jnp.zeros((m, b, s, kvh, hd), dtype)
    cv = jnp.zeros((m, b, s, kvh, hd), dtype)
    pos = jnp.full((m, b), s - 1, jnp.int32)
    interpret = jax.default_backend() != "tpu"
    # per lane: qkv proj + attention over the full ring + out proj + swiglu
    flops = m * b * (2.0 * d * (h + 2 * kvh) * hd + 4.0 * h * hd * s
                     + 2.0 * h * hd * d + 6.0 * d * ff)
    return (lambda: decode_layer(lp, x, ck, cv, pos, num_heads=h,
                                 head_dim=hd, rope_theta=10000.0,
                                 window=window, interpret=interpret),
            flops,
            _nbytes(x, ck, cv, pos, *lp.values())
            + _nbytes(x, ck, cv),                  # x/cache written back
            f"x({m},{b},{d}) H={h}/{kvh} S={s} ff={ff}", interpret)


def _mk_logits_sample(m, b, d, v, dtype):
    from repro.kernels.decode_layer import logits_sample
    x = jnp.ones((m, b, d), dtype)
    scale = jnp.ones((m, d), dtype)
    head = jnp.ones((m, d, v), dtype)
    interpret = jax.default_backend() != "tpu"
    return (lambda: logits_sample(x, scale, head, interpret=interpret),
            2.0 * m * b * d * v,
            _nbytes(x, scale, head) + m * b * 4,
            f"x({m},{b},{d}) V={v}", interpret)


_BUILDERS = {
    "fused_matmul": _mk_fused_matmul,
    "decode_attn": _mk_decode_attn,
    "chunk_prefill_attn": _mk_chunk_prefill_attn,
    "mlstm_chunk": _mk_mlstm_chunk,
    "slstm_cell": _mk_slstm_cell,
    "decode_layer": _mk_decode_layer,
    "logits_sample": _mk_logits_sample,
}


def profile_kernel(name: str, *, dtype: str = "bfloat16", repeats: int = 3,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW, **shape) -> dict:
    """Time one kernel at the given shape; returns achieved FLOP/s and
    bytes/s against the roofline envelope.  The first (compile/trace)
    call is excluded; ``wall_s`` is the min of ``repeats`` settled
    calls (min, not mean: dispatch noise only ever adds time)."""
    fn, flops, nbytes, shape_str, interpret = _BUILDERS[name](
        **shape, dtype=jnp.dtype(dtype))
    jax.block_until_ready(fn())              # compile + warmup
    wall = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        wall = min(wall, time.perf_counter() - t0)
    intensity = flops / nbytes
    t_compute = flops / peak_flops
    t_memory = nbytes / hbm_bw
    roofline_flops = flops / max(t_compute, t_memory)
    achieved_flops = flops / wall
    return {
        "kernel": name,
        "shape": shape_str,
        "dtype": str(dtype),
        "backend": jax.default_backend(),
        "interpret": interpret,
        "wall_s": wall,
        "flops": flops,
        "bytes": nbytes,
        "intensity": intensity,
        "achieved_flops_per_s": achieved_flops,
        "achieved_bytes_per_s": nbytes / wall,
        "roofline_flops_per_s": roofline_flops,
        "frac_of_roofline": achieved_flops / roofline_flops,
        "bound": "compute" if t_compute >= t_memory else "memory",
    }


def profile_serving_kernels(cfg, *, slots: int = 4, max_context: int = 128,
                            chunk: int = 32, prefill_lanes: int = 4,
                            repeats: int = 3,
                            kernels=KERNELS) -> list[dict]:
    """Profile every serving kernel at this config's shapes (the grid
    and admission geometry the engine actually launches)."""
    shapes = serving_shapes(cfg, slots=slots, max_context=max_context,
                            chunk=chunk, prefill_lanes=prefill_lanes)
    return [
        profile_kernel(k, dtype=cfg.dtype, repeats=repeats, **shapes[k])
        for k in kernels
    ]


def format_table(rows) -> str:
    """Markdown achieved-vs-roofline table (roofline_table --achieved)."""
    out = [
        "| kernel | shape | wall (ms) | GFLOP/s | GB/s | % roofline "
        "| bound | backend |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        be = r["backend"] + (" (interpret)" if r["interpret"] else "")
        out.append(
            f"| {r['kernel']} | {r['shape']} | {1e3 * r['wall_s']:.3f} "
            f"| {r['achieved_flops_per_s'] / 1e9:.2f} "
            f"| {r['achieved_bytes_per_s'] / 1e9:.2f} "
            f"| {100 * r['frac_of_roofline']:.2f}% "
            f"| {r['bound']} | {be} |"
        )
    return "\n".join(out)


def validate_profile(rows) -> None:
    """Every figure finite and positive (CI bench-smoke contract)."""
    for r in rows:
        for f in ("wall_s", "flops", "bytes", "achieved_flops_per_s",
                  "achieved_bytes_per_s", "roofline_flops_per_s",
                  "frac_of_roofline"):
            v = r[f]
            assert isinstance(v, (int, float)) and np.isfinite(v) and v > 0, (
                r["kernel"], f, v)
