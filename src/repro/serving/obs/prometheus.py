"""Prometheus text exposition for ``ServerMetrics.snapshot()``.

``GET /metrics`` negotiates on the ``Accept`` header: JSON stays the
default (every existing client keeps working), but ``text/plain`` or
``application/openmetrics-text`` answers Prometheus exposition format
0.0.4 — ``# HELP`` / ``# TYPE`` comments, one ``name{labels} value``
sample per line — rendered straight from the same snapshot dict, so the
two representations can never disagree.

No prometheus_client dependency (the container bakes none): the format
is lines of text with three escape sequences in label values
(``\\`` -> ``\\\\``, ``"`` -> ``\\"``, newline -> ``\\n``), which
:func:`escape_label` implements and the tests' parser round-trips.
"""
from __future__ import annotations

import math

PREFIX = "repro"

# (snapshot key, metric name suffix, type, help)
_ENGINE_FIELDS = (
    ("generated_tokens", "generated_tokens_total", "counter",
     "Tokens generated across all instances"),
    ("decode_steps", "decode_steps_total", "counter",
     "Fused (M,B)-grid decode+sample scan steps"),
    ("decode_device_calls", "decode_device_calls_total", "counter",
     "Fused decode device calls (K-step blocks; == steps at K=1)"),
    ("tokens_per_device_call", "tokens_per_device_call", "gauge",
     "Real tokens emitted per fused decode device call (K*occupancy)"),
    ("decode_dispatch_ms_per_token", "decode_dispatch_ms_per_token", "gauge",
     "Host dispatch ms per decoded token (amortized ~K-fold by blocks)"),
    ("prefill_batches", "prefill_chunk_calls_total", "counter",
     "Prefill chunk/tail device calls"),
    ("prefill_tokens", "prefill_tokens_total", "counter",
     "Real (non-padded) prompt positions prefilled"),
    ("device_calls", "device_calls_total", "counter",
     "All device calls: decode steps + prefill chunks + slot scatters"),
    ("scatter_calls", "scatter_calls_total", "counter",
     "Prefill-lane -> grid-slot scatter device calls"),
    ("prefill_compiled_shapes", "prefill_compiled_shapes", "gauge",
     "Distinct compiled prefill shapes (a rise mid-run is a recompile)"),
    ("cancelled", "cancelled_total", "counter",
     "Requests cancelled/expired across all instances"),
    ("rejected", "rejected_total", "counter",
     "Requests rejected at submit-time validation"),
    ("failed", "failed_total", "counter",
     "Requests terminally failed by a contained fault (NaN guard, "
     "prefill/scatter error)"),
    ("shed", "shed_total", "counter",
     "Requests shed by overload brownout (queued past the age bound)"),
    ("requeued", "requeued_total", "counter",
     "Requests requeued by crash recovery (replayed under the same id)"),
    ("replayed_tokens", "tokens_replayed_total", "counter",
     "Tokens regenerated with emission suppressed after a requeue"),
    ("replay_mismatches", "replay_mismatches_total", "counter",
     "Replayed tokens that differed from the delivered prefix "
     "(must stay 0 under greedy decode)"),
    ("tok_per_s", "tokens_per_second", "gauge",
     "Aggregate generation throughput over the metrics window"),
    ("prefill_tok_per_s", "prefill_tokens_per_second", "gauge",
     "Prefill throughput over settled admission wall time"),
    ("decode_tok_per_s", "decode_tokens_per_second", "gauge",
     "Decode throughput over non-prefill wall time"),
    ("admission_stall_ms", "admission_stall_ms_total", "counter",
     "Wall time decode-ready slots waited on admission chunks"),
    ("wall_s", "window_seconds", "gauge",
     "Age of the metrics window"),
)

_INSTANCE_FIELDS = (
    ("submitted", "instance_submitted_total", "counter"),
    ("admitted", "instance_admitted_total", "counter"),
    ("completed", "instance_completed_total", "counter"),
    ("cancelled", "instance_cancelled_total", "counter"),
    ("rejected", "instance_rejected_total", "counter"),
    ("queue_depth", "instance_queue_depth", "gauge"),
    ("queue_peak", "instance_queue_peak", "gauge"),
    ("prompt_tokens", "instance_prompt_tokens_total", "counter"),
    ("generated_tokens", "instance_generated_tokens_total", "counter"),
    ("tok_per_s", "instance_tokens_per_second", "gauge"),
    ("failed", "instance_failed_total", "counter"),
    ("shed", "instance_shed_total", "counter"),
    ("requeued", "instance_requeued_total", "counter"),
)

# snapshot["resilience"] block (Supervisor counters; zeros when no
# Supervisor is wired, so the rows are always present for scrapers)
_RESILIENCE_FIELDS = (
    ("driver_restarts", "driver_restarts_total",
     "Supervised engine-driver restarts (crash or watchdog)"),
    ("request_retries", "request_retries_total",
     "Request requeues across driver restarts"),
    ("watchdog_timeouts", "watchdog_timeouts_total",
     "Device steps that overran the watchdog deadline"),
    ("tokens_replayed", "supervisor_tokens_replayed_total",
     "Delivered-prefix tokens scheduled for suppressed replay"),
    ("retry_budget_exhausted", "retry_budget_exhausted_total",
     "Requests terminally failed after exhausting the retry budget"),
)

HEALTH_STATES = ("healthy", "degraded", "quarantined", "probation")
SLO_STATES = ("ok", "burning", "violated")

_QUANTILES = (("p50", "0.5"), ("p95", "0.95"), ("p99", "0.99"))


def escape_label(value) -> str:
    """Prometheus label-value escaping: backslash, double quote, newline."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _num(v) -> str:
    if v is None:
        return "NaN"
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if not v.is_integer() else str(int(v))


def _sample(name: str, labels: dict, value) -> str:
    if labels:
        body = ",".join(
            f'{k}="{escape_label(v)}"' for k, v in labels.items())
        return f"{PREFIX}_{name}{{{body}}} {_num(value)}"
    return f"{PREFIX}_{name} {_num(value)}"


def render(snapshot: dict, *, extra_labels: dict | None = None) -> str:
    """Render a ``ServerMetrics.snapshot()`` dict as Prometheus text
    exposition (format 0.0.4).  ``extra_labels`` (e.g. mesh geometry)
    attach to every sample."""
    base = dict(extra_labels or {})
    lines: list[str] = []

    def head(name, typ, hlp):
        lines.append(f"# HELP {PREFIX}_{name} {hlp}")
        lines.append(f"# TYPE {PREFIX}_{name} {typ}")

    for key, name, typ, hlp in _ENGINE_FIELDS:
        if key not in snapshot:
            continue
        head(name, typ, hlp)
        lines.append(_sample(name, base, snapshot[key]))

    for block, name in (("ttft_ms", "ttft_milliseconds"),
                        ("itl_ms", "itl_milliseconds")):
        head(name, "summary", f"{block} quantiles over the sample window")
        d = snapshot.get(block)
        for pkey, q in _QUANTILES:
            lines.append(_sample(
                name, {**base, "quantile": q},
                d[pkey] if d is not None else None))

    insts = snapshot.get("instances", ())
    for key, name, typ in _INSTANCE_FIELDS:
        head(name, typ, f"Per-instance {key}")
        for i, st in enumerate(insts):
            lines.append(_sample(name, {**base, "instance": i}, st[key]))
    for block, name in (("ttft_ms", "instance_ttft_milliseconds"),
                        ("itl_ms", "instance_itl_milliseconds")):
        head(name, "summary", f"Per-instance {block} quantiles")
        for i, st in enumerate(insts):
            d = st.get(block)
            for pkey, q in _QUANTILES:
                lines.append(_sample(
                    name, {**base, "instance": i, "quantile": q},
                    d[pkey] if d is not None else None))

    for block, name in (("ttft_hist", "instance_ttft_seconds"),
                        ("itl_hist", "instance_itl_seconds")):
        if not any(st.get(block) for st in insts):
            continue
        head(name, "histogram",
             f"Per-instance {block.split('_')[0]} log-bucketed histogram")
        for i, st in enumerate(insts):
            h = st.get(block)
            if h is None:
                continue
            for le, cum in h["buckets"]:
                lines.append(_sample(
                    f"{name}_bucket",
                    {**base, "instance": i,
                     "le": "+Inf" if math.isinf(le) else _num(le)},
                    cum))
            lines.append(_sample(f"{name}_sum", {**base, "instance": i},
                                 h["sum"]))
            lines.append(_sample(f"{name}_count", {**base, "instance": i},
                                 h["count"]))

    slo = snapshot.get("slo")
    if slo is not None and slo.get("configured"):
        head("slo_burn_rate", "gauge",
             "Recent bad fraction over the allowed SLO error budget "
             "(>1 means the budget is burning)")
        for i, inst in enumerate(slo["instances"]):
            for obj, rep in inst["objectives"].items():
                lines.append(_sample(
                    "slo_burn_rate", {**base, "instance": i, "objective": obj},
                    rep["burn_rate"]))
        head("slo_budget_remaining", "gauge",
             "Fraction of the cumulative SLO error budget still unspent")
        for i, inst in enumerate(slo["instances"]):
            for obj, rep in inst["objectives"].items():
                lines.append(_sample(
                    "slo_budget_remaining",
                    {**base, "instance": i, "objective": obj},
                    rep["budget_remaining"]))
        head("slo_state", "gauge",
             "Per-instance worst objective state; the active state reads 1")
        for i, inst in enumerate(slo["instances"]):
            for state in SLO_STATES:
                lines.append(_sample(
                    "slo_state", {**base, "instance": i, "state": state},
                    1 if inst["state"] == state else 0))

    acct = snapshot.get("accounting")
    if acct is not None:
        head("tenant_device_seconds_total", "counter",
             "Settled device wall seconds attributed to each tenant, "
             "split by account (decode/prefill/scatter/idle)")
        for i, per in sorted(acct["per_tenant"].items(),
                             key=lambda kv: int(kv[0])):
            for account in ("decode_s", "prefill_s", "scatter_s", "idle_s"):
                lines.append(_sample(
                    "tenant_device_seconds_total",
                    {**base, "instance": i,
                     "account": account.removesuffix("_s")},
                    per[account]))
        head("tenant_queue_wait_seconds_total", "counter",
             "Queue wait accumulated by each tenant's admitted requests")
        for i, per in sorted(acct["per_tenant"].items(),
                             key=lambda kv: int(kv[0])):
            lines.append(_sample(
                "tenant_queue_wait_seconds_total", {**base, "instance": i},
                per["queue_wait_s"]))
        head("attribution_conservation_rel_err", "gauge",
             "Relative error |attributed - settled| / settled "
             "(the conservation invariant; must stay < 0.01)")
        lines.append(_sample("attribution_conservation_rel_err", base,
                             acct["conservation_rel_err"]))

    res = snapshot.get("resilience")
    if res is not None:
        for key, name, hlp in _RESILIENCE_FIELDS:
            head(name, "counter", hlp)
            lines.append(_sample(name, base, res.get(key, 0)))
        head("last_recovery_seconds", "gauge",
             "Duration of the most recent driver recovery (NaN if none)")
        lines.append(_sample("last_recovery_seconds", base,
                             res.get("last_recovery_s")))

    health = snapshot.get("health")
    if health is not None:
        head("instances_quarantined", "gauge",
             "Instances currently quarantined (their requests 503)")
        lines.append(_sample("instances_quarantined", base,
                             health["quarantined_now"]))
        head("instance_health_state", "gauge",
             "Per-instance health lifecycle; the active state reads 1")
        for i, st in enumerate(health["states"]):
            for state in HEALTH_STATES:
                lines.append(_sample(
                    "instance_health_state",
                    {**base, "instance": i, "state": state},
                    1 if st == state else 0))

    mesh = snapshot.get("mesh")
    if mesh is not None:
        head("mesh_devices", "gauge", "Devices in the serving mesh")
        lines.append(_sample(
            "mesh_devices",
            {**base, "shape": "x".join(
                f"{k}={v}" for k, v in mesh["shape"].items())},
            mesh["devices"]))
    return "\n".join(lines) + "\n"
