"""Log-bucketed latency histograms + per-tenant SLO evaluation (§6.9).

Two pieces, both deliberately free of engine imports (stdlib only, so
``metrics.py`` can import this module without touching the rest of the
obs package's dependency graph):

* :class:`LogHistogram` — an HDR-style geometric-bucket histogram.  The
  bounded TTFT/ITL sample windows in ``metrics.py`` (``deque(maxlen=
  4096)``) silently drop the *oldest* samples, so on a long run the
  reported p99 is the p99 of the last few minutes, not of the run —
  tail bias that gets worse the longer the server lives.  A histogram
  with geometric buckets keeps every sample forever at O(buckets)
  memory: percentiles are unbiased over the whole run, with relative
  error bounded by the bucket growth factor (``2**0.25`` → ≤ ~19% per
  bucket, ~9.5% expected).  Buckets are FIXED at import time (every
  histogram shares the same ``les`` table), which is what makes
  :meth:`merge` and Prometheus ``histogram`` exposition (cumulative
  ``le`` buckets) exact.

* :func:`evaluate_objective` — SLO error-budget math.  An objective is
  "``target`` of samples must land at or under ``threshold_ms``"
  (e.g. 99% of TTFTs under 200 ms).  The *cumulative* bad fraction
  comes from the histogram (the whole run: has the budget been spent?);
  the *recent* burn rate comes from the caller's last-N sample window
  (the same deques the percentile fix demoted to a debug view — they
  are exactly a sliding recent window, which is what burn rate wants).
  States: ``violated`` (cumulative budget exhausted), ``burning``
  (recent window failing faster than the budget allows — on track to
  violate), ``ok``.
"""
from __future__ import annotations

import bisect
import dataclasses
import math

# geometric bucket ladder: 0.1 ms .. 120 s, 4 buckets per octave.
# ~82 finite buckets + the +Inf bucket; shared by every LogHistogram so
# merge() and cross-instance aggregation are bucket-exact.
HIST_LO_S = 1e-4
HIST_HI_S = 120.0
HIST_GROWTH = 2 ** 0.25


def _bucket_bounds() -> tuple:
    les = [HIST_LO_S]
    while les[-1] < HIST_HI_S:
        les.append(les[-1] * HIST_GROWTH)
    return tuple(les)


_LES = _bucket_bounds()


class LogHistogram:
    """Fixed geometric-bucket latency histogram (seconds).

    ``record`` is one ``bisect`` on the shared bounds table plus three
    scalar updates — cheap enough to be ALWAYS ON (histograms are the
    percentile-bias fix, not an opt-in observability layer).  Bucket i
    counts samples v with ``les[i-1] < v <= les[i]``; the last bucket
    is +Inf.  ``percentile`` returns the matched bucket's UPPER bound:
    a conservative (never under-reporting) estimate whose relative
    error is bounded by the growth factor."""

    __slots__ = ("counts", "sum", "count")

    les = _LES                       # ascending upper bounds, seconds

    def __init__(self):
        self.counts = [0] * (len(_LES) + 1)    # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def record(self, v: float) -> None:
        self.counts[bisect.bisect_left(_LES, v)] += 1
        self.sum += v
        self.count += 1

    def __len__(self) -> int:
        return self.count

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Accumulate ``other`` into self (same bounds by construction)."""
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count
        return self

    def percentile(self, q: float) -> float:
        """q in [0, 1] → seconds (upper bound of the matched bucket).
        Nearest-rank on the cumulative counts; +Inf bucket reports the
        largest finite bound (nothing tighter is known)."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return _LES[i] if i < len(_LES) else _LES[-1]
        return _LES[-1]

    def percentiles(self, scale: float = 1e3) -> dict | None:
        """{"p50","p95","p99"} scaled (default ms) — the same contract
        as ``metrics.percentiles``; None when empty."""
        if not self.count:
            return None
        return {"p50": self.percentile(0.50) * scale,
                "p95": self.percentile(0.95) * scale,
                "p99": self.percentile(0.99) * scale}

    def frac_le(self, threshold_s: float) -> float:
        """Fraction of samples known to be <= threshold (counts only
        buckets wholly at or under it — conservative: a threshold
        mid-bucket credits none of that bucket, so the derived bad
        fraction never under-reports)."""
        if not self.count:
            return 1.0
        k = bisect.bisect_right(_LES, threshold_s)
        return sum(self.counts[:k]) / self.count

    def buckets(self):
        """Yield ``(le_seconds, cumulative_count)`` per finite bucket,
        then ``(inf, total_count)`` — the Prometheus histogram rows."""
        cum = 0
        for i, le in enumerate(_LES):
            cum += self.counts[i]
            yield le, cum
        yield math.inf, self.count

    def snapshot(self) -> dict:
        return {"buckets": [[le, cum] for le, cum in self.buckets()],
                "sum": self.sum, "count": self.count}


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Per-instance serving objectives.  ``None`` threshold = objective
    not set (not evaluated).  ``target`` is the good-fraction goal for
    the latency objectives; ``availability_target`` for completed vs
    failed requests."""
    ttft_ms: float | None = None
    itl_ms: float | None = None
    target: float = 0.99
    availability_target: float = 0.99

    def active(self) -> bool:
        return self.ttft_ms is not None or self.itl_ms is not None


def evaluate_objective(hist: LogHistogram, recent, threshold_ms: float,
                       target: float = 0.99) -> dict:
    """Error-budget view of one latency objective.

    ``allowed = 1 - target`` is the error budget as a fraction of
    samples.  Cumulative ``bad_frac`` (from the histogram, whole run)
    against it gives ``budget_remaining`` and the terminal ``violated``
    state; the bad fraction of ``recent`` (an iterable of seconds —
    the last-N debug window) over ``allowed`` is the burn rate: > 1
    means the recent window is failing faster than the budget can
    absorb (``burning``)."""
    allowed = max(1.0 - target, 1e-12)
    n = hist.count
    bad_frac = (1.0 - hist.frac_le(threshold_ms * 1e-3)) if n else 0.0
    recent = list(recent)
    recent_bad = (sum(1 for v in recent if v > threshold_ms * 1e-3)
                  / len(recent)) if recent else 0.0
    burn_rate = recent_bad / allowed
    if n and bad_frac > allowed:
        state = "violated"
    elif burn_rate > 1.0:
        state = "burning"
    else:
        state = "ok"
    return {
        "threshold_ms": threshold_ms,
        "target": target,
        "count": n,
        "bad_frac": bad_frac,
        "burn_rate": burn_rate,
        "budget_remaining": 1.0 - bad_frac / allowed,
        "state": state,
    }


def evaluate_availability(completed: int, failed: int,
                          target: float = 0.99) -> dict:
    """Availability objective from terminal request counts (failed =
    error/unavailable outcomes chargeable to the server)."""
    allowed = max(1.0 - target, 1e-12)
    n = completed + failed
    bad_frac = failed / n if n else 0.0
    burn_rate = bad_frac / allowed
    state = ("violated" if n and bad_frac > allowed else "ok")
    return {
        "target": target,
        "count": n,
        "bad_frac": bad_frac,
        "burn_rate": burn_rate,
        "budget_remaining": 1.0 - bad_frac / allowed,
        "state": state,
    }


def worst_state(states) -> str:
    """Fold per-objective states into one instance-level state."""
    order = {"ok": 0, "burning": 1, "violated": 2}
    worst = "ok"
    for s in states:
        if order.get(s, 0) > order[worst]:
            worst = s
    return worst
