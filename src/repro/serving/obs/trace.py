"""Step-level tracing for the fused serving engine.

The engine's whole argument is GPU/TPU utilization — M merged instances
sharing one fused (M, B) program should beat M sequential programs — yet
until now the only figures were end-to-end tokens/s.  :class:`Tracer`
makes the per-step anatomy visible: every device call (fused decode
step, prefill chunk, slot scatter) becomes one ring-buffered event
carrying

* **wall vs settled time** — dispatch wall (host time to issue the
  async call) and settled wall (through ``block_until_ready`` /
  ``device_get``), so host dispatch overhead separates from device
  execution,
* **dispatch gap** — host time since the previous device call settled:
  the per-step overhead that makes the fused path lose to the
  sequential baseline at small M (BENCH_serve.json ``speedup`` < 1),
* **grid occupancy** — active decoding (M, B) slots vs capacity, the
  paper's utilization claim made measurable per step, plus prefill
  lanes busy and the validity fraction of padded chunks,

and every request leaves a lifecycle trail (submit → admit →
prefill-done → finish/cancel) correlated by request id, exported as
spans.

Off by default and **free when off**: every engine call site guards on
``tracer.enabled`` before touching the tracer, so the disabled path
constructs no event objects, takes no locks, and reads no clocks
(tests assert zero event construction).  When on, events append to a
bounded ``deque`` under a lock (the async frontend runs steps on an
executor thread while ``GET /debug/trace`` exports from the event
loop), so capture cost is O(1) per device call and memory is capped by
``capacity``.

Exports:

* :meth:`Tracer.export_chrome` — Chrome-trace / Perfetto JSON
  (``chrome://tracing`` or https://ui.perfetto.dev): device calls on a
  ``device`` process (one track per call kind), request phases on a
  ``requests`` process (one track per request id),
* :meth:`Tracer.summary` — aggregates: dispatch-overhead p50/p95,
  mean grid occupancy, idle-slot token-steps, prefill-lane occupancy,
  chunk validity.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

DEFAULT_CAPACITY = 65536

# request lifecycle stages, in order; consecutive pairs become spans
STAGES = ("submit", "admit", "prefill_done", "finish")
TERMINAL = ("finish", "cancel")
# resilience stages (DESIGN.md §6.8): each occurrence renders as its
# own instant (a request can requeue more than once, a driver can
# restart more than once — these never collapse into lifecycle spans)
RECOVERY = ("requeue", "restart", "shed", "quarantine")


@dataclasses.dataclass
class DeviceCallEvent:
    """One device call: a fused decode step, a prefill chunk/tail call,
    or a prefill->grid slot scatter."""
    kind: str                  # "decode" | "prefill_chunk" | "scatter"
    t0: float                  # dispatch begin (tracer clock)
    t_dispatch: float          # dispatch returned (async call issued)
    t_settled: float           # outputs settled on the host
    gap_s: float               # host gap since the previous call settled
    step: int                  # engine step counter at the call
    active: int = 0            # decoding (M, B) slots at the call
    capacity: int = 0          # M * B
    lanes_busy: int = 0        # prefill lanes mid-admission
    lanes: int = 0             # total prefill lanes
    valid_frac: float = 1.0    # real positions / padded positions (chunks)
    tokens: int = 0            # real tokens this call advanced
    pending: int = 0           # queued requests at the call
    decode_steps: int = 1      # scan steps fused into this call (decode
                               # blocks, DESIGN.md §6.6; 1 otherwise)


@dataclasses.dataclass
class RequestEvent:
    """One request-lifecycle edge, correlated by request id."""
    rid: int
    stage: str                 # submit | admit | prefill_done | finish | cancel
    t: float
    instance: int = -1
    status: str | None = None  # terminal stages: ok/cancelled/expired/...


class Tracer:
    """Ring-buffered step tracer; disabled until :meth:`start`.

    Call sites MUST guard on ``tracer.enabled`` — the methods themselves
    assume capture is on (that keeps the disabled hot path at literal
    zero cost: one attribute read per guard)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock=time.perf_counter):
        self.enabled = False
        self.capacity = capacity
        self.clock = clock
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._epoch = 0.0          # clock at start(); event times relative
        self._last_settled: float | None = None
        self.dropped = 0           # events evicted by the ring bound

    # -- capture lifecycle ---------------------------------------------------

    def start(self) -> None:
        """Begin (or restart) capture; the ring and clock epoch reset so
        a fresh capture never mixes with a previous window."""
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._epoch = self.clock()
            self._last_settled = None
            self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    def __len__(self) -> int:
        return len(self._events)

    def _append(self, ev) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    # -- recording (call only when ``enabled``) ------------------------------

    def device_call(self, kind: str, t0: float, t_dispatch: float,
                    t_settled: float, *, step: int = 0, active: int = 0,
                    capacity: int = 0, lanes_busy: int = 0, lanes: int = 0,
                    valid_frac: float = 1.0, tokens: int = 0,
                    pending: int = 0, decode_steps: int = 1) -> None:
        """Record one device call; timestamps are raw ``clock()`` reads
        (the tracer rebases them onto its epoch)."""
        last = self._last_settled
        self._last_settled = t_settled
        self._append(DeviceCallEvent(
            kind, t0 - self._epoch, t_dispatch - self._epoch,
            t_settled - self._epoch,
            gap_s=(t0 - last) if last is not None else 0.0,
            step=step, active=active, capacity=capacity,
            lanes_busy=lanes_busy, lanes=lanes, valid_frac=valid_frac,
            tokens=tokens, pending=pending, decode_steps=decode_steps,
        ))

    def request_event(self, rid: int, stage: str, *, instance: int = -1,
                      status: str | None = None) -> None:
        self._append(RequestEvent(
            rid, stage, self.clock() - self._epoch, instance, status))

    # -- export --------------------------------------------------------------

    def _snapshot(self) -> list:
        with self._lock:
            return list(self._events)

    def export_chrome(self) -> dict:
        """The capture as Chrome-trace JSON (the ``traceEvents`` array
        format Perfetto and ``chrome://tracing`` load directly).

        Device calls render as complete ("X") slices on pid 0, one tid
        per call kind, with the dispatch gap and occupancy in ``args``;
        request lifecycles render on pid 1, one tid per request id, as
        one slice per completed phase (queued / prefill / decode) plus
        an instant ("i") event at terminal stages."""
        us = lambda t: t * 1e6
        kinds: dict[str, int] = {}
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "device"}},
            {"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "requests"}},
        ]
        marks: dict[int, dict[str, RequestEvent]] = {}
        for ev in self._snapshot():
            if isinstance(ev, DeviceCallEvent):
                tid = kinds.setdefault(ev.kind, len(kinds))
                events.append({
                    "name": ev.kind, "ph": "X", "cat": "device",
                    "pid": 0, "tid": tid,
                    "ts": us(ev.t0), "dur": max(us(ev.t_settled - ev.t0), 0.0),
                    "args": {
                        "step": ev.step,
                        "dispatch_ms": 1e3 * (ev.t_dispatch - ev.t0),
                        "settled_ms": 1e3 * (ev.t_settled - ev.t0),
                        "gap_ms": 1e3 * ev.gap_s,
                        "active_slots": ev.active,
                        "slot_capacity": ev.capacity,
                        "occupancy": (ev.active / ev.capacity
                                      if ev.capacity else 0.0),
                        "lanes_busy": ev.lanes_busy,
                        "lanes": ev.lanes,
                        "valid_frac": ev.valid_frac,
                        "tokens": ev.tokens,
                        "pending": ev.pending,
                        "decode_steps": ev.decode_steps,
                    },
                })
            elif ev.stage in RECOVERY:
                # rendered immediately (not via marks): every
                # occurrence is its own instant, and rid -1 (driver
                # restarts) is not a request lifecycle
                events.append({
                    "name": (f"{ev.stage}:{ev.status}" if ev.status
                             else ev.stage),
                    "ph": "i", "cat": "resilience", "pid": 1,
                    "tid": ev.rid, "ts": us(ev.t), "s": "t",
                    "args": {"request_id": ev.rid,
                             "instance": ev.instance},
                })
            else:
                marks.setdefault(ev.rid, {})[ev.stage] = ev
        for tid, kind in sorted((v, k) for k, v in kinds.items()):
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": kind}})
        span_names = {("submit", "admit"): "queued",
                      ("admit", "prefill_done"): "prefill",
                      ("prefill_done", "finish"): "decode",
                      # zero-work admissions skip prefill_done; cancels
                      # can land in any phase — close with what exists
                      ("submit", "finish"): "request",
                      ("submit", "cancel"): "cancelled",
                      ("admit", "finish"): "serve",
                      ("admit", "cancel"): "cancelled",
                      ("prefill_done", "cancel"): "cancelled"}
        for rid, stages in marks.items():
            order = [s for s in
                     ("submit", "admit", "prefill_done", "finish", "cancel")
                     if s in stages]
            for a, b in zip(order, order[1:]):
                ea, eb = stages[a], stages[b]
                events.append({
                    "name": span_names.get((a, b), f"{a}->{b}"),
                    "ph": "X", "cat": "request", "pid": 1, "tid": rid,
                    "ts": us(ea.t), "dur": max(us(eb.t - ea.t), 0.0),
                    "args": {"request_id": rid, "instance": eb.instance
                             if eb.instance >= 0 else ea.instance},
                })
            for s in TERMINAL:
                if s in stages:
                    ev = stages[s]
                    events.append({
                        "name": f"{s}:{ev.status or 'ok'}", "ph": "i",
                        "cat": "request", "pid": 1, "tid": rid,
                        "ts": us(ev.t), "s": "t",
                        "args": {"request_id": rid, "status": ev.status},
                    })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def summary(self) -> dict:
        """Aggregate the capture: the figures BENCH_serve.json records
        and ``perf_delta --serve`` diffs across PRs."""
        # local import: metrics.py imports obs.slo at module scope, so a
        # module-level import here would close an import cycle through
        # the obs package __init__
        from repro.serving.metrics import percentiles
        calls = [e for e in self._snapshot()
                 if isinstance(e, DeviceCallEvent)]
        decodes = [e for e in calls if e.kind == "decode"]
        chunks = [e for e in calls if e.kind == "prefill_chunk"]
        # the first call of a capture has no predecessor: gap 0 by
        # construction, harmless in the percentiles
        gaps = [e.gap_s for e in calls]
        occ = [e.active / e.capacity for e in decodes if e.capacity]
        decode_tokens = sum(e.tokens for e in decodes)
        decode_gap_s = sum(e.gap_s for e in decodes)
        out = {
            "device_calls": len(calls),
            "decode_steps": len(decodes),   # decode device calls (blocks)
            # multi-step decode (DESIGN.md §6.6): scan steps fused into
            # those calls, and the per-TOKEN dispatch cost — the figure
            # K-fold amortization actually improves (per-CALL overhead
            # stays flat while each call yields up to K*occupancy tokens)
            "decode_scan_steps": sum(e.decode_steps for e in decodes),
            "mean_decode_steps_per_call": (
                sum(e.decode_steps for e in decodes) / len(decodes)
                if decodes else 0.0),
            "dispatch_overhead_per_token_ms": (
                1e3 * decode_gap_s / decode_tokens
                if decode_tokens else None),
            "prefill_chunks": len(chunks),
            "scatters": sum(1 for e in calls if e.kind == "scatter"),
            # host time between device calls — the per-step dispatch
            # overhead the megakernel/multi-step-decode work must attack
            "dispatch_overhead_ms": percentiles(gaps),
            "mean_dispatch_gap_ms": (
                1e3 * sum(gaps) / len(gaps) if gaps else 0.0),
            "settled_ms": percentiles(
                [e.t_settled - e.t0 for e in calls]),
            # the utilization claim: decoding slots / grid capacity
            "mean_grid_occupancy": sum(occ) / len(occ) if occ else 0.0,
            # slot-steps the fused program computed for nobody (an idle
            # lane still rides every fused step)
            "idle_slot_token_steps": sum(
                e.capacity - e.active for e in decodes),
            "mean_prefill_lane_occupancy": (
                sum(e.lanes_busy / e.lanes for e in chunks if e.lanes)
                / len(chunks) if chunks else 0.0),
            "mean_chunk_validity": (
                sum(e.valid_frac for e in chunks) / len(chunks)
                if chunks else 0.0),
            "dropped_events": self.dropped,
        }
        return out
