"""Length-bucketed, batched prefill for serving admission.

The old engine jitted ``api.prefill`` at the exact prompt shape — every
new prompt length triggered a fresh XLA compile, and k admitted requests
cost k device calls.  Admission here is compiled per *bucket*:

* prompts are right-padded to the next length bucket (defaults are
  powers of two clipped to the cache length), and up to a power-of-two
  batch of requests is prefilled in ONE fused call — each request rides
  the *instances* axis of the merged program via an on-device gather of
  its model's weight rows (``gather_instances``), so requests targeting
  different fine-tuned models still share the batch,
* padded junk positions are harmless for KV-cache families: the grid
  decode masks cache slots beyond the current position (see
  DESIGN.md §6), and the engine re-decodes the last prompt token so no
  logits need to be extracted at per-request offsets,
* recurrent-state families can't absorb padded junk (state integrates
  every step), so exactness is kept a different way: ssm prompts are
  processed in fixed-size chunks through a state-carrying prefill (one
  compile for the chunk, one for the single-token tail) and hybrid
  prompts fall back to exact-length per-request prefill (documented
  limitation: Hymba's meta-token attention + SWA ring make mid-prompt
  cache chaining family-specific work).

MoE caveat: expert capacity is computed over the padded token count, so
a bucketed moe prefill may route marginal tokens differently from an
exact-length prefill.  Greedy serving output equality is only guaranteed
for dense/vlm (and tested there); moe serving is validated as a smoke
path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.launch.compat import mesh_context
from repro.models.common import constrain_tree, gather_instances

DEFAULT_BUCKETS = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
KV_FAMILIES = ("dense", "moe", "vlm", "audio")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class PrefillOut:
    """One admitted request's prefill product.

    ``cache`` is a cache/state tree whose instances axis holds this
    request at row ``index`` (batched KV prefills share one tree across
    the group; recurrent prefills are per-request with index 0).  The
    engine scatters row ``index`` into the request's grid slot, then
    seeds decode at ``pos`` with ``last_token`` — the last prompt token
    is (re)decoded by the first fused grid step, so sampling stays fully
    on-device and prefill never extracts per-request logits."""
    cache: Any
    index: int
    pos: int
    last_token: int


class BucketedPrefill:
    def __init__(
        self,
        cfg,
        *,
        max_context: int,
        buckets: tuple[int, ...] | None = None,
        recurrent_chunk: int = 16,
        metrics=None,
        mesh=None,
        rules=None,
    ):
        if cfg.family not in KV_FAMILIES + ("ssm", "hybrid"):
            raise ValueError(f"family {cfg.family!r} is not servable")
        self.cfg = cfg
        self.family = cfg.family
        self.max_context = max_context
        self.metrics = metrics
        self.chunk = max(1, recurrent_chunk)
        self._axes = api.axes(cfg)
        # mesh-parametric admission: every prefill jit traces under the
        # mesh + rules context (model-zoo constrain calls engage) and the
        # produced cache/state tree is pinned to the rules' layout, so
        # the engine's slot scatter consumes already-sharded trees
        from repro.launch.shardings import default_serve_rules
        self.mesh = mesh
        self.rules = default_serve_rules(mesh, rules)
        self._cache_axes = api.cache_axes(cfg)
        # KV prefill caches are built directly at the grid's cache length
        # so slot scatter is a pure dynamic-update (no reshaping)
        self.cache_len = (
            (cfg.sliding_window or max_context) if cfg.family in KV_FAMILIES
            else max_context
        )
        prefix = cfg.num_image_patches if cfg.family == "vlm" else 0
        cap = self.cache_len - prefix
        assert cap > 0, (self.cache_len, prefix)
        base = buckets if buckets is not None else DEFAULT_BUCKETS
        self.buckets = tuple(sorted({min(b, cap) for b in base} | {cap}))
        self._fns: dict = {}          # (family-specific key) -> jitted fn
        self._zero_state = None

    # -- public --------------------------------------------------------------

    def max_prompt_len(self) -> int:
        """Longest admissible prompt (tokens)."""
        if self.family == "hybrid":
            from repro.models import hybrid as H
            return self.max_context - H.NUM_META_TOKENS
        if self.family == "ssm":
            return self.max_context
        return self.buckets[-1]

    @property
    def compiled_shapes(self) -> int:
        return len(self._fns)

    def run(self, params, reqs) -> list[PrefillOut]:
        """Prefill the admitted requests; one PrefillOut per request, in
        the same order."""
        with mesh_context(self.mesh, self.rules):
            if self.family == "ssm":
                return [self._run_ssm(params, r) for r in reqs]
            if self.family == "hybrid":
                return [self._run_hybrid(params, r) for r in reqs]
            return self._run_kv(params, reqs)

    # -- KV-cache families: padded bucket batches ----------------------------

    def _bucket(self, n: int) -> int:
        for s in self.buckets:
            if s >= n:
                return s
        raise ValueError(
            f"prompt of {n} tokens exceeds the largest bucket "
            f"{self.buckets[-1]} (max_context={self.max_context})"
        )

    def _run_kv(self, params, reqs) -> list[PrefillOut]:
        outs: list[PrefillOut | None] = [None] * len(reqs)
        groups: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            groups.setdefault(self._bucket(len(r.prompt)), []).append(i)
        prefix = self.cfg.num_image_patches if self.family == "vlm" else 0
        for s_b, idxs in sorted(groups.items()):
            kb = _next_pow2(len(idxs))
            toks = np.zeros((kb, 1, s_b), np.int32)
            inst = np.zeros((kb,), np.int32)
            for row, i in enumerate(idxs):
                p = reqs[i].prompt
                toks[row, 0, : len(p)] = p
                inst[row] = reqs[i].instance
            cache = self._kv_fn(s_b, kb)(params, jnp.asarray(inst), jnp.asarray(toks))
            if self.metrics is not None:
                self.metrics.note_prefill_batch(len(idxs))
            for row, i in enumerate(idxs):
                r = reqs[i]
                outs[i] = PrefillOut(
                    cache=cache, index=row,
                    pos=prefix + len(r.prompt) - 1, last_token=r.prompt[-1],
                )
        return outs  # type: ignore[return-value]

    def _kv_fn(self, s_b: int, kb: int):
        key = ("kv", s_b, kb)
        if key not in self._fns:
            cfg = self.cfg

            def fn(params, idx, tokens):
                sub = gather_instances(params, self._axes, idx)
                batch = {"tokens": tokens}
                if cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros(
                        (kb, 1, cfg.num_image_patches, cfg.vision_embed_dim),
                        jnp.dtype(cfg.dtype),
                    )
                elif cfg.family == "audio":
                    batch["frames"] = jnp.zeros(
                        (kb, 1, cfg.num_audio_frames, cfg.d_model),
                        jnp.dtype(cfg.dtype),
                    )
                _, cache = api.prefill(cfg, sub, batch, cache_len=self.cache_len)
                return constrain_tree(cache, self._cache_axes)

            self._fns[key] = jax.jit(fn)
        return self._fns[key]

    # -- ssm: exact chunked state-carrying prefill ---------------------------

    def _zero(self):
        if self._zero_state is None:
            from repro.models import ssm
            self._zero_state = ssm.make_state(self.cfg, 1, 1)
        return self._zero_state

    def _run_ssm(self, params, req) -> PrefillOut:
        toks = np.asarray(req.prompt[:-1], np.int32)
        idx = jnp.asarray([req.instance], jnp.int32)
        state = self._zero()
        i, c = 0, self.chunk
        while i + c <= len(toks):
            state = self._ssm_fn(c)(
                params, idx, jnp.asarray(toks[i : i + c]).reshape(1, 1, c), state
            )
            i += c
        for t in toks[i:]:
            state = self._ssm_fn(1)(
                params, idx, jnp.full((1, 1, 1), t, jnp.int32), state
            )
        if self.metrics is not None:
            self.metrics.note_prefill_batch(1)
        return PrefillOut(
            cache=state, index=0, pos=len(req.prompt) - 1,
            last_token=req.prompt[-1],
        )

    def _ssm_fn(self, c: int):
        key = ("ssm", c)
        if key not in self._fns:
            cfg = self.cfg
            from repro.models import ssm

            def fn(params, idx, tokens, state):
                sub = gather_instances(params, self._axes, idx)
                _, st = ssm.prefill(cfg, sub, tokens, state=state)
                return constrain_tree(st, self._cache_axes)

            self._fns[key] = jax.jit(fn)
        return self._fns[key]

    # -- hybrid: exact-length per-request prefill ----------------------------

    def _run_hybrid(self, params, req) -> PrefillOut:
        from repro.models import hybrid as H
        toks = np.asarray(req.prompt[:-1], np.int32).reshape(1, 1, -1)
        cache = self._hybrid_fn(toks.shape[2])(
            params, jnp.asarray([req.instance], jnp.int32), jnp.asarray(toks)
        )
        if self.metrics is not None:
            self.metrics.note_prefill_batch(1)
        return PrefillOut(
            cache=cache, index=0,
            pos=H.NUM_META_TOKENS + len(req.prompt) - 1,
            last_token=req.prompt[-1],
        )

    def _hybrid_fn(self, s: int):
        key = ("hybrid", s)
        if key not in self._fns:
            cfg = self.cfg

            def fn(params, idx, tokens):
                sub = gather_instances(params, self._axes, idx)
                _, cache = api.prefill(cfg, sub, {"tokens": tokens})
                return constrain_tree(cache, self._cache_axes)

            self._fns[key] = jax.jit(fn)
        return self._fns[key]
