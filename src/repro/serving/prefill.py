"""Unified chunked prefill for serving admission — one length-agnostic
path for EVERY family, interleavable with decode.

The old admission layer was three divergent paths (padded length-bucket
batches for KV families, a state-carrying chunk loop for ssm, exact
per-prompt-length compiles for hybrid) with a documented MoE capacity
caveat.  This runtime replaces all of them: every prompt — dense, moe,
vlm, audio, ssm AND hybrid — streams through the family's chainable
``api.prefill_chunk`` (DESIGN.md §6.2) in fixed-size chunks, so

* admission compiles exactly ONE shape per family — the chunk; the old
  single-token tail loop is folded into one padded final chunk with
  per-position validity masks (``tail_fold``), so a mixed-length lane
  batch drains in ``ceil(L_max/chunk)`` device calls with zero
  per-token tail calls (``compiled_shapes``/``device_calls`` assert
  this in tests),
* up to ``lanes`` requests prefill together in ONE carry tree, each
  riding the instances axis of the merged program via an on-device
  weight-row gather (``gather_instances``); per-lane traced offsets let
  lanes sit at different prompt depths inside the same compiled call,
* progress is incremental: the engine grants a per-step chunk *budget*,
  so a 4k prompt no longer stalls the decode grid — partially-prefilled
  lanes coexist with decoding slots (true continuous batching),
* exactness is positional, not padded: chunk queries attend over
  [cache-so-far, chunk] with ring/meta/window validity encoded in one
  kv-position mask, recurrent state threads through the carry, and moe
  routing carries per-expert counts + real-length capacities so chunked
  routing equals the exact-length pass.

Lane lifecycle: ``start`` binds a request to a free lane; each jitted
call takes (valid, fresh) lane masks — ``fresh`` re-initializes a
lane's carry rows in-graph (no extra compiled shape for resets),
``valid`` gates which lanes actually advance.  Completed lanes are
handed to the engine as :class:`PrefillOut` rows of the shared carry
tree and scattered into their grid slots.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.launch.compat import mesh_context
from repro.models import common as C
from repro.models.common import constrain_tree, gather_instances
from repro.serving.scheduler import Request

KV_FAMILIES = ("dense", "moe", "vlm", "audio")
SERVABLE = KV_FAMILIES + ("ssm", "hybrid")

DEFAULT_CHUNK = 32
DEFAULT_LANES = 4


@dataclasses.dataclass
class PrefillOut:
    """One admitted request's prefill product.

    ``cache`` is a cache/state tree whose instances axis holds this
    request at row ``index`` (all completed lanes of one advance() share
    the same tree).  The engine scatters row ``index`` into the
    request's grid slot, then seeds decode at ``pos`` with
    ``last_token`` — the last prompt token is (re)decoded by the first
    fused grid step, so sampling stays fully on-device and prefill never
    extracts per-request logits."""
    cache: Any
    index: int
    pos: int
    last_token: int


@dataclasses.dataclass
class _Lane:
    req: Request | None = None
    next_pos: int = 0          # next absolute position to process
    total: int = 0             # positions to prefill = prefix + len(prompt) - 1
    fresh: bool = False        # carry rows need re-init before first work


class ChunkedPrefill:
    def __init__(
        self,
        cfg,
        *,
        max_context: int,
        chunk: int = DEFAULT_CHUNK,
        lanes: int = DEFAULT_LANES,
        metrics=None,
        mesh=None,
        rules=None,
        tail_fold: bool = True,
        donate: bool | None = None,
        tracer=None,
        accounting=None,
    ):
        if cfg.family not in SERVABLE:
            raise ValueError(f"family {cfg.family!r} is not servable")
        self.cfg = cfg
        self.family = cfg.family
        self.max_context = max_context
        self.metrics = metrics
        # step tracer (engine-owned; None for standalone use) — call
        # sites guard on ``tracer.enabled`` so the off path is free
        self.tracer = tracer
        # per-tenant attribution (§6.9), same off-is-free discipline
        self.accounting = accounting
        self.lanes = max(1, lanes)
        # tail folding: pad the final chunk to the full chunk width with
        # per-position validity masks instead of issuing up to chunk-1
        # single-token tail calls — ONE compiled shape, ceil(L/chunk)
        # device calls per admission (off = the two-shape chunk+tail path,
        # kept for A/B benchmarking)
        self.tail_fold = tail_fold
        # donate the lane carry through the jitted chunk step so chunk
        # calls update the carry buffers in place instead of materializing
        # a second copy per call (mirrors engine.py's grid-cache donation;
        # skipped on CPU, where XLA can't honor it and jit warns)
        self.donate = (jax.default_backend() != "cpu") if donate is None else donate
        # a chunk must map to distinct cache slots, so clamp it to the
        # narrowest ring the family keeps (hybrid SWA ring / sliding
        # window); full-context caches don't wrap during prefill
        ring = self._min_ring_width()
        self.chunk = max(1, min(chunk, ring if ring else chunk))
        self.prefix = api.prefill_prefix_len(cfg)
        if self.max_prompt_len() <= 0:
            raise ValueError(
                f"max_context={max_context} leaves no room for prompt "
                f"tokens after the {self.prefix}-position learned prefix"
            )
        self._axes = api.axes(cfg)
        self._carry_axes = api.chunk_carry_axes(cfg)
        from repro.launch.shardings import default_serve_rules
        self.mesh = mesh
        self.rules = default_serve_rules(mesh, rules)
        with mesh_context(self.mesh, self.rules):
            self._carry = api.init_chunk_carry(cfg, self.lanes, 1, max_context)
        if mesh is not None:
            from repro.launch.shardings import tree_shardings
            self._carry = jax.device_put(
                self._carry,
                tree_shardings(self.rules, self._carry_axes, self._carry),
            )
        # pristine carry for zero-work completions (single-token prompts
        # of prefix-less families scatter fresh init state, no device
        # call).  A deep copy, NOT an alias: the chunk step donates the
        # live carry, which would invalidate an aliased zero carry
        self._zero_carry = jax.tree.map(jnp.copy, self._carry)
        if mesh is not None:
            from repro.launch.shardings import tree_shardings
            self._zero_carry = jax.device_put(
                self._zero_carry,
                tree_shardings(self.rules, self._carry_axes, self._zero_carry),
            )
        self._lanes = [_Lane() for _ in range(self.lanes)]
        self._fns: dict[int, Any] = {}      # chunk width -> jitted step
        self._static = self._static_inputs()
        self._tail_turn = False             # chunk/tail round alternation
        self.device_calls = 0               # total chunk/tail device calls
        self.admitted = 0                   # lanes ever started

    # -- geometry ------------------------------------------------------------

    def _min_ring_width(self) -> int:
        cfg = self.cfg
        if cfg.family == "hybrid":
            from repro.models import hybrid as H
            # the ACTUAL ring width of the SWA group cache: make_cache
            # clips the cache to max_context, so a context below
            # meta+window leaves a narrower ring than the window itself
            s_cache = min(H.NUM_META_TOKENS + H.swa_window(cfg), self.max_context)
            return max(s_cache - H.NUM_META_TOKENS, 1)
        if cfg.family in ("dense", "moe", "vlm") and cfg.sliding_window:
            return cfg.sliding_window
        return 0

    def max_prompt_len(self) -> int:
        """Longest admissible prompt: every position (learned prefix +
        prompt tokens) must fit the serving context."""
        return self.max_context - self.prefix

    @property
    def compiled_shapes(self) -> int:
        """Distinct compiled prefill shapes — 1 with tail folding (the
        chunk), at most 2 without (chunk + single-token tail)."""
        return len(self._fns)

    # -- lane bookkeeping ----------------------------------------------------

    def free_lanes(self) -> int:
        return sum(1 for l in self._lanes if l.req is None)

    def in_flight(self) -> int:
        return sum(1 for l in self._lanes if l.req is not None)

    def start(self, req: Request) -> None:
        """Bind a request to a free lane (its chunks run on subsequent
        ``advance`` calls)."""
        for lane in self._lanes:
            if lane.req is None:
                lane.req = req
                lane.next_pos = 0
                lane.total = self.prefix + len(req.prompt) - 1
                lane.fresh = True
                self.admitted += 1
                return
        raise RuntimeError("no free prefill lane")

    def abort(self, request_id: int) -> bool:
        """Evict a mid-flight request from its lane (client cancel /
        disconnect / deadline expiry).  The lane is free for the very
        next ``start``; its carry rows are left as-is — binding a new
        request sets ``fresh``, which re-initializes the rows in-graph,
        so no device call and no extra compiled shape is spent on the
        eviction."""
        for lane in self._lanes:
            if lane.req is not None and lane.req.request_id == request_id:
                lane.req = None
                return True
        return False

    # -- static per-call inputs ----------------------------------------------

    def _static_inputs(self) -> dict:
        cfg, k = self.cfg, self.lanes
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "vlm":
            return {"image_embeds": jnp.zeros(
                (k, 1, cfg.num_image_patches, cfg.vision_embed_dim), dt)}
        if cfg.family == "audio":
            return {"frames": jnp.zeros(
                (k, 1, cfg.num_audio_frames, cfg.d_model), dt)}
        return {}

    def _fn(self, c: int):
        if c not in self._fns:
            cfg = self.cfg

            def fn(params, idx, tokens, carry, offset, valid, fresh, extras):
                sub = gather_instances(params, self._axes, idx)
                init = api.init_chunk_carry(cfg, self.lanes, 1, self.max_context)
                carry = C.tree_select_lanes(fresh, init, carry, self._carry_axes)
                batch = {"tokens": tokens, **self._static, **extras}
                new = api.prefill_chunk(cfg, sub, batch, carry, offset)
                new = C.tree_select_lanes(valid, new, carry, self._carry_axes)
                return constrain_tree(new, self._carry_axes)

            # donate the carry (arg 3): the chunk step then updates the
            # lane caches in place instead of allocating a full second
            # copy of the (lanes, 1, max_context) tree per call
            self._fns[c] = jax.jit(
                fn, donate_argnums=(3,) if self.donate else ()
            )
        return self._fns[c]

    # -- the chunk pump ------------------------------------------------------

    def advance(self, params, budget: int,
                step: int = 0) -> list[tuple[Request, PrefillOut]]:
        """Run up to ``budget`` chunk device calls; return the requests
        whose prefill completed (with their PrefillOut rows of the shared
        carry tree).  Under donation the returned rows alias the live
        carry, which the NEXT advance updates in place — consume (scatter)
        them before advancing again, as the engine does.  ``step`` tags
        trace events with the engine's step counter."""
        done: list[tuple[Request, PrefillOut]] = []
        # zero-work lanes (single-token prompts of prefix-less families)
        # complete immediately from the pristine init carry — their grid
        # slot needs fresh state, never a device call
        zero_done: list[tuple[Request, PrefillOut]] = []
        for i, lane in enumerate(self._lanes):
            if lane.req is not None and lane.total == 0:
                zero_done.append((lane.req, PrefillOut(
                    cache=self._zero_carry["cache"], index=i, pos=0,
                    last_token=lane.req.prompt[-1],
                )))
                lane.req = None
        stepped = False
        t0 = time.perf_counter()
        with mesh_context(self.mesh, self.rules):
            while budget > 0:
                busy = [i for i, l in enumerate(self._lanes) if l.req is not None]
                if not busy:
                    break
                if self.tail_fold:
                    # folded: EVERY lane with work advances together; a
                    # lane with < chunk left rides a padded final chunk
                    # whose junk suffix is masked per position — one
                    # compiled shape, ceil(L_max/chunk) calls total
                    workable = [i for i in busy
                                if self._lanes[i].total > self._lanes[i].next_pos]
                    if not workable:
                        break
                    self._step(params, workable, self.chunk, fold=True,
                               step=step)
                else:
                    chunkable = [i for i in busy
                                 if self._lanes[i].total - self._lanes[i].next_pos >= self.chunk]
                    tailable = [i for i in busy
                                if 0 < self._lanes[i].total - self._lanes[i].next_pos < self.chunk]
                    if not chunkable and not tailable:
                        break
                    # alternate chunk and tail rounds when both kinds of
                    # work exist: under continuous long-prompt arrivals a
                    # lane one token from completion must not be starved
                    # behind lanes that always have a full chunk left
                    run_tail = bool(tailable) and (self._tail_turn or not chunkable)
                    self._tail_turn = not run_tail
                    workable = tailable if run_tail else chunkable
                    c = 1 if run_tail else self.chunk
                    self._step(params, workable, c, step=step)
                stepped = True
                budget -= 1
                for i in busy:
                    lane = self._lanes[i]
                    if lane.req is not None and lane.next_pos >= lane.total:
                        done.append((lane.req, PrefillOut(
                            cache=None, index=i, pos=lane.total,
                            last_token=lane.req.prompt[-1],
                        )))
                        lane.req = None
        if stepped:
            # settle the async dispatch so the engine's admission-stall
            # timer measures device execution, not just dispatch (the
            # scatter/decode it times against depend on this carry anyway)
            jax.block_until_ready(self._carry)
            if self.metrics is not None:
                self.metrics.note_prefill_wall(time.perf_counter() - t0)
        for _, out in done:
            out.cache = self._carry["cache"]
        return zero_done + done

    def _step(self, params, workable: list[int], c: int, fold: bool = False,
              step: int = 0) -> None:
        k = self.lanes
        toks = np.zeros((k, 1, c), np.int32)
        inst = np.zeros((k,), np.int32)
        offset = np.zeros((k, 1), np.int32)
        valid = np.zeros((k,), bool)
        fresh = np.zeros((k,), bool)
        pvalid = np.zeros((k, 1, c), bool)
        tokens_done = 0
        # lane bookkeeping is STAGED and committed only after the device
        # call returns: an exception mid-call leaves every lane exactly
        # as it was (exception-safe step; the engine fails or requeues
        # the requests, never resumes from half-advanced positions)
        staged: list[tuple[_Lane, int]] = []
        for i, lane in enumerate(self._lanes):
            if lane.req is None:
                continue
            inst[i] = lane.req.instance
            offset[i, 0] = lane.next_pos
            fresh[i] = lane.fresh
            if i in workable:
                valid[i] = True
                # folded final chunks advance only their real remainder;
                # the junk suffix (token 0) is masked per position
                adv = min(c, lane.total - lane.next_pos) if fold else c
                pvalid[i, 0, :adv] = True
                for j in range(adv):
                    p = lane.next_pos + j
                    if p >= self.prefix:
                        toks[i, 0, j] = lane.req.prompt[p - self.prefix]
                tokens_done += adv
                staged.append((lane, adv))
        extras = {}
        if fold:
            extras["valid"] = jnp.asarray(pvalid)
        if self.family == "moe":
            from repro.models import moe
            limit = np.zeros((k, 1), np.int32)
            for i, lane in enumerate(self._lanes):
                if lane.req is not None and lane.total > 0:
                    limit[i, 0] = moe.capacity(self.cfg, lane.total)
            extras["moe_limit"] = jnp.asarray(limit)
        tr = self.tracer
        trace_on = tr is not None and tr.enabled
        acct = self.accounting
        acct_on = acct is not None and acct.enabled
        obs_on = trace_on or acct_on
        if obs_on:
            t0 = time.perf_counter()
        self._carry = self._fn(c)(
            params, jnp.asarray(inst), jnp.asarray(toks), self._carry,
            jnp.asarray(offset), jnp.asarray(valid), jnp.asarray(fresh), extras,
        )
        self.device_calls += 1
        # the call landed: commit lane advances, and clear ``fresh`` on
        # every bound lane (the call re-initialized all fresh rows
        # in-graph, workable or not)
        for lane, adv in staged:
            lane.next_pos += adv
        for lane in self._lanes:
            if lane.req is not None:
                lane.fresh = False
        if obs_on:
            t_dispatch = time.perf_counter()
            # settling per chunk is a tracing/accounting-ON cost: it buys
            # the true per-call device time; the unobserved path keeps
            # its async dispatch (one settle per advance)
            jax.block_until_ready(self._carry)
            t_settled = time.perf_counter()
            if trace_on:
                tr.device_call(
                    "prefill_chunk", t0, t_dispatch, t_settled,
                    step=step, lanes_busy=self.in_flight(), lanes=self.lanes,
                    valid_frac=tokens_done / (len(workable) * c) if workable else 1.0,
                    tokens=tokens_done,
                )
            if acct_on:
                # lane-weighted attribution: each busy lane charges its
                # tenant wall/lanes; unoccupied lanes are shared idle
                acct.note_prefill(
                    t_settled - t0,
                    [int(inst[i]) for i in workable], self.lanes)
        if self.metrics is not None:
            self.metrics.note_prefill_batch(len(workable), tokens_done)

    def reset(self) -> None:
        """Crash recovery (DESIGN.md §6.8): evict every lane and rebuild
        the live carry from the pristine zero copy — a failed donated
        chunk call may have invalidated the carry buffers.  Compiled
        chunk programs and cumulative counters are kept."""
        for lane in self._lanes:
            lane.req = None
            lane.fresh = False
        carry = jax.tree.map(jnp.copy, self._zero_carry)
        if self.mesh is not None:
            from repro.launch.shardings import tree_shardings
            carry = jax.device_put(
                carry, tree_shardings(self.rules, self._carry_axes, carry))
        self._carry = carry
        self._tail_turn = False

    # -- convenience (tests / non-interleaved callers) -----------------------

    def run(self, params, reqs) -> list[PrefillOut]:
        """Prefill the given requests to completion (no interleaving);
        one PrefillOut per request, in submission order.  Requests are
        fed through the lanes in waves of ``self.lanes``.

        Under donation a returned carry is only valid until the next
        ``advance`` (which updates it in place) — the engine scatters
        each wave immediately; here later waves would invalidate earlier
        rows, so donated multi-wave runs snapshot each wave's cache."""
        outs: dict[int, PrefillOut] = {}
        pending = list(enumerate(reqs))
        started: dict[int, int] = {}      # id(req) -> original index
        while pending or self.in_flight():
            while pending and self.free_lanes():
                i, r = pending.pop(0)
                started[id(r)] = i
                self.start(r)
            wave = self.advance(params, budget=1_000_000)
            if self.donate and (pending or self.in_flight()):
                snap = None
                for _, out in wave:
                    if out.cache is self._carry["cache"]:
                        if snap is None:
                            snap = jax.tree.map(jnp.copy, out.cache)
                        out.cache = snap
            for req, out in wave:
                outs[started[id(req)]] = out
        return [outs[i] for i in range(len(reqs))]
