"""Fault-tolerant serving core (DESIGN.md §6.8): deterministic fault
injection, supervised driver recovery, per-instance health/quarantine,
and overload brownout."""
from repro.serving.resilience.faults import (
    FaultInjected,
    FaultInjector,
    FaultSpec,
)
from repro.serving.resilience.health import HealthMonitor
from repro.serving.resilience.policy import BrownoutPolicy
from repro.serving.resilience.supervisor import Supervisor, WatchdogTimeout

__all__ = [
    "BrownoutPolicy",
    "FaultInjected",
    "FaultInjector",
    "FaultSpec",
    "HealthMonitor",
    "Supervisor",
    "WatchdogTimeout",
]
