"""Deterministic fault injection for the serving stack (DESIGN.md §6.8).

A ``FaultInjector`` holds a declarative *fault plan*: a list of
``FaultSpec`` entries, each naming a **site** (which call-counter it
watches), a **kind** (what happens when it fires), and a trigger
(``at_call`` / ``every`` / ``prob``).  The engine and driver consult the
injector at well-defined points; every consultation advances that
site's call counter, so with a fixed plan + seed the fault schedule is
a pure function of the call sequence — same seed ⇒ same faults ⇒ same
recovered streams, which is what makes the chaos suite deterministic.

Sites (what the counter counts):

- ``decode``     one fused decode+sample dispatch (``MultiModelServer.step``)
- ``prefill``    one chunked-prefill ``advance`` pass
- ``scatter``    one slot-surgery scatter of a finished prefill
- ``driver``     one AsyncEngine driver-loop iteration
- ``checkpoint`` one checkpoint ``restore`` read

Kinds:

- ``raise``  raise ``FaultInjected`` at the site (before the device
  call dispatches, so host/device state is never half-mutated)
- ``nan``    poison the logits' finite-mask for ``instance`` on this
  decode call — the host-side NaN/Inf guard then sees the row exactly
  as it would see real non-finite logits.  (Injecting real NaN into the
  cache would *persist* — 0·NaN=NaN survives masked attention — and
  poison every later step, so the injection flips the guard instead;
  the guard itself is computed on device from the real logits.)
- ``stall``  sleep ``stall_s`` seconds at the site (models a hung
  device call; the watchdog should fire)

The injector is **disarmed by default and zero-cost when disarmed**:
every call site is guarded by ``if injector.armed:`` so no injector
code runs at all (proven by the bombed-methods test, same discipline as
the PR-6 tracer).
"""
from __future__ import annotations

import dataclasses
import json
import random
import time

SITES = ("decode", "prefill", "scatter", "driver", "checkpoint")
KINDS = ("raise", "nan", "stall")


class FaultInjected(RuntimeError):
    """Raised by a firing ``raise``-kind fault."""

    def __init__(self, message: str, *, site: str = "", call: int = 0):
        super().__init__(message)
        self.site = site
        self.call = call


@dataclasses.dataclass
class FaultSpec:
    """One declarative fault.

    Exactly one trigger should be set: ``at_call`` (fire on the Nth
    call at the site, 1-based), ``every`` (fire on every Nth call), or
    ``prob`` (seeded Bernoulli per call).  ``times`` bounds total
    fires (default 1; ``None`` = unlimited).
    """

    site: str
    kind: str = "raise"
    at_call: int | None = None
    every: int | None = None
    prob: float | None = None
    instance: int = 0          # nan: which instance row to poison
    stall_s: float = 0.0       # stall: how long to sleep
    times: int | None = 1
    fired: int = 0             # runtime: how often this spec has fired

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.at_call is None and not self.every and not self.prob:
            raise ValueError(f"fault {self.site}/{self.kind} needs a "
                             f"trigger: at_call, every, or prob")


class FaultInjector:
    """Seedable, deterministic fault injector.

    Construct with a plan (list of ``FaultSpec`` / dicts) and call
    ``arm()``; the engine's ``if faults.armed:`` guards then route each
    site through ``on_call``.  ``fired`` records ``(site, call_index,
    kind)`` tuples in firing order — the schedule fingerprint the
    determinism tests compare.
    """

    def __init__(self, plan=(), *, seed: int = 0):
        self.plan: list[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in plan
        ]
        self.seed = seed
        self.armed = False
        self.calls: dict[str, int] = {}
        self.fired: list[tuple[str, int, str]] = []
        self._rng = random.Random(seed)

    # -- construction -------------------------------------------------
    @classmethod
    def from_plan(cls, plan: dict) -> "FaultInjector":
        """Build from the JSON plan schema:
        ``{"seed": 0, "faults": [{"site": ..., "kind": ..., ...}, ...]}``.
        """
        return cls(plan.get("faults", ()), seed=int(plan.get("seed", 0)))

    @classmethod
    def from_json(cls, text_or_path: str) -> "FaultInjector":
        """Accept a path to a plan file or an inline JSON literal."""
        text = text_or_path
        if not text.lstrip().startswith("{"):
            with open(text_or_path) as f:
                text = f.read()
        return cls.from_plan(json.loads(text))

    # -- lifecycle ----------------------------------------------------
    def arm(self) -> "FaultInjector":
        self.armed = True
        return self

    def disarm(self) -> None:
        self.armed = False

    def reset(self) -> None:
        """Rewind counters, spec fire-counts and the RNG to t=0 (the
        schedule replays identically)."""
        self.calls.clear()
        del self.fired[:]
        self._rng = random.Random(self.seed)
        for s in self.plan:
            s.fired = 0

    # -- the hot path (only ever reached when armed) ------------------
    def on_call(self, site: str) -> set[int]:
        """Count one call at ``site`` and apply matching faults.

        Returns the set of instance rows whose logits finite-mask
        should be poisoned for this call (empty normally; only ``nan``
        faults populate it).  ``raise`` faults raise ``FaultInjected``;
        ``stall`` faults sleep, then let the call proceed.
        """
        n = self.calls.get(site, 0) + 1
        self.calls[site] = n
        poison: set[int] = set()
        for spec in self.plan:
            if spec.site != site:
                continue
            if spec.times is not None and spec.fired >= spec.times:
                continue
            if spec.at_call is not None:
                hit = n == spec.at_call
            elif spec.every:
                hit = n % spec.every == 0
            else:
                hit = self._rng.random() < (spec.prob or 0.0)
            if not hit:
                continue
            spec.fired += 1
            self.fired.append((site, n, spec.kind))
            if spec.kind == "stall":
                time.sleep(spec.stall_s)
            elif spec.kind == "nan":
                poison.add(spec.instance)
            else:
                raise FaultInjected(
                    f"injected fault at {site} call {n}", site=site, call=n)
        return poison
