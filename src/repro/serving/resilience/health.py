"""Per-instance health states (DESIGN.md §6.8).

The fused grid's worst failure property is shared fate: one tenant's
poisoned weights would take down all M.  ``HealthMonitor`` contains the
blast radius to one grid *row*: each instance walks

    healthy → degraded → quarantined → probation → healthy

- **degraded**: ``degrade_after`` consecutive request failures.  Still
  admits; it is a warning state surfaced via /healthz.
- **quarantined**: a non-finite-logits (NaN/Inf) token — immediately —
  or ``quarantine_after`` consecutive failures.  The scheduler stops
  admitting to that row and ``try_submit`` answers ``model=i`` requests
  with a terminal ``unavailable`` Result (HTTP 503 + Retry-After); the
  other M−1 tenants are untouched.
- **probation**: after ``quarantine_steps`` engine steps the row may
  admit again, but one more failure re-quarantines with **doubled**
  duration (capped); one success restores healthy and resets the
  duration.

Durations are counted in *engine steps*, not wall time, so the
lifecycle is deterministic under test.
"""
from __future__ import annotations

import dataclasses

STATES = ("healthy", "degraded", "quarantined", "probation")


@dataclasses.dataclass
class _InstanceHealth:
    state: str = "healthy"
    consecutive_failures: int = 0
    failures: int = 0              # lifetime failed requests
    poisoned: int = 0              # lifetime NaN/Inf guard trips
    quarantine_left: int = 0       # steps until probation
    quarantine_len: int = 0        # current duration (doubles on re-trip)
    quarantines: int = 0           # lifetime quarantine entries


class HealthMonitor:
    def __init__(self, num_instances: int, *, degrade_after: int = 1,
                 quarantine_after: int = 3, quarantine_steps: int = 64,
                 max_quarantine_steps: int = 4096):
        self.degrade_after = degrade_after
        self.quarantine_after = quarantine_after
        self.quarantine_steps = quarantine_steps
        self.max_quarantine_steps = max_quarantine_steps
        self._inst = [_InstanceHealth() for _ in range(num_instances)]
        self.quarantine_events = 0
        # incident hook: called with the instance index on every FRESH
        # quarantine transition (not on extensions of an existing one).
        # The engine wires the flight recorder here (§6.9); None = no-op
        self.on_quarantine = None

    # -- queries ------------------------------------------------------
    def state(self, i: int) -> str:
        return self._inst[i].state

    def states(self) -> list[str]:
        return [st.state for st in self._inst]

    def admissible(self, i: int) -> bool:
        """May the scheduler admit (and the engine accept) requests for
        instance ``i``?"""
        return self._inst[i].state != "quarantined"

    def quarantined_now(self) -> int:
        return sum(1 for st in self._inst if st.state == "quarantined")

    # -- signals from the engine --------------------------------------
    def note_poisoned(self, i: int) -> None:
        """Instance ``i`` produced non-finite logits: quarantine now."""
        st = self._inst[i]
        st.poisoned += 1
        self._quarantine(st, i)

    def note_failure(self, i: int) -> None:
        """A request on instance ``i`` failed terminally."""
        st = self._inst[i]
        st.failures += 1
        st.consecutive_failures += 1
        if st.state == "probation":
            self._quarantine(st, i)
        elif st.consecutive_failures >= self.quarantine_after:
            self._quarantine(st, i)
        elif (st.state == "healthy"
              and st.consecutive_failures >= self.degrade_after):
            st.state = "degraded"

    def note_success(self, i: int) -> None:
        """A request on instance ``i`` completed normally."""
        st = self._inst[i]
        st.consecutive_failures = 0
        if st.state == "probation":
            st.state = "healthy"
            st.quarantine_len = 0      # full recovery resets the doubling
        elif st.state == "degraded":
            st.state = "healthy"

    def note_step(self) -> None:
        """One engine step elapsed: age quarantines toward probation."""
        for st in self._inst:
            if st.state == "quarantined":
                st.quarantine_left -= 1
                if st.quarantine_left <= 0:
                    st.state = "probation"

    def _quarantine(self, st: _InstanceHealth, i: int) -> None:
        st.consecutive_failures = 0
        st.quarantine_len = (
            self.quarantine_steps if st.quarantine_len == 0
            else min(st.quarantine_len * 2, self.max_quarantine_steps))
        st.quarantine_left = st.quarantine_len
        fresh = st.state != "quarantined"
        if fresh:
            st.quarantines += 1
            self.quarantine_events += 1
        st.state = "quarantined"
        if fresh and self.on_quarantine is not None:
            self.on_quarantine(i)

    # -- export -------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "states": self.states(),
            "quarantined_now": self.quarantined_now(),
            "quarantine_events": self.quarantine_events,
            "poisoned_tokens": sum(st.poisoned for st in self._inst),
            "failures": sum(st.failures for st in self._inst),
            "per_instance": [
                {
                    "state": st.state,
                    "consecutive_failures": st.consecutive_failures,
                    "failures": st.failures,
                    "poisoned": st.poisoned,
                    "quarantines": st.quarantines,
                    "quarantine_left": st.quarantine_left,
                }
                for st in self._inst
            ],
        }
