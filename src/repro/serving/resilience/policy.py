"""Overload brownout policy (DESIGN.md §6.8).

Under sustained backpressure a server has three moves better than
hard-429ing everything: bound how often the supervisor retries a
request across crashes (poison-pill defense), shed the *oldest* queued
requests (whose clients have likely given up) with a ``Retry-After``,
and brown out — keep admitting but cap ``max_new_tokens`` so everyone
gets a shorter answer instead of some getting none.

The policy is plain host-side bookkeeping consulted by the engine once
per step (``note_depth`` + age shedding) and once per submit
(``cap_request``); it never touches device state.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BrownoutPolicy:
    # retry budget: how often the Supervisor may requeue one request
    # across driver restarts before failing it terminally
    max_retries: int = 3
    # advisory client backoff for 429/503 responses, seconds
    retry_after_s: float = 1.0
    # shed queued requests older than this (None = never shed)
    shed_age_s: float | None = None
    # degraded mode: engaged after `degrade_steps` consecutive engine
    # steps with total queue depth >= `degrade_depth` (0 = disabled);
    # while engaged, submissions are capped to `degraded_max_new`
    degrade_depth: int = 0
    degrade_steps: int = 3
    degraded_max_new: int = 4

    # runtime state
    degraded: bool = False
    shed_total: int = 0
    capped_total: int = 0
    _over: int = 0

    def note_depth(self, total_pending: int) -> None:
        """One engine step's total queue depth: drive degraded mode."""
        if self.degrade_depth and total_pending >= self.degrade_depth:
            self._over += 1
            if self._over >= self.degrade_steps:
                self.degraded = True
        else:
            self._over = 0
            self.degraded = False

    def cap_request(self, req) -> bool:
        """In degraded mode, cap a submission's ``max_new_tokens``.
        Returns True if the request was capped."""
        if (self.degraded and self.degraded_max_new
                and req.max_new_tokens > self.degraded_max_new):
            req.max_new_tokens = self.degraded_max_new
            self.capped_total += 1
            return True
        return False

    def snapshot(self) -> dict:
        return {
            "degraded": self.degraded,
            "shed_total": self.shed_total,
            "capped_total": self.capped_total,
            "max_retries": self.max_retries,
            "retry_after_s": self.retry_after_s,
        }
