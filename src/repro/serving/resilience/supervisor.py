"""Supervisor — crash/stall recovery for the AsyncEngine driver.

The fused serving engine (DESIGN.md §6) runs ONE device call per step;
the async frontend owns that loop on a single driver task.  A fault
anywhere in the step — a poisoned device call, a wedged collective, an
injected crash from :mod:`repro.serving.resilience.faults` — kills the
driver, and without supervision every live stream dies with it.  The
Supervisor turns driver death into a bounded, client-invisible blip:

* **watchdog** — every device step is stamped with its dispatch time
  (``engine._step_started``); a step that overruns ``watchdog_s`` is
  declared stalled, the driver is cancelled, and recovery proceeds as
  for a crash (with ``server_factory`` the wedged server is abandoned
  wholesale — an executor thread cannot be killed, only orphaned).
* **restart with backoff** — bounded restarts (``max_restarts``), each
  delayed by seeded-jitter exponential backoff so a crash loop cannot
  spin the host.
* **replay-based state reconstruction** — the frontend's records
  (``engine._requests`` + each stream's ``emitted`` prefix) survive the
  crash; recovery resets the serving state to empty and requeues every
  live request under its ORIGINAL id with ``emit_skip`` set to the
  already-delivered prefix length.  Greedy decode regenerates that
  prefix bit-identically (a greedy stream depends only on its own
  prompt — DESIGN.md §6.8 has the exactly-once argument), the engine
  suppresses its re-emission, and the client-visible stream resumes
  exactly where it broke: no token duplicated, none lost.
* **give-up** — past the restart budget every live stream ends with a
  terminal ``status="error"`` Result carrying its partial tokens, and
  pending submitters get :class:`EngineClosed` — nobody hangs.

Single-writer discipline is preserved: the Supervisor only touches
engine state while NO driver task is alive (it restarts the driver
last), so driver and Supervisor never mutate concurrently.
"""
from __future__ import annotations

import asyncio
import random

from repro.serving.scheduler import Result


class WatchdogTimeout(RuntimeError):
    """A device step overran the watchdog deadline (injected stall or a
    genuinely wedged device call)."""

    def __init__(self, elapsed_s: float, deadline_s: float):
        super().__init__(
            f"device step ran {elapsed_s:.3f}s against a "
            f"{deadline_s:.3f}s watchdog deadline"
        )
        self.elapsed_s = elapsed_s
        self.deadline_s = deadline_s


class Supervisor:
    """Owns an :class:`~repro.serving.frontend.async_engine.AsyncEngine`
    driver's lifecycle: watchdog, crash detection, backoff restart, and
    replay-based request recovery (module docstring has the model).

    Parameters
    ----------
    engine:         the AsyncEngine to supervise (marked ``supervised``
                    immediately: its driver stops self-terminating on
                    failure and leaves state intact for recovery).
    watchdog_s:     per-device-step deadline; ``None`` disables stall
                    detection (crashes are still recovered).
    max_restarts:   restart budget before giving up.
    backoff_base_s / backoff_cap_s: exponential backoff envelope; the
                    actual delay is ``min(cap, base·2^k)·(0.5+U[0,1))``
                    with a ``seed``-ed RNG, so tests are reproducible.
    max_retries:    per-request requeue budget; ``None`` defers to the
                    engine's BrownoutPolicy (default 3).
    server_factory: zero-arg callable building a replacement
                    ``MultiModelServer`` (same config/params).  Only
                    used for STALL recovery: a wedged executor thread
                    cannot be killed, so the old server is abandoned to
                    it and serving resumes on a fresh one.  Without a
                    factory, stall recovery waits the stalled step out
                    before resetting state on the same server.
    """

    def __init__(self, engine, *, watchdog_s: float | None = None,
                 max_restarts: int = 5, backoff_base_s: float = 0.02,
                 backoff_cap_s: float = 1.0, seed: int = 0,
                 max_retries: int | None = None, server_factory=None):
        self._engine = engine
        self.watchdog_s = watchdog_s
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.max_retries = max_retries
        self.server_factory = server_factory
        self._rng = random.Random(seed)
        # counters surfaced through metrics.snapshot()["resilience"] and
        # the Prometheus exposition
        self.restarts = 0
        self.request_retries = 0
        self.watchdog_timeouts = 0
        self.tokens_replayed = 0
        self.retry_budget_exhausted = 0
        self.last_recovery_s: float | None = None
        self.recoveries: list[dict] = []
        # set()s when the step loop is truly over (clean drain or
        # give-up); None until start() — drain()/aclose() key off it
        self.stopped: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        engine.supervised = True
        engine._supervisor = self
        engine.server.metrics.resilience_fn = self.snapshot

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Start the driver (if needed) and the watch loop.  Must run
        inside the event loop (any client coroutine qualifies)."""
        if self._task is not None and not self._task.done():
            return
        self._engine._ensure_started()
        self.stopped = asyncio.Event()
        self._task = self._engine._loop.create_task(
            self._watch(), name="engine-supervisor")

    async def __aenter__(self) -> "Supervisor":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self._engine.aclose(drain=exc == (None, None, None))

    def snapshot(self) -> dict:
        """Resilience counters (the metrics extension hook)."""
        return {
            "driver_restarts": self.restarts,
            "request_retries": self.request_retries,
            "watchdog_timeouts": self.watchdog_timeouts,
            "tokens_replayed": self.tokens_replayed,
            "retry_budget_exhausted": self.retry_budget_exhausted,
            "last_recovery_s": self.last_recovery_s,
            "recoveries": [dict(r) for r in self.recoveries],
        }

    # -- watch loop ----------------------------------------------------------

    async def _watch(self) -> None:
        eng = self._engine
        loop = eng._loop
        poll = (self.watchdog_s / 4) if self.watchdog_s else 0.05
        while True:
            driver = eng._driver
            try:
                # shield: a poll timeout must not cancel the driver
                await asyncio.wait_for(asyncio.shield(driver), timeout=poll)
            except asyncio.TimeoutError:
                started = eng._step_started
                if (self.watchdog_s is not None and started is not None
                        and loop.time() - started > self.watchdog_s):
                    if not await self._recover_from_stall(
                            loop.time() - started):
                        return
                continue
            except asyncio.CancelledError:
                raise
            except BaseException as e:
                reason = f"crash: {type(e).__name__}: {e}"
                if not await self._recover(reason):
                    return
                continue
            # clean exit: drain()/aclose() finished every in-flight
            # request before the driver returned
            self._shutdown()
            return

    # -- recovery ------------------------------------------------------------

    async def _recover_from_stall(self, elapsed_s: float) -> bool:
        """Watchdog path: cancel the (live but blocked) driver, then
        either abandon the wedged server (``server_factory``) or wait
        the stalled step out, and recover as for a crash."""
        eng = self._engine
        self.watchdog_timeouts += 1
        timeout = WatchdogTimeout(elapsed_s, self.watchdog_s)
        driver = eng._driver
        driver.cancel()
        try:
            await driver
        except BaseException:
            pass
        # flight-record the WEDGED server now — the factory path below
        # swaps it out before _recover runs (metrics/trace reads are
        # host-side, safe even with the stalled step still in flight)
        flight = getattr(eng.server, "flight", None)
        if flight is not None and flight.enabled:
            flight.dump(f"watchdog: {timeout}", server=eng.server,
                        extra={"restarts": self.restarts,
                               "in_flight": len(eng._streams)})
        if self.server_factory is not None:
            # hard restart: the stalled executor thread keeps the old
            # server; detach its token hook FIRST so late emissions
            # from the orphaned step can't leak into the new buffer
            old = eng.server
            old.on_token = None
            new = self.server_factory()
            # request ids must stay unique across the swap: requeued
            # requests keep their original ids, new submissions must
            # not collide with them
            new._req_counter = max(new._req_counter, old._req_counter)
            new.on_token = eng._hook
            new.metrics.resilience_fn = self.snapshot
            # observability continuity (§6.9): the replacement server
            # keeps the old ledger, flight recorder, and SLO config so
            # tenant accounts and error budgets span the swap
            new.accounting = old.accounting
            new.accounting.queued_fn = new.scheduler.queued_instances
            new.prefill.accounting = old.accounting
            new.metrics.accounting_fn = old.accounting.snapshot
            new.flight = old.flight
            new.metrics.slo = old.metrics.slo
            eng.server = new
            return await self._recover(f"watchdog: {timeout}",
                                       reset_state=False,
                                       flight_dumped=True)
        # soft path: an executor thread cannot be killed — wait the
        # stalled step out, then reset state on the same server
        fut = eng._step_future
        if fut is not None:
            try:
                await asyncio.shield(fut)
            except BaseException:
                pass
        return await self._recover(f"watchdog: {timeout}",
                                   flight_dumped=True)

    async def _recover(self, reason: str, *, reset_state: bool = True,
                       flight_dumped: bool = False) -> bool:
        """Backoff, reset the serving state, requeue every live request
        with its delivered prefix, and restart the driver.  Returns
        False when the restart budget is exhausted (watch loop exits)."""
        eng = self._engine
        loop = eng._loop
        # flight recorder (§6.9): freeze the pre-reset state — trace
        # tail, metrics/SLO snapshot, queue depths — while the incident
        # is still visible (watchdog paths dumped the wedged server
        # already and say so via ``flight_dumped``)
        flight = getattr(eng.server, "flight", None)
        if not flight_dumped and flight is not None and flight.enabled:
            flight.dump(reason, server=eng.server,
                        extra={"restarts": self.restarts,
                               "in_flight": len(eng._streams)})
        if self.restarts >= self.max_restarts:
            await self._give_up(reason)
            return False
        self.restarts += 1
        delay = min(self.backoff_cap_s,
                    self.backoff_base_s * 2 ** (self.restarts - 1))
        await asyncio.sleep(delay * (0.5 + self._rng.random()))
        t0 = loop.time()
        if reset_state:
            # the frontend's records are the recovery truth; the
            # engine-side live list only feeds the trace/debug log
            eng.server.reset_serving_state()
        del eng._tok_buf[:]
        eng._step_future = None
        budget = self.max_retries
        if budget is None:
            pol = eng.server.policy
            budget = pol.max_retries if pol is not None else 3
        requeued = failed = 0
        for rid in sorted(eng._streams):
            req = eng._requests.get(rid)
            stream = eng._streams[rid]
            if req is None:        # defensive: no record, fail terminally
                eng._finish(Result(
                    rid, stream.instance, list(stream.emitted),
                    status="error",
                    error=f"no request record for recovery ({reason})",
                ))
                failed += 1
                continue
            req.retries += 1
            if req.retries > budget:
                self.retry_budget_exhausted += 1
                eng._finish(Result(
                    rid, stream.instance, list(stream.emitted),
                    prompt_len=len(req.prompt), status="error",
                    error=f"retry budget exhausted after {budget} "
                          f"restarts ({reason})",
                ))
                failed += 1
                continue
            self.request_retries += 1
            self.tokens_replayed += len(stream.emitted)
            eng.server.requeue(req, emitted=list(stream.emitted))
            requeued += 1
        if eng.server.tracer.enabled:
            eng.server.tracer.request_event(-1, "restart", status=reason)
        eng._restart_driver()
        dt = loop.time() - t0
        self.last_recovery_s = dt
        self.recoveries.append({
            "reason": reason, "restart": self.restarts,
            "requeued": requeued, "failed": failed,
            "time_to_recover_s": dt,
        })
        await eng._notify_space()
        return True

    async def _give_up(self, reason: str) -> None:
        """Restart budget exhausted: terminal-fail every live stream
        (keeping its delivered tokens), fail pending submitters, close
        the engine.  Nobody hangs; nobody silently loses tokens."""
        eng = self._engine
        flight = getattr(eng.server, "flight", None)
        if flight is not None and flight.enabled:
            flight.dump(f"give-up: {reason}", server=eng.server,
                        extra={"restarts": self.restarts,
                               "in_flight": len(eng._streams)})
        err = (f"engine driver failed permanently after "
               f"{self.restarts} restarts: {reason}")
        eng._fail_pending_commands(err)
        for rid in sorted(eng._streams):
            stream = eng._streams[rid]
            req = eng._requests.get(rid)
            eng._finish(Result(
                rid, stream.instance, list(stream.emitted),
                prompt_len=len(req.prompt) if req is not None else 0,
                status="error", error=err,
            ))
        eng._closing = True
        if eng.server.on_token is eng._hook:
            eng.server.on_token = None
        self.stopped.set()
        await eng._notify_space()

    def _shutdown(self) -> None:
        """Clean driver exit (drain/aclose done): release waiters."""
        eng = self._engine
        if eng.server.on_token is eng._hook:
            eng.server.on_token = None
        self.stopped.set()
