"""Fully on-device sampling over the whole (M, B) serving grid.

The old engine fetched the (M, B, V) logits to the host every decode
step and ran per-slot ``np.argmax`` / ``jax.random.categorical`` — one
host round-trip plus M*B tiny device calls per generated token.  Here
the whole grid is sampled in ONE fused op that lives inside the same
jitted program as the decode step (the engine's multi-step block,
DESIGN.md §6.6), so a serving step is exactly one device call
regardless of M and B.  Inside the block's ``lax.scan`` the sampler
runs once per scan step with a fresh ``jax.random.split`` of the
carried key — one split per decoded step, exactly the split sequence
the historical one-call-per-token protocol produced, so K=1 streams
are bit-identical to it (greedy streams are key-independent and
bit-identical across ALL K).

Greedy (temperature <= 0), temperature and top-k sampling; every slot
draws from an independent stream derived from one key (fold over the
flat slot index), so results do not depend on which slots are busy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample_tokens(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    """Sample next tokens for every slot. logits (M, B, V) -> (M, B) int32.

    temperature <= 0 is greedy argmax (top_k ignored); otherwise logits
    are scaled by 1/temperature, optionally truncated to the top_k
    largest per slot, and sampled categorically."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    m, b, v = logits.shape
    scaled = logits.astype(jnp.float32) / temperature
    if top_k > 0 and top_k < v:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, NEG_INF, scaled)
    # per-slot independent streams from one key: fold in the slot index
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(m * b, dtype=jnp.uint32)
    )
    flat = jax.vmap(jax.random.categorical)(keys, scaled.reshape(m * b, v))
    return flat.reshape(m, b).astype(jnp.int32)


def make_grid_sampler(temperature: float, top_k: int = 0):
    """Closure over static sampling params (jit-stable)."""
    return functools.partial(sample_tokens, temperature=temperature, top_k=top_k)
