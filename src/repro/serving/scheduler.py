"""Policy-driven admission scheduling for the multi-model server.

The paper's deployment scenario (§2.1) has one request stream per merged
instance; the serving engine exposes an (M, B) slot grid and asks the
scheduler, once per engine step, which pending requests to admit into
the free slots.  Three policies:

* ``fifo`` — strict global arrival order (head-of-line requests whose
  instance row is full are skipped over, not blocking other instances),
* ``round-robin`` — cycle instances, taking one request per instance per
  pass; equal *slot* share regardless of arrival pattern,
* ``token-budget`` — least-total-tokens-served instance first (deficit
  style fairness): instances that got fewer prompt+decode tokens win
  ties for free slots, so one chatty task can't starve the others.

Policies are pure host-side bookkeeping — no device work — so swapping
them never changes compiled programs.

Mesh-aware admission: under a mesh the grid's instance rows shard over
the data axes in contiguous blocks, so each instance lives on ONE
data-parallel device group.  Schedulers accept ``mesh=`` and expose
``data_shard_of(instance)``; ``token-budget`` uses it to break served-
token ties toward the least-loaded device group, spreading decode work
across the data axis.  Without a mesh every instance maps to shard 0
and behavior is exactly the single-device policy.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import deque
from typing import Iterable, Mapping

from repro.models.common import Rules


@dataclasses.dataclass
class Request:
    instance: int                  # which fine-tuned model (task) this targets
    prompt: list[int]
    max_new_tokens: int = 16
    request_id: int = -1
    submit_time: float = 0.0       # host clock at submit (metrics)
    _seq: int = -1                 # global arrival index (scheduler-owned)
    # crash recovery (DESIGN.md §6.8): on requeue after a driver crash,
    # the first ``emit_skip`` regenerated tokens were already delivered
    # to the client — the engine replays them with emission suppressed
    # (``replay_expect`` holds the delivered prefix for the mismatch
    # counter); ``retries`` is the supervisor's per-request restart
    # count against the retry budget
    emit_skip: int = 0
    replay_expect: list[int] | None = None
    retries: int = 0


@dataclasses.dataclass
class Result:
    request_id: int
    instance: int
    tokens: list[int]              # generated tokens (excluding prompt)
    prompt_len: int = 0
    latency_s: float = 0.0
    # terminal state: every request the engine ever accepted (and, via
    # ``try_submit``, every request it rejected) ends in exactly one
    # Result — the async frontend's stream fan-out keys off this.
    # "error" = device-call/driver failure, "unavailable" = instance
    # quarantined (HTTP 503), "shed" = dropped by overload brownout
    status: str = "ok"   # ok | rejected | cancelled | expired | error
    #                    # | unavailable | shed
    error: str | None = None       # human-readable reason for non-ok
    # why an ok decode stopped: "stop" (EOS) or "length" (max_new_tokens
    # / context cap) — OpenAI vocabulary, surfaced by the HTTP layer
    finish_reason: str | None = None


class Scheduler:
    """Base: per-instance FIFO queues + an admission policy in select()."""

    name = "base"

    def __init__(self, num_instances: int, mesh=None, rules=None):
        self.m = num_instances
        self.queues: list[deque[Request]] = [deque() for _ in range(num_instances)]
        self._arrival = itertools.count()
        self.mesh = mesh
        # instances shard contiguously over the mesh axes the rules
        # actually give the "instances" logical dim (Rules.spec applies
        # the suffix-drop/dedup guards, so the shard map matches the
        # grid's real placement — e.g. M=2 on ("pod","data")=(2,4)
        # shards 2-way over "pod"); without explicit rules, fall back to
        # the serve-rules batch axes
        ndata = 1
        if mesh is not None:
            if rules is None:
                axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
                entry = Rules(mesh, {"instances": axes}).spec(
                    ("instances",), (num_instances,))[0]
            else:
                entry = rules.spec(("instances",), (num_instances,))[0]
            if entry is not None:
                flat = (entry,) if isinstance(entry, str) else tuple(entry)
                ndata = math.prod(mesh.shape[a] for a in flat)
        if ndata > 1:
            per = num_instances // ndata
            self._shard_of = [i // per for i in range(num_instances)]
        else:
            self._shard_of = [0] * num_instances
        self.num_data_shards = max(ndata, 1)

    def data_shard_of(self, instance: int) -> int:
        """Which data-parallel device group serves this instance's row."""
        return self._shard_of[instance]

    # -- queue side ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if not 0 <= req.instance < self.m:
            raise ValueError(f"instance {req.instance} out of range [0, {self.m})")
        req._seq = next(self._arrival)
        self.queues[req.instance].append(req)

    def depth(self, instance: int) -> int:
        return len(self.queues[instance])

    def depths(self) -> list[int]:
        """Per-instance queue depths (one read for /healthz and trace
        events, instead of m depth() calls)."""
        return [len(q) for q in self.queues]

    def queued_instances(self) -> list[int]:
        """Instances with at least one queued request — the waiters the
        accounting layer's head-of-line interference report attributes
        each settled device call against (§6.9)."""
        return [m for m, q in enumerate(self.queues) if q]

    def total_pending(self) -> int:
        return sum(len(q) for q in self.queues)

    def cancel(self, request_id: int) -> Request | None:
        """Remove a still-queued request; return it, or None if it is not
        queued here (already admitted, finished, or unknown).  Pure queue
        surgery — policy state is untouched, which is exact for every
        policy: fifo/round-robin keep no per-request state and
        token-budget charges prompts at admission (select), so a request
        cancelled before admission was never charged."""
        for q in self.queues:
            for req in q:
                if req.request_id == request_id:
                    q.remove(req)
                    return req
        return None

    def drain_all(self) -> list[Request]:
        """Pop every queued request (crash recovery: the supervisor
        requeues them in arrival order).  Policy state is untouched —
        exact for every policy, same argument as ``cancel``."""
        out: list[Request] = []
        for q in self.queues:
            out.extend(q)
            q.clear()
        out.sort(key=lambda r: r._seq)
        return out

    def shed_older_than(self, cutoff: float) -> list[Request]:
        """Pop every queued request submitted before ``cutoff`` (overload
        brownout: shed by age).  Returns them oldest-first."""
        out: list[Request] = []
        for q in self.queues:
            keep = [r for r in q if r.submit_time >= cutoff]
            if len(keep) != len(q):
                out.extend(r for r in q if r.submit_time < cutoff)
                q.clear()
                q.extend(keep)
        out.sort(key=lambda r: r._seq)
        return out

    # -- accounting hook (token-budget fairness) ----------------------------
    # The engine reports each generated token; prompt tokens are charged by
    # the policy itself at admission (inside select).

    def note_generated(self, instance: int, n: int) -> None:
        pass

    # -- policy -------------------------------------------------------------

    def select(self, free: Mapping[int, int],
               limit: int | None = None) -> list[Request]:
        """Pop and return the requests to admit this round.

        ``free`` maps instance -> number of free slots in its row.  The
        returned list is in admission order; never more than ``free[m]``
        requests per instance, and never more than ``limit`` requests in
        total (the engine passes its count of free prefill lanes, so
        admission can't outrun the chunked-prefill runtime)."""
        raise NotImplementedError


class FIFOScheduler(Scheduler):
    name = "fifo"

    def select(self, free: Mapping[int, int],
               limit: int | None = None) -> list[Request]:
        budget = dict(free)
        out = []
        # strict global arrival order: repeatedly admit the OLDEST head
        # whose instance still has slot budget — under a scarce lane
        # limit this can't let a younger request on one instance jump an
        # older head queued on another
        while limit is None or len(out) < limit:
            heads = [
                q[0] for q in self.queues
                if q and budget.get(q[0].instance, 0) > 0
            ]
            if not heads:
                break
            req = min(heads, key=lambda r: r._seq)
            self.queues[req.instance].popleft()
            budget[req.instance] -= 1
            out.append(req)
        return out


class RoundRobinScheduler(Scheduler):
    name = "round-robin"

    def __init__(self, num_instances: int, mesh=None, rules=None):
        super().__init__(num_instances, mesh=mesh, rules=rules)
        self._cursor = 0

    def select(self, free: Mapping[int, int],
               limit: int | None = None) -> list[Request]:
        budget = dict(free)
        out = []
        progressed = True
        while progressed:
            progressed = False
            for off in range(self.m):
                if limit is not None and len(out) >= limit:
                    # resume the interrupted pass here next round, so a
                    # scarce lane limit can't freeze the rotation on one
                    # instance
                    self._cursor = (self._cursor + off) % self.m
                    return out
                i = (self._cursor + off) % self.m
                if self.queues[i] and budget.get(i, 0) > 0:
                    out.append(self.queues[i].popleft())
                    budget[i] -= 1
                    progressed = True
            if progressed:
                self._cursor = (self._cursor + 1) % self.m
        return out


class TokenBudgetScheduler(Scheduler):
    """Least-total-tokens-served instance first.

    ``served[i]`` accumulates prompt tokens at admission (charged inside
    select) and generated tokens per decode step (the engine calls
    note_generated); each admission round repeatedly picks the pending
    instance with the smallest served count, charging its head request's
    prompt immediately so a burst of long prompts on one instance yields
    to the others.  Under a mesh, served-token ties break toward the
    instance on the least-loaded data shard (device group), then by
    index — without a mesh both extra keys are constant and the policy
    is exactly the single-device one."""

    name = "token-budget"

    def __init__(self, num_instances: int, mesh=None, rules=None):
        super().__init__(num_instances, mesh=mesh, rules=rules)
        self.served = [0] * num_instances

    def note_generated(self, instance: int, n: int) -> None:
        self.served[instance] += n

    def _shard_load(self, shard: int) -> int:
        return sum(
            s for i, s in enumerate(self.served) if self._shard_of[i] == shard
        )

    def select(self, free: Mapping[int, int],
               limit: int | None = None) -> list[Request]:
        budget = dict(free)
        out = []
        while True:
            if limit is not None and len(out) >= limit:
                return out
            ready = [
                i for i in range(self.m) if self.queues[i] and budget.get(i, 0) > 0
            ]
            if not ready:
                return out
            i = min(
                ready,
                key=lambda j: (
                    self.served[j], self._shard_load(self._shard_of[j]), j
                ),
            )
            req = self.queues[i].popleft()
            # charge the prompt now so the NEXT pick sees the updated share
            self.served[i] += len(req.prompt)
            out.append(req)
            budget[i] -= 1


POLICIES = {
    c.name: c for c in (FIFOScheduler, RoundRobinScheduler, TokenBudgetScheduler)
}


def make_scheduler(policy: str, num_instances: int, mesh=None,
                   rules=None) -> Scheduler:
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
    return POLICIES[policy](num_instances, mesh=mesh, rules=rules)
