from repro.train.loop import TrainState, make_train_step, train_loop
