"""Training substrate: train_step factory (AdamW + remat + optional
microbatch grad accumulation) and a simple host loop.

NetFuse training mode (paper §6 "Applicability on training models"):
with num_instances M > 1 the same step trains M models at once — the
loss averages per-instance CE (each instance sees its own data stream),
and gradients stay instance-local because every op is input-weight
local.  ``examples/train_merged.py`` demonstrates this end to end.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import api
from repro.optim import adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: Any


def make_train_step(
    cfg,
    *,
    lr_schedule: Callable,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    microbatches: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss(params, batch):
        return api.loss_fn(cfg, params, batch)

    def grads_of(params, batch):
        (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return l, m, g

    def train_step(state: TrainState, batch):
        params, opt = state
        if microbatches > 1:
            def mb(i, carry):
                lsum, gsum = carry
                sub = jax.tree.map(
                    lambda x: x.reshape(x.shape[0], microbatches, -1, *x.shape[2:])[:, i],
                    batch,
                )
                l, _, g = grads_of(params, sub)
                return (lsum + l, jax.tree.map(jnp.add, gsum, g))
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            lsum, gsum = jax.lax.fori_loop(
                0, microbatches, mb, (jnp.float32(0.0), zero)
            )
            l = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = {}
        else:
            l, metrics, grads = grads_of(params, batch)
        lr = lr_schedule(opt.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt, params,
            lr=lr, weight_decay=weight_decay, max_grad_norm=max_grad_norm,
        )
        out = {"loss": l, "lr": lr, **opt_metrics}
        out.update({k: v for k, v in metrics.items()})
        return TrainState(new_params, new_opt), out

    return train_step


def init_state(cfg, key) -> TrainState:
    params = api.init(cfg, key)
    return TrainState(params, adamw_init(params))


def train_loop(
    cfg,
    data,
    *,
    steps: int,
    batch_size: int,
    seq_len: int,
    lr_schedule,
    key=None,
    log_every: int = 10,
    state: TrainState | None = None,
    print_fn=print,
):
    """Host loop used by examples + integration tests (CPU-scale)."""
    key = jax.random.PRNGKey(0) if key is None else key
    state = init_state(cfg, key) if state is None else state
    step_fn = jax.jit(make_train_step(cfg, lr_schedule=lr_schedule))
    losses = []
    t0 = time.perf_counter()
    for step in range(steps):
        batch = data.batch(step, batch_size, seq_len) if hasattr(data, "batch") else data(step)
        state, metrics = step_fn(state, batch)
        if step % log_every == 0 or step == steps - 1:
            l = float(metrics["loss"])
            losses.append((step, l))
            print_fn(
                f"step {step:5d}  loss {l:.4f}  lr {float(metrics['lr']):.2e}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"({time.perf_counter() - t0:.1f}s)"
            )
    return state, losses
