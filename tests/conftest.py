"""Shared test scaffolding.

``hypothesis`` is an optional dependency: when it is missing, the
property tests in test_fused_ops.py / test_kernels.py import no-op
stand-ins for ``given``/``settings``/``st`` from here (module-level
``pytest.importorskip`` would skip those files' non-hypothesis tests
too).  ``given`` marks the test as skipped; ``st`` strategies evaluate
to inert placeholders so decorator arguments still build.
"""
import os

# jax 0.4.3x's CPU thunk runtime segfaults inside backend_compile once a
# single process has accumulated enough compiled executables (reproducible
# at test_serving_chunked.py scale, same crash with the repo diff stashed
# — not our code).  The legacy runtime compiles everything cleanly, so
# pin it for the whole suite.  Appended (not assigned) so CI's
# --xla_force_host_platform_device_count survives; must run before the
# first jax import in the test process, which conftest import order
# guarantees.
_xla_flags = os.environ.get("XLA_FLAGS", "")
if "xla_cpu_use_thunk_runtime" not in _xla_flags:
    os.environ["XLA_FLAGS"] = (
        _xla_flags + " --xla_cpu_use_thunk_runtime=false"
    ).strip()

import pytest


class _StrategyStub:
    """Evaluates any strategy expression (st.integers(...), st.sampled_from
    chains) to an inert placeholder."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _StrategyStub()


def settings(*_a, **_k):
    return lambda f: f


def given(*_a, **_k):
    return pytest.mark.skip(reason="hypothesis not installed")
