"""Shared test scaffolding.

``hypothesis`` is an optional dependency: when it is missing, the
property tests in test_fused_ops.py / test_kernels.py import no-op
stand-ins for ``given``/``settings``/``st`` from here (module-level
``pytest.importorskip`` would skip those files' non-hypothesis tests
too).  ``given`` marks the test as skipped; ``st`` strategies evaluate
to inert placeholders so decorator arguments still build.
"""
import pytest


class _StrategyStub:
    """Evaluates any strategy expression (st.integers(...), st.sampled_from
    chains) to an inert placeholder."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _StrategyStub()


def settings(*_a, **_k):
    return lambda f: f


def given(*_a, **_k):
    return pytest.mark.skip(reason="hypothesis not installed")
