"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each assigned arch family runs one forward + one train step
on CPU; output shapes checked, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ShapeConfig
from repro.configs import registry

ARCHS = sorted(registry.ASSIGNED)

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=2, kind="decode")


def _real_batch(cfg, shape, key):
    specs = api.input_specs(cfg, shape)
    def mk(s):
        if s.dtype == jnp.int32:
            return jax.random.randint(key, s.shape, 0, max(cfg.vocab_size, 2)).astype(jnp.int32)
        return jax.random.normal(key, s.shape, s.dtype) * 0.3
    return jax.tree.map(mk, specs)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = api.init(cfg, jax.random.PRNGKey(0))
    specs = _real_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    batch = specs["batch"]

    # forward
    out = api.train_logits(cfg, params, batch, remat=False)
    if cfg.family == "moe":
        out, aux = out
        assert np.isfinite(float(aux))
    assert out.shape[:2] == batch["labels"].shape[:2]
    assert out.shape[2] == batch["labels"].shape[2]
    assert out.shape[3] == cfg.vocab_size
    assert not bool(jnp.isnan(out).any())

    # one SGD train step via value_and_grad
    loss, metrics = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: api.loss_fn(cfg, p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2, _ = api.loss_fn(cfg, new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = registry.get_smoke_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0))
    m = cfg.num_instances
    b = SMOKE_DECODE.global_batch // m
    cache = api.make_cache(cfg, m, b, SMOKE_DECODE.seq_len)
    tokens = jnp.zeros((m, b, 1), jnp.int32)
    pos = jnp.full((m, b), SMOKE_DECODE.seq_len // 2, jnp.int32)
    logits, new_cache = api.decode_step(cfg, params, cache, tokens, pos)
    assert logits.shape == (m, b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(jax.tree.map(jnp.shape, cache)) == \
        jax.tree.structure(jax.tree.map(jnp.shape, new_cache))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b", "internvl2-26b"])
def test_smoke_sliding_window_variant(arch):
    """long_500k variant (full-attention families w/ window) still runs."""
    cfg = registry.get_smoke_config(arch).with_(sliding_window=8)
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = _real_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))["batch"]
    out = api.train_logits(cfg, params, batch, remat=False)
    if cfg.family == "moe":
        out = out[0]
    assert not bool(jnp.isnan(out).any())


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact published shapes."""
    c = registry.get_config("olmoe-1b-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.num_experts_per_tok) == \
        (16, 2048, 16, 16, 1024, 50304, 64, 8)
    c = registry.get_config("hymba-1.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.ssm_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = registry.get_config("xlstm-1.3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) == (48, 2048, 4, 50304)
    c = registry.get_config("internvl2-26b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (48, 6144, 48, 8, 16384, 92553)
    c = registry.get_config("tinyllama-1.1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (22, 2048, 32, 4, 5632, 32000)
    c = registry.get_config("deepseek-67b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = registry.get_config("whisper-small")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == \
        (12, 768, 12, 3072, 51865)
    c = registry.get_config("granite-3-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2048, 32, 8, 8192, 49155)
    c = registry.get_config("qwen1.5-0.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (24, 1024, 16, 16, 2816, 151936, True)
    c = registry.get_config("qwen3-moe-30b-a3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.num_experts_per_tok) == \
        (48, 2048, 32, 4, 768, 151936, 128, 8)


def test_shape_support_matrix():
    for arch in registry.ASSIGNED:
        assert registry.supported(arch, "train_4k")
        assert registry.supported(arch, "prefill_32k")
        assert registry.supported(arch, "decode_32k")
    assert not registry.supported("whisper-small", "long_500k")
    assert registry.supported("xlstm-1.3b", "long_500k")
    assert registry.supported("hymba-1.5b", "long_500k")
    # full-attention archs run long_500k via the sliding-window variant
    cfg = registry.config_for_shape("deepseek-67b", "long_500k")
    assert cfg.sliding_window == registry.LONG_CONTEXT_WINDOW
