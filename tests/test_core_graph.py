"""Exactness tests for paper Algorithm 1 (graph merging).

The paper's central correctness claim: "NETFUSE does not alter the
computation results in any way".  We build per-instance graphs, merge
them, and assert the merged execution matches per-instance execution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G

jax.config.update("jax_enable_x64", False)


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32) * 0.1


def make_ffnn_graph():
    """The paper's Figure 4 example: FC -> LayerNorm -> GELU -> FC."""
    g = G.Graph()
    g.add("x", "input")
    g.add("fc1", "matmul", ["x"])
    g.add("ln", "layernorm", ["fc1"])
    g.add("act", "gelu", ["ln"])
    g.add("fc2", "matmul", ["act"])
    g.outputs = ["fc2"]
    return g


def make_ffnn_weights(key, d_in=12, d_hidden=16, d_out=8):
    k = jax.random.split(key, 6)
    return {
        "fc1": {"w": _rand(k[0], d_in, d_hidden), "b": _rand(k[1], d_hidden)},
        "ln": {"scale": 1.0 + _rand(k[2], d_hidden), "bias": _rand(k[3], d_hidden)},
        "fc2": {"w": _rand(k[4], d_hidden, d_out), "b": _rand(k[5], d_out)},
    }


def make_cnn_graph():
    """Small CNN: conv -> BN -> relu -> conv(residual add) -> pool -> flatten -> fc."""
    g = G.Graph()
    g.add("img", "input")
    g.add("conv1", "conv2d", ["img"], stride=1, padding="SAME")
    g.add("bn1", "batchnorm", ["conv1"])
    g.add("relu1", "relu", ["bn1"])
    g.add("conv2", "conv2d", ["relu1"], stride=1, padding="SAME")
    g.add("res", "add", ["conv2", "relu1"])
    g.add("pool", "maxpool2d", ["res"], kernel=2)
    g.add("gap", "global_avgpool", ["pool"])
    g.add("fc", "matmul", ["gap"])
    g.outputs = ["fc"]
    return g


def make_cnn_weights(key, cin=3, c=8, n_class=5):
    k = jax.random.split(key, 8)
    return {
        "conv1": {"w": _rand(k[0], 3, 3, cin, c), "b": _rand(k[1], c)},
        "bn1": {
            "mean": _rand(k[2], c),
            "var": jnp.abs(_rand(k[3], c)) + 0.5,
            "scale": 1.0 + _rand(k[4], c),
            "bias": _rand(k[5], c),
        },
        "conv2": {"w": _rand(k[6], 3, 3, c, c)},
        "fc": {"w": _rand(k[7], c, n_class)},
    }


@pytest.mark.parametrize("m", [2, 4, 8])
def test_ffnn_merge_exact(m):
    """Paper Fig. 4: merged FFNN == per-instance FFNNs, bit-for-bit math."""
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, m + 1)
    g = make_ffnn_graph()
    weights = [make_ffnn_weights(keys[i]) for i in range(m)]
    inputs = [{"x": _rand(keys[-1], 4, 12) + i} for i in range(m)]

    merged, mw, dims = G.merge_graph(g, weights)
    # fc1 -> bmm demands Batch; ln demands Channel => a reshape is inserted.
    assert any(op.op_type == "merge_reshape" for op in merged.ops.values())
    assert dims["fc1"] is G.MergeDim.BATCH
    assert dims["ln"] is G.MergeDim.CHANNEL

    fused = G.execute_merged(merged, mw, dims, inputs)
    for i in range(m):
        ref = G.execute(g, inputs[i], weights[i])
        np.testing.assert_allclose(
            np.asarray(fused[i]["fc2"]), np.asarray(ref["fc2"]), rtol=2e-5, atol=2e-5
        )


@pytest.mark.parametrize("m", [2, 3])
def test_cnn_merge_exact(m):
    """Grouped-conv merging (paper Appendix A) on a residual CNN."""
    key = jax.random.PRNGKey(1)
    keys = jax.random.split(key, m + 1)
    g = make_cnn_graph()
    weights = [make_cnn_weights(keys[i]) for i in range(m)]
    inputs = [{"img": _rand(keys[-1], 2, 8, 8, 3) * (i + 1)} for i in range(m)]

    merged, mw, dims = G.merge_graph(g, weights)
    assert merged.ops["conv1"].attrs["groups"] == m
    fused = G.execute_merged(merged, mw, dims, inputs)
    for i in range(m):
        ref = G.execute(g, inputs[i], weights[i])
        np.testing.assert_allclose(
            np.asarray(fused[i]["fc"]), np.asarray(ref["fc"]), rtol=2e-4, atol=2e-4
        )


def test_grouped_ops_compose():
    """Merging ops that already have groups multiplies the group count
    (paper §3.1: 4 grouped convs x 2 groups -> 8 groups)."""
    g = G.Graph()
    g.add("x", "input")
    g.add("gconv", "conv2d", ["x"], groups=2)
    g.outputs = ["gconv"]
    key = jax.random.PRNGKey(2)
    m = 4
    keys = jax.random.split(key, m + 1)
    weights = [
        {"gconv": {"w": _rand(keys[i], 3, 3, 4, 8)}} for i in range(m)
    ]  # cin=8 in 2 groups of 4
    inputs = [{"x": _rand(keys[-1], 2, 6, 6, 8) + i} for i in range(m)]
    merged, mw, dims = G.merge_graph(g, weights)
    assert merged.ops["gconv"].attrs["groups"] == 8
    fused = G.execute_merged(merged, mw, dims, inputs)
    for i in range(m):
        ref = G.execute(g, inputs[i], weights[i])
        np.testing.assert_allclose(
            np.asarray(fused[i]["gconv"]), np.asarray(ref["gconv"]), rtol=2e-5, atol=2e-5
        )


def test_merge_rejects_different_architectures():
    from repro.core import merge as M

    p1 = {"a": jnp.zeros((2, 3))}
    p2 = {"b": jnp.zeros((2, 3))}
    with pytest.raises(ValueError):
        M.stack_instances([p1, p2])


def test_dontcare_majority_rule():
    """Alg.1 lines 23-27: DontCare op follows the majority of parents."""
    g = G.Graph()
    g.add("x", "input")
    g.add("fc", "matmul", ["x"])        # Batch
    g.add("ln1", "layernorm", ["fc"])   # Channel
    g.add("ln2", "layernorm", ["fc"])   # Channel (reuses fc's output)
    g.add("sum", "add", ["ln1", "ln2"])  # DontCare -> Channel (majority)
    g.outputs = ["sum"]
    key = jax.random.PRNGKey(3)
    keys = jax.random.split(key, 3)
    mkw = lambda k: {
        "fc": {"w": _rand(k, 6, 8)},
        "ln1": {"scale": jnp.ones(8), "bias": jnp.zeros(8)},
        "ln2": {"scale": 2 * jnp.ones(8), "bias": jnp.ones(8)},
    }
    weights = [mkw(keys[i]) for i in range(2)]
    inputs = [{"x": _rand(keys[-1], 4, 6) + i} for i in range(2)]
    merged, mw, dims = G.merge_graph(g, weights)
    assert dims["sum"] is G.MergeDim.CHANNEL
    fused = G.execute_merged(merged, mw, dims, inputs)
    for i in range(2):
        ref = G.execute(g, inputs[i], weights[i])
        np.testing.assert_allclose(
            np.asarray(fused[i]["sum"]), np.asarray(ref["sum"]), rtol=2e-5, atol=2e-5
        )
