"""Unit + property tests for the merged op counterparts (paper Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is optional: property tests skip, everything else runs
    from conftest import given, settings, st  # noqa: F401

from repro.core import fused_ops as F
from repro.core import baselines, merge


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# batch matmul == per-instance matmuls
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 6),
    b=st.integers(1, 5),
    d=st.integers(1, 9),
    f=st.integers(1, 9),
    bias=st.booleans(),
)
def test_batch_matmul_property(m, b, d, f, bias):
    ks = _keys(3)
    x = jax.random.normal(ks[0], (m, b, d))
    w = jax.random.normal(ks[1], (m, d, f))
    bb = jax.random.normal(ks[2], (m, f)) if bias else None
    y = F.batch_matmul(x, w, bb)
    for i in range(m):
        ref = x[i] @ w[i] + (bb[i] if bias else 0.0)
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_batch_matmul_concat_matches_instance_axis():
    ks = _keys(2)
    m, b, d, f = 4, 3, 8, 5
    x = jax.random.normal(ks[0], (m, b, d))
    w = jax.random.normal(ks[1], (m, d, f))
    y1 = F.batch_matmul(x, w)
    y2 = F.batch_matmul_concat(x.reshape(m * b, d), w).reshape(m, b, f)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


# ---------------------------------------------------------------------------
# grouped conv == M convs (paper Appendix A derivation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,cin,cout,stride", [(2, 3, 4, 1), (4, 2, 2, 2), (1, 3, 5, 1)])
def test_grouped_conv_equals_m_convs(m, cin, cout, stride):
    ks = _keys(2 * m)
    xs = [jax.random.normal(ks[i], (2, 8, 8, cin)) for i in range(m)]
    ws = [jax.random.normal(ks[m + i], (3, 3, cin, cout)) for i in range(m)]
    x_cat = jnp.concatenate(xs, axis=-1)
    w_cat = F.merge_conv_weights(ws)
    y = F.grouped_conv2d(x_cat, w_cat, groups=m, stride=stride)
    for i in range(m):
        ref = F.grouped_conv2d(xs[i], ws[i], groups=1, stride=stride)
        np.testing.assert_allclose(
            np.asarray(y[..., i * cout : (i + 1) * cout]), np.asarray(ref),
            rtol=1e-4, atol=1e-4,
        )


# ---------------------------------------------------------------------------
# group norm == M layer norms
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 5), b=st.integers(1, 4), d=st.integers(2, 12))
def test_group_norm_equals_m_layernorms(m, b, d):
    ks = _keys(3)
    xs = jax.random.normal(ks[0], (m, b, d))
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (m, d))
    bias = 0.1 * jax.random.normal(ks[2], (m, d))

    # concat (paper) form
    x_cat = jnp.moveaxis(xs, 0, 1).reshape(b, m * d)
    y_cat = F.group_norm(x_cat, scale.reshape(-1), bias.reshape(-1), num_groups=m)
    # instance-axis form
    y_inst = F.merged_layer_norm(xs, scale, bias)

    for i in range(m):
        mu = xs[i].mean(-1, keepdims=True)
        var = xs[i].var(-1, keepdims=True)
        ref = (xs[i] - mu) / jnp.sqrt(var + 1e-5) * scale[i] + bias[i]
        np.testing.assert_allclose(
            np.asarray(y_cat[:, i * d : (i + 1) * d]), np.asarray(ref), rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(np.asarray(y_inst[i]), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_merged_embedding():
    ks = _keys(2)
    m, v, d = 3, 11, 6
    table = jax.random.normal(ks[0], (m, v, d))
    ids = jax.random.randint(ks[1], (m, 4, 5), 0, v)
    out = F.merged_embedding(ids, table)
    assert out.shape == (m, 4, 5, d)
    for i in range(m):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(table[i][ids[i]]))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 4), b=st.integers(1, 4), d=st.integers(1, 8))
def test_form_conversion_roundtrip(m, b, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (m * b, d))
    y = F.batch_to_channel(x, m)
    assert y.shape == (b, m * d)
    z = F.channel_to_batch(y, m)
    np.testing.assert_allclose(np.asarray(x), np.asarray(z))


# ---------------------------------------------------------------------------
# baselines agree with each other (and with netfuse) on a toy model
# ---------------------------------------------------------------------------


def _toy_apply(params, x):
    """Fusion-aware 2-layer MLP: params have leading M axis, x is (M,B,D)."""
    h = F.batch_matmul(x, params["w1"], params["b1"])
    h = F.merged_layer_norm(h, params["ln_s"], params["ln_b"])
    h = jax.nn.gelu(h)
    return F.batch_matmul(h, params["w2"])


def _toy_params(key, d=8, h=16, o=4):
    ks = jax.random.split(key, 5)
    return {
        "w1": jax.random.normal(ks[0], (d, h)) * 0.1,
        "b1": jax.random.normal(ks[1], (h,)) * 0.1,
        "ln_s": 1.0 + jax.random.normal(ks[2], (h,)) * 0.1,
        "ln_b": jax.random.normal(ks[3], (h,)) * 0.1,
        "w2": jax.random.normal(ks[4], (h, o)) * 0.1,
    }


def test_all_strategies_agree():
    m = 5
    ks = _keys(m + 1, seed=7)
    params_list = [_toy_params(ks[i]) for i in range(m)]
    inputs = [jax.random.normal(ks[-1], (3, 8)) + i for i in range(m)]

    seq = baselines.sequential(_toy_apply, params_list, inputs)
    conc = baselines.concurrent(_toy_apply, params_list, inputs)
    hyb = baselines.hybrid(_toy_apply, params_list, inputs, num_concurrent=2)
    fused = baselines.netfuse(_toy_apply, params_list, inputs)
    for i in range(m):
        for other in (conc[i], hyb[i], fused[i]):
            np.testing.assert_allclose(
                np.asarray(seq[i]), np.asarray(other), rtol=1e-5, atol=1e-6
            )


def test_stack_unstack_roundtrip():
    ks = _keys(4, seed=9)
    params_list = [_toy_params(k) for k in ks]
    merged = merge.stack_instances(params_list)
    assert merge.num_instances(merged) == 4
    back = merge.unstack_instances(merged)
    for a, b in zip(params_list, back):
        jax.tree.map(lambda x, y: np.testing.assert_allclose(np.asarray(x), np.asarray(y)), a, b)


def test_concat_instances_composes():
    ks = _keys(4, seed=11)
    a = merge.stack_instances([_toy_params(ks[0]), _toy_params(ks[1])])
    b = merge.stack_instances([_toy_params(ks[2])])
    ab = merge.concat_instances(a, b)
    assert merge.num_instances(ab) == 3
