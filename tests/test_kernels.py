"""Pallas kernel validation: shape/dtype sweeps + hypothesis property
tests against the pure-jnp oracles in kernels/ref.py (interpret=True —
kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is optional: property tests skip, everything else runs
    from conftest import given, settings, st  # noqa: F401

from repro.kernels import ops, ref
from repro.kernels.decode_attn import decode_attention
from repro.kernels.fused_matmul import fused_matmul
from repro.kernels.group_norm import group_rms_norm


def _tol(dt):
    return dict(rtol=3e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


def _cmp(got, want, dt):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dt)
    )


# ---------------------------------------------------------------------------
# fused_matmul
# ---------------------------------------------------------------------------

MATMUL_SHAPES = [
    (1, 1, 16, 16),      # paper regime: single token, single instance
    (8, 1, 64, 32),      # 8 merged instances at bs=1 (the NetFuse case)
    (2, 128, 256, 128),  # MXU-aligned
    (3, 7, 48, 17),      # ragged everything
    (4, 33, 96, 64),
]


@pytest.mark.parametrize("m,t,d,f", MATMUL_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
def test_fused_matmul_sweep(m, t, d, f, dt, bias):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (m, t, d), dt)
    w = jax.random.normal(ks[1], (m, d, f), dt)
    b = jax.random.normal(ks[2], (m, f), dt) if bias else None
    _cmp(fused_matmul(x, w, b), ref.fused_matmul(x, w, b), dt)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 4), t=st.integers(1, 9), d=st.integers(1, 24),
    f=st.integers(1, 12), bt=st.sampled_from([32, 128]),
    bd=st.sampled_from([8, 512]),
)
def test_fused_matmul_property(m, t, d, f, bt, bd):
    """Block-shape invariance: any clamped tiling gives the same result."""
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (m, t, d))
    w = jax.random.normal(ks[1], (m, d, f))
    got = fused_matmul(x, w, block_t=bt, block_d=bd)
    _cmp(got, ref.fused_matmul(x, w), jnp.float32)


def test_fused_matmul_instance_isolation():
    """NetFuse invariant: zeroing instance j's weights must not change
    instance i's output."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(ks[0], (3, 4, 32))
    w = jax.random.normal(ks[1], (3, 32, 16))
    base = fused_matmul(x, w)
    w2 = w.at[1].set(0.0)
    out = fused_matmul(x, w2)
    _cmp(out[0], base[0], jnp.float32)
    _cmp(out[2], base[2], jnp.float32)
    assert float(jnp.abs(out[1]).max()) == 0.0


# ---------------------------------------------------------------------------
# group_rms_norm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,t,d", [(1, 1, 8), (2, 16, 64), (3, 250, 128), (4, 64, 512)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_group_rms_norm_sweep(m, t, d, dt):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (m, t, d), dt)
    sc = 1 + 0.1 * jax.random.normal(ks[1], (m, d), dt)
    _cmp(group_rms_norm(x, sc), ref.group_rms_norm(x, sc), dt)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 4), t=st.integers(1, 17), d=st.integers(2, 40))
def test_group_rms_norm_property(m, t, d):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (m, t, d))
    sc = 1 + 0.1 * jax.random.normal(ks[1], (m, d))
    _cmp(group_rms_norm(x, sc, block_t=8), ref.group_rms_norm(x, sc), jnp.float32)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

DECODE_SHAPES = [
    (1, 1, 4, 4, 32, 16),    # MHA
    (2, 2, 4, 2, 64, 16),    # GQA 2:1
    (1, 3, 8, 4, 128, 32),
    (2, 1, 8, 1, 96, 8),     # MQA
]


@pytest.mark.parametrize("m,b,h,kvh,s,hd", DECODE_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(m, b, h, kvh, s, hd, dt):
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = jax.random.normal(ks[0], (m, b, h, hd), dt)
    k = jax.random.normal(ks[1], (m, b, s, kvh, hd), dt)
    v = jax.random.normal(ks[2], (m, b, s, kvh, hd), dt)
    kv_len = jax.random.randint(ks[3], (m, b), 1, s + 1)
    got = decode_attention(q, k, v, kv_len, block_s=32)
    _cmp(got, ref.decode_attention(q, k, v, kv_len), dt)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3), kvh=st.sampled_from([1, 2]), g=st.integers(1, 3),
    s_blocks=st.integers(1, 4), bs=st.sampled_from([16, 32]),
)
def test_decode_attention_property(b, kvh, g, s_blocks, bs):
    """Online-softmax block invariance + mask correctness for any valid
    prefix length."""
    m, hd = 2, 8
    s = s_blocks * bs
    h = kvh * g
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(ks[0], (m, b, h, hd))
    k = jax.random.normal(ks[1], (m, b, s, kvh, hd))
    v = jax.random.normal(ks[2], (m, b, s, kvh, hd))
    kv_len = jax.random.randint(ks[3], (m, b), 1, s + 1)
    got = decode_attention(q, k, v, kv_len, block_s=bs)
    _cmp(got, ref.decode_attention(q, k, v, kv_len), jnp.float32)


def test_decode_attention_matches_model_flash_path():
    """Kernel agrees with the model zoo's flash_attention decode path."""
    from repro.models import layers as L
    m, b, h, kvh, s, hd = 1, 2, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (m, b, h, hd))
    k = jax.random.normal(ks[1], (m, b, s, kvh, hd))
    v = jax.random.normal(ks[2], (m, b, s, kvh, hd))
    kv_len = jnp.array([[40, 64]], jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_s=16)
    kv_pos = jnp.where(
        jnp.arange(s)[None, None] < kv_len[..., None],
        jnp.arange(s, dtype=jnp.int32)[None, None], -1,
    )
    want = L.flash_attention(
        q[:, :, None], k, v,
        (kv_len - 1)[..., None], kv_pos, kv_chunk=16,
    )[:, :, 0]
    _cmp(got, want, jnp.float32)


def test_ops_dispatch():
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    x = jax.random.normal(ks[0], (2, 4, 16))
    w = jax.random.normal(ks[1], (2, 16, 8))
    _cmp(ops.fused_matmul(x, w), ops.fused_matmul(x, w, use_pallas=False), jnp.float32)


# ---------------------------------------------------------------------------
# slstm_cell — whole-sequence recurrent cell kernel (§Perf xlstm next lever)
# ---------------------------------------------------------------------------

SLSTM_SHAPES = [
    (1, 1, 4, 1, 8),     # minimal
    (2, 3, 16, 2, 8),    # multi-instance, multi-head
    (3, 2, 24, 4, 16),   # chunk boundary (24 % default chunk)
    (1, 4, 32, 2, 64),   # wide head
]


def _slstm_inputs(m, b, s, hh, hd, dt):
    d = hh * hd
    k = jax.random.PRNGKey(42)
    pre = (jax.random.normal(k, (m, b, s, 4, d)) * 0.5).astype(dt)
    r = (jax.random.normal(jax.random.PRNGKey(1), (m, 4, hh, hd, hd)) * 0.2).astype(jnp.float32)
    state = (
        jnp.zeros((m, b, d), jnp.float32),
        jnp.zeros((m, b, d), jnp.float32),
        jnp.zeros((m, b, d), dt),
        jnp.full((m, b, d), -1e30, jnp.float32),
    )
    return pre, r, state


@pytest.mark.parametrize("m,b,s,hh,hd", SLSTM_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_slstm_cell_sweep(m, b, s, hh, hd, dt):
    pre, r, state = _slstm_inputs(m, b, s, hh, hd, dt)
    hs_k, st_k = ops.slstm_cell(pre, r, state, num_heads=hh, chunk=8)
    hs_r, st_r = ref.slstm_cell(pre, r, state, num_heads=hh)
    tol = 1e-5 if dt == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(hs_k, np.float32), np.asarray(hs_r, np.float32),
        rtol=tol, atol=tol)
    for a, bb in zip(st_k, st_r):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(bb, np.float32),
            rtol=tol, atol=tol)


def test_slstm_cell_matches_model_block_recurrence():
    """Kernel == the sLSTM recurrence inside repro.models.ssm.slstm_block
    (same gates, stabilizer, head-block-diagonal recurrent projection)."""
    from repro.configs.base import ModelConfig
    from repro.models import ssm

    cfg = ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64, slstm_every=1, slstm_offset=0,
        dtype="float32", param_dtype="float32",
    )
    m, b, s = 2, 3, 12
    lp = jax.tree.map(lambda p: p, ssm.init(cfg, jax.random.PRNGKey(0))["slstm"][0])
    x = jax.random.normal(jax.random.PRNGKey(1), (m, b, s, cfg.d_model)) * 0.5

    # replicate the block's pre-activation path, then compare the scan part
    from repro.models import layers as L
    xn = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    pre = L.linear(xn, lp["w_in"], lp["b_in"]).reshape(m, b, s, 4, cfg.d_model)
    state = (
        jnp.zeros((m, b, cfg.d_model), jnp.float32),
        jnp.zeros((m, b, cfg.d_model), jnp.float32),
        jnp.zeros((m, b, cfg.d_model), x.dtype),
        jnp.full((m, b, cfg.d_model), -1e30, jnp.float32),
    )
    hs_k, _ = ops.slstm_cell(pre, lp["r"], state, num_heads=cfg.num_heads, chunk=4)

    _, st = ssm.slstm_block(cfg, lp, x)   # runs the full block
    # recompute the block's raw scan output by re-deriving hs from its
    # published step function: easiest exact cross-check is the ref oracle
    hs_r, _ = ref.slstm_cell(pre, lp["r"], state, num_heads=cfg.num_heads)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), rtol=1e-5, atol=1e-5)
    # and the final h of the oracle must equal the model block's state h
    _, st_r = ref.slstm_cell(pre, lp["r"], state, num_heads=cfg.num_heads)
    np.testing.assert_allclose(
        np.asarray(st_r[2]), np.asarray(st["h"]), rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(1, 3), b=st.integers(1, 3),
    s_chunks=st.integers(1, 4), hh=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=15, deadline=None)
def test_slstm_cell_property_chunk_invariance(m, b, s_chunks, hh):
    """Output is invariant to the kernel's S-chunking (the VMEM-resident
    carry must be exact across chunk boundaries)."""
    hd, s = 8, s_chunks * 4
    pre, r, state = _slstm_inputs(m, b, s, hh, hd, jnp.float32)
    a, sa = ops.slstm_cell(pre, r, state, num_heads=hh, chunk=4)
    bfull, sb = ops.slstm_cell(pre, r, state, num_heads=hh, chunk=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bfull), rtol=1e-6, atol=1e-6)
    for x, y in zip(sa, sb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-6)


def test_slstm_block_pallas_flag_matches_reference():
    """cfg.use_pallas_kernels routes slstm_block through the Pallas cell;
    forward outputs and prefill->decode state handoff must be identical
    (serving path — the XLA scan remains the autodiff/training path)."""
    from repro.configs.base import ModelConfig
    from repro.models import ssm

    base = ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64, slstm_every=1, slstm_offset=0,
        dtype="float32", param_dtype="float32",
    )
    lp = ssm.init(base, jax.random.PRNGKey(0))["slstm"][0]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 12, base.d_model)) * 0.5

    y_ref, st_ref = ssm.slstm_block(base, lp, x)
    y_pl, st_pl = ssm.slstm_block(base.with_(use_pallas_kernels=True), lp, x)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for kk in ("c", "n", "h", "m"):
        np.testing.assert_allclose(np.asarray(st_pl[kk]), np.asarray(st_ref[kk]),
                                   rtol=1e-5, atol=1e-5)

    # decode continuation (s=1 with carried state)
    x1 = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 1, base.d_model)) * 0.5
    y1_ref, _ = ssm.slstm_block(base, lp, x1, state=st_ref)
    y1_pl, _ = ssm.slstm_block(
        base.with_(use_pallas_kernels=True), lp, x1, state=st_pl)
    np.testing.assert_allclose(np.asarray(y1_pl), np.asarray(y1_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mlstm_chunkwise — matrix-memory chunk kernel (companion to slstm_cell)
# ---------------------------------------------------------------------------

MLSTM_SHAPES = [
    (1, 1, 1, 8, 8),
    (2, 2, 2, 32, 16),
    (1, 3, 4, 24, 8),    # non-power-of-two S
]


def _mlstm_inputs(m, b, hh, s, hd, dt):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = (jax.random.normal(ks[0], (m, b, hh, s, hd)) * 0.5).astype(dt)
    k = (jax.random.normal(ks[1], (m, b, hh, s, hd)) * 0.5).astype(dt)
    v = (jax.random.normal(ks[2], (m, b, hh, s, hd)) * 0.5).astype(dt)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (m, b, hh, s)) + 2.0)
    li = jax.random.normal(ks[4], (m, b, hh, s)) * 0.5
    return q, k, v, lf, li


@pytest.mark.parametrize("m,b,hh,s,hd", MLSTM_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunkwise_sweep(m, b, hh, s, hd, dt):
    q, k, v, lf, li = _mlstm_inputs(m, b, hh, s, hd, dt)
    hk, (ck, nk, mk) = ops.mlstm_chunkwise(q, k, v, lf, li, chunk=8)
    hr, (cr, nr, mr) = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=8)
    tol = 2e-5 if dt == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr, np.float32), rtol=tol, atol=tol)
    for a, bb2 in ((ck, cr), (nk, nr), (mk, mr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb2),
                                   rtol=tol, atol=tol)


@given(chunk=st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunkwise_property_chunk_invariance(chunk):
    """The chunkwise form is exact: outputs must agree across chunk sizes
    (and with the model's scan at yet another chunking)."""
    q, k, v, lf, li = _mlstm_inputs(2, 2, 2, 32, 8, jnp.float32)
    h1, st1 = ops.mlstm_chunkwise(q, k, v, lf, li, chunk=chunk)
    h2, st2 = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)
    for a, b2 in zip(st1, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), rtol=2e-4, atol=2e-4)


def test_xlstm_forward_pallas_flag_matches_reference():
    """Full xLSTM forward with cfg.use_pallas_kernels routes BOTH cell
    kernels (mLSTM chunk + sLSTM cell) and must match the XLA scans."""
    from repro.configs import registry
    from repro.models import ssm

    cfg = registry.get_smoke_config("xlstm-1.3b").with_(
        dtype="float32", param_dtype="float32")
    params = ssm.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 2, 16), 0, cfg.vocab_size)
    y_ref = ssm.forward(cfg, params, toks)
    y_pl = ssm.forward(cfg.with_(use_pallas_kernels=True), params, toks)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# chunk_prefill_attention — chunked-prefill flash attention over a cache
# (decode_attention extended from q-len 1 to q-len C; serving tail folding)
# ---------------------------------------------------------------------------

# (m, b, c, h, kvh, s_cache, hd, pin, window, sink)
CHUNK_ATTN_CASES = [
    (2, 1, 8, 4, 2, 16, 8, 0, 0, 0),      # GQA, full cache, mid-prompt
    (1, 2, 4, 4, 4, 24, 8, 0, 6, 0),      # MHA, sliding window, ring wrap
    (2, 1, 8, 8, 2, 20, 16, 4, 8, 4),     # pinned prefix + sink (hybrid SWA)
    (1, 1, 5, 3, 1, 13, 8, 0, 0, 0),      # MQA, ragged everything
]


@pytest.mark.parametrize("m,b,c,h,kvh,sc,hd,pin,win,sink", CHUNK_ATTN_CASES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_chunk_prefill_attention_sweep(m, b, c, h, kvh, sc, hd, pin, win, sink, dt):
    """GQA parity vs the pure-jnp oracle across cache layouts: plain ring,
    wrapped ring, pinned-prefix (meta-token) ring with attention sink."""
    from repro.kernels.chunk_prefill_attn import chunk_prefill_attention

    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (m, b, c, h, hd), dt)
    k = jax.random.normal(ks[1], (m, b, sc + c, kvh, hd), dt)
    v = jax.random.normal(ks[2], (m, b, sc + c, kvh, hd), dt)
    # offsets straddle empty / mid-fill / wrapped cache states per lane
    offset = jax.random.randint(ks[3], (m, b), max(pin, 1), sc + 5)
    got = chunk_prefill_attention(
        q, k, v, offset, s_cache=sc, pin=pin, window=win, sink=sink, block_s=8)
    want = ref.chunk_prefill_attention(
        q, k, v, offset, s_cache=sc, pin=pin, window=win, sink=sink)
    _cmp(got, want, dt)


def test_chunk_prefill_attention_matches_model_flash_path():
    """Kernel agrees with the model zoo's flash_attention chunk path (the
    XLA formulation it replaces in dense._prefill_chunk_embeds): same
    [cache-before, chunk] stream, positions from cache_positions_after."""
    from repro.kernels.chunk_prefill_attn import chunk_prefill_attention
    from repro.models import layers as L

    m, b, c, h, kvh, sc, hd = 2, 1, 6, 4, 2, 18, 16
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = jax.random.normal(ks[0], (m, b, c, h, hd))
    k = jax.random.normal(ks[1], (m, b, sc + c, kvh, hd))
    v = jax.random.normal(ks[2], (m, b, sc + c, kvh, hd))
    offset = jnp.array([[4], [21]], jnp.int32)        # pre-wrap and wrapped
    got = chunk_prefill_attention(q, k, v, offset, s_cache=sc, window=8,
                                  block_s=8)
    positions = offset[..., None] + jnp.arange(c, dtype=jnp.int32)
    kv_pos = jnp.concatenate(
        [L.cache_positions_after(offset - 1, sc, 0), positions], axis=-1)
    want = L.flash_attention(q, k, v, positions, kv_pos, window=8, kv_chunk=8)
    _cmp(got, want, jnp.float32)


def test_chunk_prefill_ops_dispatch():
    from repro.kernels.chunk_prefill_attn import chunk_prefill_attention  # noqa: F401

    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (1, 2, 4, 4, 8))
    k = jax.random.normal(ks[1], (1, 2, 16 + 4, 2, 8))
    v = jax.random.normal(ks[2], (1, 2, 16 + 4, 2, 8))
    offset = jnp.array([[3, 9]], jnp.int32)
    got = ops.chunk_prefill_attention(q, k, v, offset, s_cache=16)
    want = ops.chunk_prefill_attention(q, k, v, offset, s_cache=16,
                                       use_pallas=False)
    _cmp(got, want, jnp.float32)
