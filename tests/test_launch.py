"""Launch-layer tests: HLO cost model, sharding rules, input specs, and
in-process lowering of every family on a 1x1 mesh (the 512-device meshes
are exercised by launch/dryrun.py, which must own jax initialization)."""
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import api
from repro.configs import registry
from repro.configs.base import SHAPES, ShapeConfig
from repro.launch import hlo_analysis as H
from repro.models.common import Rules


# ---------------------------------------------------------------------------
# HLO cost model
# ---------------------------------------------------------------------------


def test_scan_flops_account_trip_count():
    """The whole reason hlo_analysis exists: XLA's cost_analysis counts a
    while body once; ours multiplies by known_trip_count."""
    D, N = 128, 8

    def f(c, xs):
        return jax.lax.scan(lambda c, x: (c @ x, None), c, xs)[0]

    txt = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((N, D, D), jnp.float32),
        )
        .compile()
        .as_text()
    )
    got = H.analyze_hlo_text(txt)["flops"]
    want = N * 2 * D**3
    assert want <= got <= want * 1.2, (got, want)
    # and XLA's own counts exactly one body:
    assert got >= 7 * (2 * D**3)


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    txt = jax.jit(lambda x, y: x @ y).lower(a, b).compile().as_text()
    got = H.analyze_hlo_text(txt)["flops"]
    assert abs(got - 2 * 64 * 32 * 16) / (2 * 64 * 32 * 16) < 0.05


def test_collective_parsing_synthetic():
    """Collective byte accounting on a hand-written HLO module."""
    txt = """
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[2048]{0} all-gather(%ar), dimensions={0}
  ROOT %rs = f32[1024]{0} reduce-scatter(%ag), dimensions={0}, to_apply=%add
}
"""
    out = H.analyze_hlo_text(txt)
    assert out["collectives"]["all-reduce"] == 1024 * 4
    assert out["collectives"]["all-gather"] == 2048 * 4     # result moves
    assert out["collectives"]["reduce-scatter"] == 2048 * 4  # operand moves
    assert out["collective_bytes"] == (1024 + 2048 + 2048) * 4


def test_while_trip_multiplies_collectives():
    txt = """
HloModule m

%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256]{0} get-tuple-element(%p), index=1
  %ar = f32[256]{0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[256]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[256])) -> pred[] {
  %p = (s32[], f32[256]) parameter(0)
  ROOT %lt = pred[] compare(%p, %p), direction=LT
}

ENTRY %main (p0: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p0 = (s32[], f32[256]) parameter(0)
  ROOT %w = (s32[], f32[256]) while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
    out = H.analyze_hlo_text(txt)
    assert out["collectives"]["all-reduce"] == 5 * 256 * 4


def test_shape_parsing():
    assert H._parse_shape("f32[128,64]{1,0}") == ("f32", [128, 64])
    assert H._parse_shape("bf16[2]") == ("bf16", [2])
    assert H._parse_shape("s32[]") == ("s32", [])
    tup = H._parse_shape("(s32[], f32[4,4]{1,0})")
    assert tup == [("s32", []), ("f32", [4, 4])]
    assert H._nbytes(("bf16", [8, 8])) == 128


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


class _FakeMesh:
    shape = {"data": 4, "model": 2}


def test_rules_divisibility_guard():
    r = Rules(_FakeMesh(), {"batch": "data", "heads": "model"})
    assert r.spec(("batch", "heads"), (8, 6)) == P("data", "model")
    assert r.spec(("batch", "heads"), (3, 6)) == P(None, "model")   # 3 % 4 != 0
    assert r.spec(("batch", "heads"), (8, 5)) == P("data", None)


def test_rules_duplicate_axis_guard():
    r = Rules(_FakeMesh(), {"seq": "model", "heads": "model"})
    # 'model' can appear only once; first dim wins
    assert r.spec(("seq", "heads"), (4, 4)) == P("model", None)


def test_kv_hd_fallback():
    """8 kv heads on 16-way model axis -> head_dim shards instead."""
    class M:
        shape = {"data": 16, "model": 16}
    r = Rules(M(), {"batch": "data", "kv_heads": "model", "kv_hd": "model"})
    spec = r.spec(("batch", None, "kv_heads", "kv_hd"), (128, 32768, 8, 128))
    assert spec == P("data", None, None, "model")
    spec = r.spec(("batch", None, "kv_heads", "kv_hd"), (128, 32768, 16, 128))
    assert spec == P("data", None, "model", None)


# ---------------------------------------------------------------------------
# input specs: every supported (arch x shape)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(registry.ASSIGNED))
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_input_specs_all_pairs(arch, shape_name):
    shape = SHAPES[shape_name]
    if not registry.supported(arch, shape):
        with pytest.raises(ValueError):
            registry.config_for_shape(arch, shape)
        return
    cfg = registry.config_for_shape(arch, shape)
    specs = api.input_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        toks = specs["batch"]["tokens"]
        assert toks.shape[0] == 1 and toks.shape[1] == shape.global_batch
        if cfg.family == "vlm":
            assert toks.shape[2] + cfg.num_image_patches == shape.seq_len
        else:
            assert toks.shape[2] == shape.seq_len
    else:
        assert specs["tokens"].shape == (1, shape.global_batch, 1)
        assert specs["pos"].shape == (1, shape.global_batch)
        leaves = jax.tree.leaves(specs["cache"])
        assert leaves, "decode cache must be non-empty"
        # cache sized by context (or window/meta+window for SW variants)
        assert all(l.size > 0 for l in leaves)


# ---------------------------------------------------------------------------
# lowering every family in-process (1x1 mesh, smoke configs)
# ---------------------------------------------------------------------------

SMALL_TRAIN = ShapeConfig("small_train", 32, 2, "train")
SMALL_PREFILL = ShapeConfig("small_prefill", 32, 2, "prefill")
SMALL_DECODE = ShapeConfig("small_decode", 64, 2, "decode")


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b", "xlstm-1.3b",
                                  "hymba-1.5b", "internvl2-26b", "whisper-small"])
@pytest.mark.parametrize("shape", [SMALL_TRAIN, SMALL_PREFILL, SMALL_DECODE])
def test_lower_compile_smoke_mesh(arch, shape):
    from repro.launch.dryrun import build_lowerable
    from repro.launch.shardings import serve_rules, train_rules

    cfg = registry.get_smoke_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = train_rules(mesh) if shape.kind == "train" else serve_rules(mesh)
    with jax.set_mesh(mesh), rules:
        fn, args, in_sh = build_lowerable(cfg, shape, mesh, rules)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    txt = compiled.as_text()
    analysis = H.analyze_hlo_text(txt)
    assert analysis["flops"] > 0
    assert analysis["bytes"] > 0
