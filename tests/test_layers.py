"""Correctness tests for the fusion-aware primitives (attention etc.)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _naive_attention(q, k, v, q_pos, kv_pos, window=0, causal=True):
    """O(S^2) reference with explicit masks. Shapes as flash_attention."""
    m, b, sq, h, hd = q.shape
    kvh = k.shape[3]
    g = h // kvh
    qg = q.reshape(m, b, sq, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("mbqkgd,mbckd->mbkgqc", qg, k.astype(jnp.float32)) / np.sqrt(hd)
    valid = (kv_pos >= 0)[:, :, None, :]
    if causal:
        valid = valid & (kv_pos[:, :, None, :] <= q_pos[:, :, :, None])
    if window > 0:
        valid = valid & (q_pos[:, :, :, None] - kv_pos[:, :, None, :] < window)
    s = jnp.where(valid[:, :, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zero output (flash uses l=max(l,eps))
    any_valid = valid.any(axis=-1)[:, :, None, None, :, None]
    o = jnp.einsum("mbkgqc,mbckd->mbkgqd", p, v.astype(jnp.float32))
    o = jnp.where(any_valid, o, 0.0)
    return jnp.moveaxis(o, -2, 2).reshape(m, b, sq, h, hd)


def _mk(m=1, b=2, sq=32, skv=32, h=4, kvh=2, hd=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (m, b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (m, b, skv, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (m, b, skv, kvh, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("m,h,kvh", [(1, 4, 2), (3, 4, 4), (2, 8, 2)])
def test_flash_attention_causal(m, h, kvh, window):
    b, s = 2, 48
    q, k, v = _mk(m, b, s, s, h, kvh)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    out = L.flash_attention(q, k, v, pos, pos, window=window, q_chunk=16, kv_chunk=16)
    ref = _naive_attention(q, k, v, pos, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_chunk_invariance():
    q, k, v = _mk(2, 2, 64, 64, 4, 2)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 2, 64))
    o1 = L.flash_attention(q, k, v, pos, pos, q_chunk=64, kv_chunk=64)
    o2 = L.flash_attention(q, k, v, pos, pos, q_chunk=8, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_matches_prefill():
    """Decoding token-by-token through the ring-buffer cache must match
    full prefill attention at every step."""
    m, b, s, h, kvh, hd = 2, 2, 16, 4, 2, 8
    q, k, v = _mk(m, b, s, s, h, kvh, hd, seed=3)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    ref = _naive_attention(q, k, v, pos, pos)

    ck = jnp.zeros((m, b, s, kvh, hd))
    cv = jnp.zeros((m, b, s, kvh, hd))
    for t in range(s):
        pt = jnp.full((m, b), t, jnp.int32)
        ck, cv = L.cache_update_one(
            ck, cv, k[:, :, t : t + 1], v[:, :, t : t + 1], pt
        )
        kv_pos = L.cache_slot_positions(pt, s)
        out_t = L.flash_attention(
            q[:, :, t : t + 1], ck, cv, pt[..., None], kv_pos, kv_chunk=8
        )
        np.testing.assert_allclose(
            np.asarray(out_t[:, :, 0]), np.asarray(ref[:, :, t]), rtol=2e-4, atol=2e-4
        )


def test_ring_buffer_sliding_window_decode():
    """With cache size == window, ring-buffer decode == sliding-window
    attention over the full sequence."""
    m, b, s, w, h, kvh, hd = 1, 2, 24, 8, 2, 2, 4
    q, k, v = _mk(m, b, s, s, h, kvh, hd, seed=4)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    ref = _naive_attention(q, k, v, pos, pos, window=w)

    ck = jnp.zeros((m, b, w, kvh, hd))
    cv = jnp.zeros((m, b, w, kvh, hd))
    for t in range(s):
        pt = jnp.full((m, b), t, jnp.int32)
        ck, cv = L.cache_update_one(ck, cv, k[:, :, t : t + 1], v[:, :, t : t + 1], pt)
        kv_pos = L.cache_slot_positions(pt, w)
        out_t = L.flash_attention(
            q[:, :, t : t + 1], ck, cv, pt[..., None], kv_pos, window=w, kv_chunk=4
        )
        np.testing.assert_allclose(
            np.asarray(out_t[:, :, 0]), np.asarray(ref[:, :, t]), rtol=2e-4, atol=2e-4
        )


def test_cache_slot_positions():
    pos = jnp.array([[2]], jnp.int32)          # 3 tokens written, cache size 4
    p = L.cache_slot_positions(pos, 4)
    np.testing.assert_array_equal(np.asarray(p[0, 0]), [0, 1, 2, -1])
    pos = jnp.array([[5]], jnp.int32)          # wrapped: slots hold 4,5,2,3
    p = L.cache_slot_positions(pos, 4)
    np.testing.assert_array_equal(np.asarray(p[0, 0]), [4, 5, 2, 3])


def test_rope_relative_shift_invariance():
    """RoPE inner products depend only on relative positions."""
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    q = jax.random.normal(ks[0], (1, 1, 4, 2, 16))
    k = jax.random.normal(ks[1], (1, 1, 4, 2, 16))
    p0 = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (1, 1, 4))
    scores = lambda qq, kk: jnp.einsum("mbshd,mbthd->mbhst", qq, kk)
    s0 = scores(L.rope(q, p0, 1e4), L.rope(k, p0, 1e4))
    s1 = scores(L.rope(q, p0 + 100, 1e4), L.rope(k, p0 + 100, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-3, atol=1e-4)


def test_gqa_attention_merged_equals_per_instance():
    """The NetFuse invariant at the attention-block level: merged M-instance
    attention == per-instance attention."""
    m, b, s, d, h, kvh, hd = 3, 2, 16, 32, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    p = {
        "wq": jax.random.normal(ks[0], (m, d, h * hd)) * 0.1,
        "wk": jax.random.normal(ks[1], (m, d, kvh * hd)) * 0.1,
        "wv": jax.random.normal(ks[2], (m, d, kvh * hd)) * 0.1,
        "wo": jax.random.normal(ks[3], (m, h * hd, d)) * 0.1,
    }
    x = jax.random.normal(ks[4], (m, b, s, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (m, b, s))
    kw = dict(num_heads=h, num_kv_heads=kvh, head_dim=hd, rope_theta=1e4)
    out, _ = L.gqa_attention(x, p, positions=pos, **kw)
    for i in range(m):
        pi = {k_: v_[i : i + 1] for k_, v_ in p.items()}
        oi, _ = L.gqa_attention(x[i : i + 1], pi, positions=pos[i : i + 1], **kw)
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(oi[0]), rtol=2e-4, atol=2e-4
        )


def test_norms():
    m, b, d = 2, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (m, b, d))
    sc = 1 + 0.1 * jax.random.normal(ks[1], (m, d))
    bi = 0.1 * jax.random.normal(ks[2], (m, d))
    y = L.rms_norm(x, sc)
    ref = x / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-5) * np.asarray(sc)[:, None]
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=1e-4)
    y2 = L.layer_norm(x, sc, bi)
    assert y2.shape == x.shape
    # normalized-then-affine: per-row mean equals mean of bias + scale*0-mean
    xn = (np.asarray(x) - np.asarray(x).mean(-1, keepdims=True)) / np.sqrt(
        np.asarray(x).var(-1, keepdims=True) + 1e-5
    )
    ref2 = xn * np.asarray(sc)[:, None] + np.asarray(bi)[:, None]
    np.testing.assert_allclose(np.asarray(y2), ref2, rtol=2e-3, atol=1e-4)
