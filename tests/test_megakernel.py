"""Decode-layer megakernel (ISSUE 8): one Pallas call per dense layer.

The contract: the fused layer kernel (QKV+RoPE, in-kernel ring-cache
append, flash decode attention, out-proj + residual, both RMS norms,
SwiGLU) and the fused logits+greedy-sampling kernel are BIT-IDENTICAL
to the unfused path — kernel-vs-oracle at the op level, decode_step
parity at the model level, and whole greedy token streams through the
engine for K ∈ {1, 8}, sync and async, no-mesh and an 8-device mesh.

Both sides of every comparison are jitted: an eager oracle differs from
a jitted one by FMA contraction, which is an XLA artifact, not a kernel
bug — the serving engine only ever runs jitted.
"""
import functools
import os
import subprocess
import sys
import textwrap

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import registry
from repro.kernels import ops, ref
from repro.serving import AsyncEngine, MultiModelServer, Request
from repro.kernels.decode_layer import tp_head_plan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _layer_inputs(key, m, b, d, h, kvh, hd, ff, s, dt, bias=False):
    ks = jax.random.split(key, 16)
    r = lambda k, shp: (jax.random.normal(k, shp) * 0.1).astype(dt)
    lp = {
        "attn_norm": jnp.ones((m, d), dt) + r(ks[0], (m, d)),
        "wq": r(ks[1], (m, d, h * hd)),
        "wk": r(ks[2], (m, d, kvh * hd)),
        "wv": r(ks[3], (m, d, kvh * hd)),
        "wo": r(ks[4], (m, h * hd, d)),
        "mlp_norm": jnp.ones((m, d), dt) + r(ks[5], (m, d)),
        "w_gate": r(ks[6], (m, d, ff)),
        "w_up": r(ks[7], (m, d, ff)),
        "w_down": r(ks[8], (m, ff, d)),
    }
    if bias:
        lp["bq"] = r(ks[9], (m, h * hd))
        lp["bk"] = r(ks[10], (m, kvh * hd))
        lp["bv"] = r(ks[11], (m, kvh * hd))
    x = r(ks[12], (m, b, d))
    ck = r(ks[13], (m, b, s, kvh, hd))
    cv = r(ks[14], (m, b, s, kvh, hd))
    pos = jax.random.randint(ks[15], (m, b), 0, 2 * s)
    return lp, x, ck, cv, pos.astype(jnp.int32)


def _assert_layer_identical(lp, x, ck, cv, pos, **kw):
    """Kernel vs JITTED oracle, bitwise on all three outputs."""
    want = jax.jit(functools.partial(ref.decode_layer, **kw))(
        lp, x, ck, cv, pos)
    got = ops.decode_layer(lp, x, ck, cv, pos, **kw)
    for g, w, name in zip(got, want, ("x", "k_cache", "v_cache")):
        np.testing.assert_array_equal(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            err_msg=name)


# ---------------------------------------------------------------------------
# kernel vs oracle: bit-identity sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(4, 2), (4, 4), (4, 1), (8, 2)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_layer_matches_oracle(h, kvh, dt):
    """GQA, MHA (g=1 hits the XLA gemv-vs-gemm path) and MQA, both
    dtypes: the megakernel output and the appended cache are bitwise
    equal to the unfused jitted reference."""
    hd, d, ff, s = 32, 48, 96, 16
    lp, x, ck, cv, pos = _layer_inputs(
        jax.random.PRNGKey(0), 2, 3, d, h, kvh, hd, ff, s, dt)
    _assert_layer_identical(
        lp, x, ck, cv, pos, num_heads=h, head_dim=hd, rope_theta=10000.0)


@pytest.mark.parametrize("theta", [0.0, 10000.0])
def test_decode_layer_qkv_bias(theta):
    """The qwen-style biased QKV path, with and without RoPE."""
    h, kvh, hd, d, ff, s = 4, 4, 16, 32, 64, 8
    lp, x, ck, cv, pos = _layer_inputs(
        jax.random.PRNGKey(1), 2, 2, d, h, kvh, hd, ff, s,
        jnp.float32, bias=True)
    _assert_layer_identical(
        lp, x, ck, cv, pos, num_heads=h, head_dim=hd, rope_theta=theta)


def test_decode_layer_ring_wrap_at_window_boundary():
    """Positions straddling the ring wrap with a sliding window shorter
    than the cache: the in-kernel validity mask (base/slot arithmetic +
    window cut) must agree with the oracle at every position from fresh
    cache through multiple wraps."""
    h, kvh, hd, d, ff, s, window = 4, 2, 16, 32, 64, 16, 12
    lp, x, ck, cv, _ = _layer_inputs(
        jax.random.PRNGKey(2), 1, 4, d, h, kvh, hd, ff, s, jnp.float32)
    for base in (0, s - 2, s, 2 * s + 3):
        pos = (base + jnp.arange(4, dtype=jnp.int32)[None]).reshape(1, 4)
        _assert_layer_identical(
            lp, x, ck, cv, pos, num_heads=h, head_dim=hd,
            rope_theta=10000.0, window=window)


def test_logits_sample_matches_oracle_with_ties():
    """Fused final-norm + unembed + argmax picks the SAME token as
    jnp.argmax over the f32 logits — including first-occurrence
    tie-breaking forced by duplicated vocab columns (and a vocab size
    that is prime, so the V-blocking clamps to one block)."""
    m, b, d, v = 2, 3, 32, 257
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (m, b, d))
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (m, d))
    head = jax.random.normal(ks[2], (m, d, v))
    head = head.at[:, :, 100].set(head[:, :, 7])   # exact ties
    head = head.at[:, :, 255].set(head[:, :, 7])
    want = jax.jit(functools.partial(ref.logits_sample))(x, scale, head)
    got = ops.logits_sample(x, scale, head)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_tp_head_plan():
    """The shared head-grouping recipe (megakernel + decode_attn)."""
    assert tp_head_plan(8, 4, 1) is None      # no model axis
    assert tp_head_plan(6, 2, 4) is None      # q heads don't split
    assert tp_head_plan(8, 4, 4) == "kv"      # kv groups split cleanly
    assert tp_head_plan(8, 4, 2) == "kv"
    assert tp_head_plan(8, 1, 4) == "expand"  # MQA: expand then split
    assert tp_head_plan(8, 2, 4) == "expand"


# ---------------------------------------------------------------------------
# model-level: decode_step / decode_step_sample parity per family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen1.5-0.5b",
                                  "hymba-1.5b"])
def test_decode_step_parity(arch):
    """use_pallas_kernels=True decode_step is bitwise the unfused one
    (hybrid routes only its global-attention layers through the fused
    attention kernel; dense/vlm take the full megakernel scan)."""
    mc = 192 if arch == "hymba-1.5b" else 48
    cfg = registry.get_smoke_config(arch).with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    tok = jnp.array([[1, 2], [3, 4]], jnp.int32)[..., None]
    cache = api.make_cache(cfg, 2, 2, mc)
    pos = jnp.array([[5, 9], [0, 17]], jnp.int32)

    run = lambda f: jax.jit(functools.partial(api.decode_step, cfg.with_(
        use_pallas_kernels=f)))(params, cache, tok, pos)
    logits_u, cache_u = run(False)
    logits_f, cache_f = run(True)
    np.testing.assert_array_equal(np.asarray(logits_f, np.float32),
                                  np.asarray(logits_u, np.float32))
    for a, b in zip(jax.tree.leaves(cache_f), jax.tree.leaves(cache_u)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # fused sampling == argmax over the unfused logits
    tok_f, _ = jax.jit(functools.partial(api.decode_step_sample, cfg.with_(
        use_pallas_kernels=True)))(params, cache, tok, pos)
    np.testing.assert_array_equal(
        np.asarray(tok_f), np.asarray(jnp.argmax(logits_u, -1), np.int32))


# ---------------------------------------------------------------------------
# engine-level: greedy streams bit-identical, megakernel vs unfused
# ---------------------------------------------------------------------------


def _build(arch, m=2, **over):
    cfg = registry.get_smoke_config(arch).with_(num_instances=m, **over)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("slots_per_instance", 2)
    kw.setdefault("max_context", 48)
    kw.setdefault("temperature", 0.0)
    return MultiModelServer(cfg, params, **kw)


def _reqs():
    # mixed budgets: lanes die mid-block under K=8, so the in-kernel
    # cache append runs under the dead-lane alive-mask
    return [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=7),
        Request(instance=1, prompt=[4, 5], max_new_tokens=5),
        Request(instance=0, prompt=[7], max_new_tokens=3),
        Request(instance=1, prompt=[3, 3, 3, 3, 3], max_new_tokens=6),
        Request(instance=0, prompt=[2, 2], max_new_tokens=4),
        Request(instance=1, prompt=[9, 8, 7], max_new_tokens=8),
    ]


def _drain(server, reqs):
    for r in reqs:
        server.submit(Request(r.instance, list(r.prompt), r.max_new_tokens))
    return {r.request_id: r.tokens for r in server.run_until_drained()}


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen1.5-0.5b"])
@pytest.mark.parametrize("k", [1, 8])
def test_greedy_streams_identical_megakernel_vs_unfused(arch, k):
    """Whole greedy streams, token for token, K ∈ {1, 8}: the fused
    decode-layer scan + fused sampling vs the per-op path.  K=8 with
    mixed budgets exercises dead-lane freezing with in-kernel append."""
    cfg, params = _build(arch)
    want = _drain(
        _server(cfg.with_(use_pallas_kernels=False), params,
                decode_steps=k), _reqs())
    assert want and all(len(t) > 0 for t in want.values())
    got = _drain(
        _server(cfg.with_(use_pallas_kernels=True), params,
                decode_steps=k), _reqs())
    assert got == want


def test_greedy_streams_identical_hybrid():
    """hymba rides the fused attention kernel only on its global-attn
    layers — streams still bitwise match the unfused engine."""
    cfg, params = _build("hymba-1.5b")
    run = lambda f: _drain(
        _server(cfg.with_(use_pallas_kernels=f), params,
                max_context=192, decode_steps=4), _reqs())
    want = run(False)
    assert want and all(len(t) > 0 for t in want.values())
    assert run(True) == want


def test_greedy_streams_identical_async():
    """The async frontend over a megakernel K=4 engine streams exactly
    the unfused sync K=1 tokens."""
    import asyncio

    cfg, params = _build("tinyllama-1.1b")
    want = _drain(
        _server(cfg.with_(use_pallas_kernels=False), params,
                decode_steps=1), _reqs())

    async def run(server, reqs):
        engine = AsyncEngine(server)

        async def client(r):
            stream = await engine.submit(
                Request(r.instance, list(r.prompt), r.max_new_tokens))
            toks = [t async for t in stream]
            res = await stream.result()
            assert res.status == "ok"
            return stream.request_id, toks

        out = await asyncio.gather(*(client(r) for r in reqs))
        await engine.aclose()
        return dict(out)

    got = asyncio.run(run(
        _server(cfg.with_(use_pallas_kernels=True), params,
                decode_steps=4), _reqs()))
    assert got == want


# ---------------------------------------------------------------------------
# 8-device mesh subprocess: sharded megakernel parity
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_megakernel_streams_identical_on_mesh():
    """No-mesh unfused == 8-device mesh megakernel, K ∈ {1, 8}, on both
    mesh shapes: (2, 4) forces the data-local shard_map fallback (kv
    heads don't split 4 ways) and (4, 2) takes the 2-phase TP split."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro import api
        from repro.configs import registry
        from repro.serving import MultiModelServer, Request

        assert len(jax.devices()) == 8, jax.devices()

        M = 2
        cfg = registry.get_smoke_config("tinyllama-1.1b").with_(
            num_instances=M, dtype="float32", param_dtype="float32")
        params = api.init(cfg, jax.random.PRNGKey(0))

        def serve(mesh, K, fused):
            srv = MultiModelServer(
                cfg.with_(use_pallas_kernels=fused), params,
                slots_per_instance=2, max_context=64,
                mesh=mesh, decode_steps=K, temperature=0.0)
            rng = np.random.default_rng(0)
            for i in range(6):
                prompt = rng.integers(
                    1, cfg.vocab_size, size=int(rng.integers(2, 8))).tolist()
                srv.submit(Request(instance=i % M, prompt=prompt,
                                   max_new_tokens=4 + (i % 3)))
            res = sorted(srv.run_until_drained(), key=lambda r: r.request_id)
            return [r.tokens for r in res]

        ref = serve(None, 1, False)
        assert all(len(t) > 0 for t in ref), ref
        for shape in ((2, 4), (4, 2)):
            mesh = jax.make_mesh(shape, ("data", "model"))
            assert serve(mesh, 1, True) == ref, shape
            assert serve(mesh, 8, True) == ref, shape
        print("megakernel mesh streams OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "megakernel mesh streams OK" in r.stdout


@pytest.mark.slow
def test_decode_attention_sharded_gqa_mqa():
    """Satellite 1: decode_attention_sharded under every tp_head_plan
    branch — "kv" (GQA groups split), "expand" (MQA), and the
    data-local fallback (q heads don't split) — bitwise equal to the
    plain kernel with no mesh."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels.decode_attn import (
            decode_attention, decode_attention_sharded)
        from repro.kernels.decode_layer import tp_head_plan
        from repro.launch.shardings import serve_rules

        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = serve_rules(mesh)

        cases = {(8, 4): "kv", (8, 1): "expand", (2, 1): None}
        for (h, kvh), plan in cases.items():
            assert tp_head_plan(h, kvh, 4) == plan, (h, kvh)
            m, b, s, hd = 2, 4, 32, 16
            ks = jax.random.split(jax.random.PRNGKey(h * 10 + kvh), 4)
            q = jax.random.normal(ks[0], (m, b, h, hd))
            k = jax.random.normal(ks[1], (m, b, s, kvh, hd))
            v = jax.random.normal(ks[2], (m, b, s, kvh, hd))
            kv_len = jax.random.randint(ks[3], (m, b), 1, s + 1)
            want = decode_attention(q, k, v, kv_len)
            with jax.set_mesh(mesh), rules:
                got = decode_attention_sharded(
                    q, k, v, kv_len, rules=rules)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want), err_msg=str((h, kvh)))
        print("sharded gqa/mqa decode attention OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "sharded gqa/mqa decode attention OK" in r.stdout
