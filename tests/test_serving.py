"""Serving subsystem tests: scheduler policies, on-device sampling,
chunked prefill, slot surgery, and end-to-end continuous batching for
both KV-cache and recurrent-state families."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import MultiModelServer, Request, sample_tokens
from repro.serving.prefill import ChunkedPrefill
from repro.serving.scheduler import (
    FIFOScheduler, RoundRobinScheduler, TokenBudgetScheduler,
)


def _req(instance, prompt, **kw):
    return Request(instance=instance, prompt=prompt, **kw)


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------


def test_fifo_admits_in_arrival_order():
    s = FIFOScheduler(2)
    a, b, c = _req(1, [1]), _req(0, [2]), _req(1, [3])
    for r in (a, b, c):
        s.submit(r)
    got = s.select({0: 2, 1: 2})
    assert got == [a, b, c]
    assert s.total_pending() == 0


def test_fifo_full_row_does_not_block_other_instances():
    s = FIFOScheduler(2)
    a, b = _req(0, [1]), _req(1, [2])
    s.submit(a)
    s.submit(b)
    # instance 0 has no free slots: its head request stays queued, the
    # younger instance-1 request is admitted anyway
    got = s.select({0: 0, 1: 1})
    assert got == [b]
    assert s.depth(0) == 1


def test_round_robin_cycles_instances():
    s = RoundRobinScheduler(3)
    reqs = [_req(0, [i]) for i in range(3)] + [_req(1, [9])]
    for r in reqs:
        s.submit(r)
    got = s.select({0: 3, 1: 3, 2: 3})
    # first pass takes one per non-empty instance before seconds
    assert [r.instance for r in got[:2]] == [0, 1]
    assert [r.instance for r in got[2:]] == [0, 0]


def test_round_robin_lane_limit_does_not_freeze_rotation():
    """A scarce admission limit (free prefill lanes) must not pin the
    rotation: the interrupted pass resumes at the next instance."""
    s = RoundRobinScheduler(2)
    for i in range(2):
        s.submit(_req(0, [i]))
        s.submit(_req(1, [i]))
    first = s.select({0: 2, 1: 2}, limit=1)
    second = s.select({0: 2, 1: 2}, limit=1)
    assert [r.instance for r in first + second] == [0, 1]


def test_token_budget_prefers_underserved_instance():
    s = TokenBudgetScheduler(2)
    s.note_generated(0, 100)            # instance 0 already got 100 tokens
    a, b = _req(0, [1, 1]), _req(1, [2, 2])
    s.submit(a)
    s.submit(b)
    got = s.select({0: 1, 1: 1})
    assert got[0] is b                   # underserved instance first
    # prompt charged at admission: next tie-break reflects it
    assert s.served[1] == 2


def test_token_budget_long_prompt_yields():
    s = TokenBudgetScheduler(2)
    for r in (_req(0, [0] * 50), _req(0, [1]), _req(1, [2]), _req(1, [3])):
        s.submit(r)
    got = s.select({0: 2, 1: 2})
    # the 50-token prompt charges instance 0, so both instance-1 requests
    # are admitted before instance 0's second request
    assert [r.instance for r in got] == [0, 1, 1, 0]


# ---------------------------------------------------------------------------
# on-device sampling
# ---------------------------------------------------------------------------


def test_greedy_sampling_matches_host_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 17))
    got = np.asarray(sample_tokens(logits, jax.random.PRNGKey(1), temperature=0.0))
    want = np.argmax(np.asarray(logits), axis=-1)
    np.testing.assert_array_equal(got, want)


def test_temperature_sampling_matches_per_slot_host_path():
    """The batched on-device sampler must equal the old per-slot host
    loop: fold the slot index into one key, categorical per slot."""
    m, b, v = 2, 3, 23
    logits = jax.random.normal(jax.random.PRNGKey(0), (m, b, v))
    key = jax.random.PRNGKey(7)
    temp = 0.7
    got = np.asarray(sample_tokens(logits, key, temperature=temp))
    for i in range(m):
        for j in range(b):
            k = jax.random.fold_in(key, jnp.uint32(i * b + j))
            want = int(jax.random.categorical(
                k, logits[i, j].astype(jnp.float32) / temp
            ))
            assert got[i, j] == want, (i, j)


def test_top_k_sampling_stays_in_top_k():
    m, b, v, k = 2, 4, 50, 5
    logits = jax.random.normal(jax.random.PRNGKey(3), (m, b, v))
    top = np.argsort(np.asarray(logits), axis=-1)[..., -k:]
    for seed in range(5):
        got = np.asarray(sample_tokens(
            logits, jax.random.PRNGKey(seed), temperature=1.0, top_k=k
        ))
        for i in range(m):
            for j in range(b):
                assert got[i, j] in top[i, j]


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_per_request_prefill():
    """Chunked, lane-batched, cross-instance prefill must write the same
    cache prefix as an exact-length per-request prefill (the chunked
    runtime processes prompt[:-1]; the engine re-decodes the last prompt
    token as its first fused grid step)."""
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=3)
    params = api.init(cfg, jax.random.PRNGKey(0))
    max_context = 32
    cp = ChunkedPrefill(cfg, max_context=max_context, chunk=4, lanes=4)
    prompts = [(0, [5, 6, 7]), (2, [9, 8, 7, 6, 5, 4]), (1, [3])]
    reqs = [_req(i, p) for i, p in prompts]
    outs = cp.run(params, reqs)
    assert cp.compiled_shapes == 1      # one folded chunk shape, all lengths

    ax = api.axes(cfg)
    for req, out in zip(reqs, outs):
        l = len(req.prompt) - 1         # chunked prefill stops before last token
        pi = C.take_instance(params, ax, req.instance)
        toks = jnp.asarray(req.prompt, jnp.int32)[None, None]
        _, exact = api.prefill(cfg, pi, {"tokens": toks}, cache_len=max_context)
        got = jax.tree.map(lambda t: t[:, out.index], out.cache)
        for g, e in zip(jax.tree.leaves(got), jax.tree.leaves(exact)):
            np.testing.assert_allclose(
                np.asarray(g[:, 0, :l], np.float32),
                np.asarray(e[:, 0, 0, :l], np.float32),
                rtol=2e-5, atol=2e-5,
            )
        assert out.pos == len(req.prompt) - 1
        assert out.last_token == req.prompt[-1]


def test_prefill_compiles_bounded_single_shape():
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    cp = ChunkedPrefill(cfg, max_context=64, chunk=4, lanes=2)
    # 7 distinct prompt lengths -> exactly ONE shape (the folded chunk;
    # tails ride padded final chunks), never a per-length compile
    for l in (1, 2, 3, 5, 9, 13, 21):
        cp.run(params, [_req(0, list(range(1, l + 1)))])
    assert cp.compiled_shapes == 1


# ---------------------------------------------------------------------------
# slot surgery (take_state / put_state)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-1.3b"])
def test_take_put_state_roundtrip(arch):
    cfg = registry.get_smoke_config(arch).with_(num_instances=2)
    grid = api.make_cache(cfg, 2, 2, 16)
    # fill with distinguishable values
    cnt = iter(range(1, 10_000))
    grid = jax.tree.map(lambda t: t + next(cnt), grid)
    one = api.take_state(cfg, grid, 1, 0)
    for leaf in jax.tree.leaves(one):
        assert 1 in leaf.shape
    empty = jax.tree.map(jnp.zeros_like, grid)
    back = api.put_state(cfg, empty, one, 0, 1)
    roundtrip = api.take_state(cfg, back, 0, 1)
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(roundtrip)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------


def _drain_and_check(arch, max_context=48, oracle=True, **server_kw):
    cfg = registry.get_smoke_config(arch).with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    server = MultiModelServer(
        cfg, params, slots_per_instance=2, max_context=max_context,
        temperature=0.0, **server_kw,
    )
    reqs = [
        _req(0, [1, 2, 3], max_new_tokens=4),
        _req(1, [4, 5], max_new_tokens=4),
        _req(0, [7], max_new_tokens=3),            # 1-token prompt edge
        _req(1, [3, 3, 3, 3, 3], max_new_tokens=3),
        _req(0, [2, 2], max_new_tokens=3),         # forces slot reuse
    ]
    ids = [server.submit(r) for r in reqs]
    results = {r.request_id: r for r in server.run_until_drained()}
    assert set(results) == set(ids)
    if oracle:
        fam = api.family_module(cfg)
        ax = api.axes(cfg)
        for req, rid in zip(reqs, ids):
            pi = C.take_instance(params, ax, req.instance)
            toks, out = list(req.prompt), []
            for _ in range(req.max_new_tokens):
                logits = fam.forward(cfg, pi, jnp.asarray(toks, jnp.int32)[None, None])
                nxt = int(jnp.argmax(logits[0, 0, -1]))
                out.append(nxt)
                toks.append(nxt)
            assert results[rid].tokens == out, (rid, results[rid].tokens, out)
    return server, reqs, results


def test_ssm_serving_end_to_end_matches_isolated_decode():
    """Recurrent-state slot surgery: fused xLSTM serving must equal each
    instance's isolated greedy decode (chunked prefill is exact)."""
    _drain_and_check("xlstm-1.3b", prefill_chunk=3)


@pytest.mark.slow
def test_hybrid_serving_smoke():
    """Hymba serving (meta tokens + SWA ring + mamba states) drains."""
    server, _, results = _drain_and_check("hymba-1.5b", max_context=200, oracle=False)
    assert all(len(r.tokens) > 0 for r in results.values())


def test_moe_serving_smoke():
    server, _, results = _drain_and_check("olmoe-1b-7b", oracle=False)
    assert sum(len(r.tokens) for r in results.values()) == 4 + 4 + 3 + 3 + 3


def test_one_device_call_per_engine_step():
    """A decode step is exactly ONE device call (jitted decode+sample);
    no per-slot host-side sampling."""
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    server = MultiModelServer(
        cfg, params, slots_per_instance=2, max_context=32, temperature=0.5,
    )
    calls = {"n": 0}
    inner = server._step

    def counting_step(*a, **k):
        calls["n"] += 1
        return inner(*a, **k)

    server._step = counting_step
    for i in range(6):
        server.submit(_req(i % 2, [1 + i, 2, 3], max_new_tokens=5))
    server.run_until_drained()
    assert server.steps > 0
    assert calls["n"] == server.steps


def test_metrics_snapshot_and_fifo_accounting():
    server, reqs, results = _drain_and_check("tinyllama-1.1b")
    snap = server.metrics.snapshot()
    gen = sum(len(r.tokens) for r in results.values())
    assert snap["generated_tokens"] == gen
    assert snap["decode_steps"] == server.steps
    per = snap["instances"]
    assert [p["submitted"] for p in per] == [3, 2]
    assert [p["completed"] for p in per] == [3, 2]
    assert all(p["queue_depth"] == 0 for p in per)
    assert all(p["mean_ttft_s"] is not None for p in per)
    assert server.metrics.format_table()


def test_token_budget_policy_serves_all():
    server, _, results = _drain_and_check(
        "tinyllama-1.1b", scheduler="token-budget"
    )
    assert len(results) == 5
