"""Async streaming frontend tests (stdlib asyncio — no pytest-asyncio).

The ISSUE-5 contract: N concurrent async clients with greedy sampling
receive token streams bit-identical to the same requests submitted
through the synchronous ``run_until_drained`` path (dense + one
recurrent family, no-mesh and 8-device CPU mesh), while the engine
still issues exactly ONE device call per decode step; cancellation
frees the slot / prefill lane / queue entry so the next step refills it
from the queues; bounded queues backpressure with a depth signal; TTL
expiry and submit-time rejection produce terminal Results like every
other outcome; and the HTTP layer streams SSE, cancels on disconnect,
and reports percentile metrics.

Each test drives its own event loop via ``asyncio.run`` inside a plain
sync test function, so no async test plugin is needed.
"""
import asyncio
import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax

from repro import api
from repro.configs import registry
from repro.serving import (
    AsyncEngine,
    Backpressure,
    EngineClosed,
    MultiModelServer,
    Request,
    start_http_server,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(arch, m=2):
    cfg = registry.get_smoke_config(arch).with_(num_instances=m)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("slots_per_instance", 2)
    kw.setdefault("max_context", 48)
    kw.setdefault("temperature", 0.0)
    return MultiModelServer(cfg, params, **kw)


def _reqs():
    return [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4),
        Request(instance=1, prompt=[4, 5], max_new_tokens=4),
        Request(instance=0, prompt=[7], max_new_tokens=3),
        Request(instance=1, prompt=[3, 3, 3, 3, 3], max_new_tokens=3),
        Request(instance=0, prompt=[2, 2], max_new_tokens=3),
        Request(instance=1, prompt=[9, 8, 7], max_new_tokens=4),
    ]


async def _stream_all(server, reqs, **engine_kw):
    """N concurrent clients, one per request; returns {request_id:
    (streamed_tokens, Result)} plus the engine for inspection."""
    engine = AsyncEngine(server, **engine_kw)

    async def client(r):
        stream = await engine.submit(r)
        toks = [t async for t in stream]
        return stream.request_id, toks, await stream.result()

    out = await asyncio.gather(*(client(r) for r in reqs))
    await engine.aclose()
    return {rid: (toks, res) for rid, toks, res in out}


# ---------------------------------------------------------------------------
# determinism: async streams == sync run_until_drained, one call per step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-1.3b"])
def test_async_streams_bit_identical_to_sync(arch):
    """Concurrent async clients see exactly the tokens the synchronous
    path produces (greedy, dense + recurrent family), the streamed
    tokens equal the terminal Result's, and the driver still issues
    exactly ONE fused device call per decode step."""
    cfg, params = _build(arch)
    sync = _server(cfg, params)
    for r in _reqs():
        sync.submit(Request(r.instance, list(r.prompt), r.max_new_tokens))
    want = {r.request_id: r.tokens for r in sync.run_until_drained()}

    server = _server(cfg, params)
    calls = {"n": 0}
    inner = server._step

    def counting_step(*a, **k):
        calls["n"] += 1
        return inner(*a, **k)

    server._step = counting_step
    got = asyncio.run(_stream_all(server, _reqs()))
    assert set(got) == set(want)
    for rid, (toks, res) in got.items():
        assert res.status == "ok"
        assert toks == res.tokens
        assert toks == want[rid], (rid, toks, want[rid])
    assert server.steps > 0 and calls["n"] == server.steps


@pytest.mark.slow
def test_async_streams_identical_under_mesh():
    """Same contract on a forced 8-CPU-device (data=2, model=4) mesh:
    the async frontend sits strictly above the mesh-parametric engine,
    so sharded greedy streams match the no-mesh sync baseline for a
    dense and a recurrent family (subprocess harness as in
    test_serving_sharded.py)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import asyncio
        import jax
        import numpy as np
        from repro import api
        from repro.configs import registry
        from repro.models import common as C
        from repro.serving import AsyncEngine, MultiModelServer, Request

        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        M = 2

        def build(arch):
            cfg1 = registry.get_smoke_config(arch).with_(
                num_instances=1, dtype="float32", param_dtype="float32")
            cfg = cfg1.with_(num_instances=M)
            keys = jax.random.split(jax.random.PRNGKey(0), M)
            merged = C.merge_instances(
                [api.init(cfg1, k) for k in keys], api.axes(cfg1))
            return cfg, merged

        def mk_reqs(cfg, n=5, max_new=4):
            rng = np.random.default_rng(0)
            return [Request(instance=i % M,
                            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(2, 8))).tolist(),
                            max_new_tokens=max_new) for i in range(n)]

        async def astream(server, reqs):
            engine = AsyncEngine(server)
            async def client(r):
                s = await engine.submit(r)
                toks = [t async for t in s]
                res = await s.result()
                assert res.status == "ok" and toks == res.tokens
                return s.request_id, toks
            out = dict(await asyncio.gather(*(client(r) for r in reqs)))
            await engine.aclose()
            return out

        for arch in ("tinyllama-1.1b", "xlstm-1.3b"):
            cfg, merged = build(arch)
            sync = MultiModelServer(cfg, merged, slots_per_instance=2,
                                    max_context=64)
            for r in mk_reqs(cfg):
                sync.submit(r)
            want = {r.request_id: r.tokens for r in sync.run_until_drained()}
            assert all(want.values())
            meshed = MultiModelServer(cfg, merged, slots_per_instance=2,
                                      max_context=64, mesh=mesh)
            got = asyncio.run(astream(meshed, mk_reqs(cfg)))
            assert got == want, (arch, got, want)
            print(arch, "async mesh streams OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "xlstm-1.3b async mesh streams OK" in r.stdout


# ---------------------------------------------------------------------------
# cancellation at every lifecycle stage
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_frees_slot_and_next_step_refills():
    """Cancelling a decoding request frees its grid slot immediately;
    the very next engine step admits the queued successor into it."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)
    victim = Request(instance=0, prompt=[1, 2, 3], max_new_tokens=64)
    waiter = Request(instance=0, prompt=[4, 5], max_new_tokens=3)
    vid = server.submit(victim)
    wid = server.submit(waiter)
    while not server.generated.get(vid):
        server.step()                      # victim is now decoding
    assert server.scheduler.depth(0) == 1  # waiter still queued
    res = server.cancel(vid)
    assert res is not None and res.status == "cancelled"
    assert res.tokens and res.request_id == vid
    assert not server.slot_busy[0, 0]      # slot freed within the cancel
    server.step()                          # next step refills from the queue
    assert server.slot_busy[0, 0]
    assert server.active[0][0].request_id == wid
    done = {r.request_id: r for r in server.run_until_drained()}
    assert done[wid].status == "ok" and len(done[wid].tokens) == 3
    # cancelled request is gone for good
    assert server.cancel(vid) is None


def test_cancel_mid_prefill_frees_lane_and_reserved_slot():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1, prefill_chunk=2,
                     chunk_budget=1, max_context=64)
    long = Request(instance=0, prompt=list(range(1, 33)), max_new_tokens=2)
    lid = server.submit(long)
    server.step()                          # admitted to a lane, still prefilling
    assert server.slot_prefilling[0, 0] and server.prefill.in_flight() == 1
    res = server.cancel(lid)
    assert res is not None and res.status == "cancelled" and res.tokens == []
    assert server.prefill.in_flight() == 0
    assert not server.slot_busy[0, 0] and not server.slot_prefilling[0, 0]
    # the freed lane serves the next request exactly
    after = Request(instance=0, prompt=[5, 6, 7], max_new_tokens=3)
    server.submit(after)
    done = server.run_until_drained()
    assert len(done) == 1 and done[0].status == "ok" and len(done[0].tokens) == 3


def test_cancel_mid_queue_and_async_terminal_results():
    """Async cancel of a queued request yields a terminal cancelled
    Result with no tokens; the other requests are untouched."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server)
        blocker = await engine.submit(
            Request(instance=0, prompt=[1, 2, 3], max_new_tokens=6))
        queued = await engine.submit(
            Request(instance=0, prompt=[4, 5], max_new_tokens=4))
        assert await queued.cancel()
        res_q = await queued.result()
        res_b = await blocker.result()
        assert not await queued.cancel()   # already terminal
        await engine.aclose()
        return res_q, res_b

    res_q, res_b = asyncio.run(run())
    assert res_q.status == "cancelled" and res_q.tokens == []
    assert res_b.status == "ok" and len(res_b.tokens) == 6


# ---------------------------------------------------------------------------
# backpressure / TTL / rejection
# ---------------------------------------------------------------------------


def test_backpressure_bounded_queue_rejects_and_awaits():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server, max_queue_depth=1)
        # slots=1: the first request occupies the slot, the second sits
        # queued at the bound, so a third non-waiting submit must bounce
        first = await engine.submit(
            Request(instance=0, prompt=[1, 2], max_new_tokens=24))
        # wait until the first request actually holds the slot (its
        # queue entry is gone) so the queued depth below is exactly 1
        while server.scheduler.depth(0) > 0 or not server.slot_busy[0, 0]:
            await asyncio.sleep(0.005)
        second = await engine.submit(
            Request(instance=0, prompt=[3, 4], max_new_tokens=2))
        try:
            await engine.submit(
                Request(instance=0, prompt=[5], max_new_tokens=2), wait=False)
            raised = None
        except Backpressure as e:
            raised = e
        assert raised is not None
        assert raised.instance == 0
        assert raised.depth >= 1 and raised.limit == 1
        # other instances are not throttled by instance 0's queue
        other = await engine.submit(
            Request(instance=1, prompt=[6], max_new_tokens=2), wait=False)
        # wait=True: parks until the queue drains, then admits
        third = await engine.submit(
            Request(instance=0, prompt=[5], max_new_tokens=2), wait=True)
        results = [await s.result() for s in (first, second, third, other)]
        await engine.aclose()
        return results

    results = asyncio.run(run())
    assert [r.status for r in results] == ["ok"] * 4


def test_ttl_expiry_returns_expired_result():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server)
        blocker = await engine.submit(
            Request(instance=0, prompt=[1, 2], max_new_tokens=12))
        doomed = await engine.submit(
            Request(instance=0, prompt=[3, 4], max_new_tokens=4), ttl_s=0.0)
        res_d = await doomed.result()
        res_b = await blocker.result()
        await engine.aclose()
        return res_d, res_b

    res_d, res_b = asyncio.run(run())
    assert res_d.status == "expired" and res_d.tokens == []
    assert res_d.error == "deadline exceeded"
    assert res_b.status == "ok" and len(res_b.tokens) == 12


def test_submit_validation_same_for_sync_raise_and_async_result():
    """The satellite contract: empty prompts and too-long prompts go
    through ONE validation path — the sync API raises, the async API
    returns an already-terminal rejected stream, with the same
    messages."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, max_context=32)
    bad = [
        Request(instance=0, prompt=[], max_new_tokens=4),
        Request(instance=0, prompt=list(range(1, 200)), max_new_tokens=4),
        Request(instance=7, prompt=[1], max_new_tokens=4),
        Request(instance=0, prompt=[1], max_new_tokens=0),
    ]
    sync_errors = []
    for r in bad:
        with pytest.raises(ValueError) as ei:
            server.submit(Request(r.instance, list(r.prompt), r.max_new_tokens))
        sync_errors.append(str(ei.value))

    async def run():
        engine = AsyncEngine(server)
        out = []
        for r in bad:
            stream = await engine.submit(
                Request(r.instance, list(r.prompt), r.max_new_tokens))
            assert [t async for t in stream] == []
            out.append(await stream.result())
        # a valid request on the same engine still serves
        ok = await engine.submit(Request(instance=0, prompt=[1, 2],
                                         max_new_tokens=2))
        res = await ok.result()
        await engine.aclose()
        return out, res

    rejected, ok = asyncio.run(run())
    assert [r.status for r in rejected] == ["rejected"] * 4
    assert [r.error for r in rejected] == sync_errors
    assert ok.status == "ok" and len(ok.tokens) == 2
    snap = server.metrics.snapshot()
    assert snap["rejected"] == 6   # 3 sync + 3 async on instance 0
    assert snap["instances"][0]["rejected"] == 6


def test_finish_reason_distinguishes_eos_from_length():
    """An EOS-terminated decode reports finish_reason "stop"; a
    max_new_tokens-terminated one reports "length" (what the HTTP layer
    surfaces to OpenAI-style clients)."""
    cfg, params = _build("tinyllama-1.1b")
    ref = _server(cfg, params)
    rid = ref.submit(Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4))
    toks = {r.request_id: r for r in ref.run_until_drained()}[rid].tokens
    assert len(toks) == 4

    server = _server(cfg, params, eos_id=toks[1])
    a = server.submit(Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4))
    b = server.submit(Request(instance=1, prompt=[4, 5], max_new_tokens=4))
    res = {r.request_id: r for r in server.run_until_drained()}
    assert res[a].tokens == toks[:2]          # stopped AT the eos token
    assert res[a].finish_reason == "stop"
    assert toks[1] not in res[b].tokens       # (other stream avoids eos)
    assert res[b].finish_reason == "length"


def test_submit_after_close_raises():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    async def run():
        engine = AsyncEngine(server)
        s = await engine.submit(Request(instance=0, prompt=[1], max_new_tokens=2))
        await s.result()
        await engine.drain()
        with pytest.raises(EngineClosed):
            await engine.submit(Request(instance=0, prompt=[2], max_new_tokens=2))

    asyncio.run(run())


def test_aclose_without_drain_cancels_live_requests():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server)
        a = await engine.submit(Request(instance=0, prompt=[1, 2],
                                        max_new_tokens=40))
        b = await engine.submit(Request(instance=0, prompt=[3],
                                        max_new_tokens=4))
        # let the first request start decoding before tearing down
        async for _ in a:
            break
        await engine.aclose(drain=False)
        return await a.result(), await b.result()

    res_a, res_b = asyncio.run(run())
    assert res_a.status == "cancelled" and len(res_a.tokens) >= 1
    assert res_b.status == "cancelled"
    assert not server.busy()


# ---------------------------------------------------------------------------
# scheduler fairness under churn (satellite)
# ---------------------------------------------------------------------------


def test_token_budget_never_starves_under_cancellation_churn():
    """Property-style: under token-budget admission with requests being
    cancelled mid-queue and mid-decode at every step, every instance
    still completes all of its surviving requests, every cancel frees
    its slot within the step, and freed slots are refilled from the
    queues on the next step."""
    cfg, params = _build("tinyllama-1.1b", m=3)
    for seed in range(3):
        server = _server(cfg, params, slots_per_instance=1,
                         scheduler="token-budget", max_context=64)
        import numpy as np
        rng = np.random.default_rng(seed)
        reqs = [
            Request(instance=i % 3,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=int(rng.integers(1, 7))).tolist(),
                    max_new_tokens=int(rng.integers(2, 6)))
            for i in range(15)
        ]
        ids = [server.submit(r) for r in reqs]
        by_id = dict(zip(ids, reqs))
        cancelled, done = set(), {}
        steps = 0
        while server.busy() and steps < 500:
            # churn: cancel a random queued request and, sometimes, a
            # random decoding one
            queued = [
                r.request_id
                for q in server.scheduler.queues for r in q
            ]
            if queued and rng.random() < 0.5:
                rid = int(rng.choice(queued))
                res = server.cancel(rid)
                assert res is not None and res.status == "cancelled"
                cancelled.add(rid)
            decoding = [
                r.request_id
                for row in server.active for r in row
                if r is not None and server.generated.get(r.request_id)
            ]
            if decoding and rng.random() < 0.25:
                rid = int(rng.choice(decoding))
                m = by_id[rid].instance
                b = next(bb for bb in range(server.b)
                         if server.active[m][bb] is not None
                         and server.active[m][bb].request_id == rid)
                res = server.cancel(rid)
                assert res is not None and res.status == "cancelled"
                assert not server.slot_busy[m, b]   # freed within the step
                cancelled.add(rid)
            for r in server.step():
                done[r.request_id] = r
            steps += 1
        assert not server.busy(), "churned workload did not drain"
        # every surviving request completed with its full token budget —
        # no instance was starved by churn on the others
        survivors = [rid for rid in ids if rid not in cancelled]
        assert set(done) == set(survivors)
        for rid in survivors:
            assert done[rid].status == "ok"
            assert len(done[rid].tokens) == by_id[rid].max_new_tokens
        per_inst = {i: sum(1 for rid in survivors if by_id[rid].instance == i)
                    for i in range(3)}
        for i, n in per_inst.items():
            got = sum(1 for rid in done if by_id[rid].instance == i)
            assert got == n, (seed, i, got, n)


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------


async def _http_post(port, path, payload):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write(
        f"POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json"
        f"\r\nContent-Length: {len(body)}\r\n\r\n".encode() + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, rest = raw.partition(b"\r\n\r\n")
    return head.decode("latin-1"), rest


async def _http_get(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, rest = raw.partition(b"\r\n\r\n")
    return head.decode("latin-1"), rest


def _sse_events(rest: bytes):
    out = []
    for line in rest.split(b"\n\n"):
        if line.startswith(b"data: ") and line != b"data: [DONE]":
            out.append(json.loads(line[len(b"data: "):]))
    return out


def test_http_completions_sse_matches_sync_and_metrics():
    """POST /v1/completions with stream=true delivers exactly the sync
    engine's greedy tokens as SSE chunks (finish_reason on the last),
    the non-stream flavor returns them in one JSON body, and
    GET /metrics carries the TTFT/ITL percentile blocks."""
    cfg, params = _build("tinyllama-1.1b")
    sync = _server(cfg, params)
    sid = sync.submit(Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4))
    want = {r.request_id: r.tokens for r in sync.run_until_drained()}[sid]

    server = _server(cfg, params)

    async def run():
        engine = AsyncEngine(server)
        http = await start_http_server(engine, port=0)
        port = http.sockets[0].getsockname()[1]

        head, rest = await _http_post(port, "/v1/completions", {
            "model": "model-0", "prompt": [1, 2, 3], "max_tokens": 4,
            "stream": True,
        })
        assert head.startswith("HTTP/1.1 200")
        assert "text/event-stream" in head
        events = _sse_events(rest)
        toks = [e["choices"][0]["token"] for e in events
                if e["choices"][0]["token"] is not None]
        assert rest.rstrip().endswith(b"data: [DONE]")
        assert events[-1]["choices"][0]["finish_reason"] == "length"

        head2, body2 = await _http_post(port, "/v1/completions", {
            "model": 0, "prompt": [1, 2, 3], "max_tokens": 4,
        })
        assert head2.startswith("HTTP/1.1 200")
        payload = json.loads(body2)

        # invalid requests map to HTTP codes, not raises
        head3, _ = await _http_post(port, "/v1/completions",
                                    {"model": "nope", "prompt": [1]})
        head4, _ = await _http_post(port, "/v1/completions",
                                    {"model": 0, "prompt": []})
        head5, _ = await _http_post(port, "/v1/completions",
                                    {"model": 0, "prompt": "text"})

        mh, mb = await _http_get(port, "/metrics")
        lh, lb = await _http_get(port, "/v1/models")

        http.close()
        await http.wait_closed()
        await engine.aclose()
        return toks, payload, (head3, head4, head5), (mh, json.loads(mb)), \
            json.loads(lb)

    toks, payload, errheads, (mh, snap), models = asyncio.run(run())
    assert toks == want
    assert payload["choices"][0]["tokens"] == want
    assert payload["choices"][0]["finish_reason"] == "length"
    assert errheads[0].startswith("HTTP/1.1 404")
    assert errheads[1].startswith("HTTP/1.1 400")
    assert errheads[2].startswith("HTTP/1.1 400")
    assert mh.startswith("HTTP/1.1 200")
    assert snap["generated_tokens"] == 8
    assert snap["ttft_ms"] is not None
    assert set(snap["ttft_ms"]) == {"p50", "p95", "p99"}
    assert snap["itl_ms"] is not None
    assert snap["instances"][0]["ttft_ms"] is not None
    assert [m["id"] for m in models["data"]] == ["model-0", "model-1"]


def test_http_client_disconnect_cancels_request():
    """Dropping the SSE connection mid-stream cancels the request: the
    engine frees its slot and the workload drains without it."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server)
        http = await start_http_server(engine, port=0)
        port = http.sockets[0].getsockname()[1]

        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = json.dumps({"model": 0, "prompt": [1, 2], "max_tokens": 500,
                           "stream": True}).encode()
        writer.write(
            f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        # read until the first token chunk arrives, then vanish
        buf = b""
        while b"\n\n" not in buf.partition(b"\r\n\r\n")[2]:
            chunk = await reader.read(4096)
            assert chunk, "server closed before first token"
            buf += chunk
        writer.close()
        await writer.wait_closed()
        # the engine notices the disconnect and cancels within a few
        # steps; a successor request then gets the slot
        for _ in range(200):
            if not server.busy():
                break
            await asyncio.sleep(0.02)
        assert not server.busy(), "disconnect did not cancel the request"
        after = await engine.submit(Request(instance=0, prompt=[6],
                                            max_new_tokens=2))
        res = await after.result()
        http.close()
        await http.wait_closed()
        await engine.aclose()
        return res

    res = asyncio.run(run())
    assert res.status == "ok" and len(res.tokens) == 2
    assert server.metrics.snapshot()["cancelled"] == 1


def test_http_nonstream_disconnect_cancels_request():
    """The non-streaming flavor must not hold a decode slot for a
    client that hung up: disconnect while the completion is in flight
    cancels it."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)

    async def run():
        engine = AsyncEngine(server)
        http = await start_http_server(engine, port=0)
        port = http.sockets[0].getsockname()[1]

        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = json.dumps({"model": 0, "prompt": [1, 2],
                           "max_tokens": 500}).encode()
        writer.write(
            f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        # give the request time to admit and start decoding, then vanish
        # without ever reading the (pending) response
        for _ in range(200):
            if server.metrics.snapshot()["generated_tokens"] > 0:
                break
            await asyncio.sleep(0.02)
        writer.close()
        await writer.wait_closed()
        for _ in range(200):
            if not server.busy():
                break
            await asyncio.sleep(0.02)
        assert not server.busy(), "disconnect did not cancel the request"
        http.close()
        await http.wait_closed()
        await engine.aclose()

    asyncio.run(run())
    snap = server.metrics.snapshot()
    assert snap["cancelled"] == 1
    assert snap["generated_tokens"] < 500
