"""Unified chunked-prefill runtime: per-family greedy-stream equality
chunked-vs-exact (including hybrid, previously untestable because exact
admission compiled per prompt length), compiled-shape caps, mid-prompt
SWA-ring chain correctness at chunk boundaries, MoE capacity-mask
routing parity, and cross-mesh stream identity for hybrid + moe.

The reference stream for each request is the family's EXACT-length
prefill followed by a greedy ``decode_step`` loop on that instance's
isolated (M=1) weights — the path the old serving layer used for
families it could serve exactly.  The chunked runtime must reproduce it
for every family with at most two compiled prefill shapes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import registry
from repro.models import common as C
from repro.serving import MultiModelServer, Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mk_batch(cfg, prompt):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None, None]}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (1, 1, cfg.num_image_patches, cfg.vision_embed_dim), dt)
    elif cfg.family == "audio":
        batch["frames"] = jnp.zeros(
            (1, 1, cfg.num_audio_frames, cfg.d_model), dt)
    return batch


def _reference_stream(cfg, pi, prompt, max_new, max_context):
    """Greedy stream from exact-length prefill + decode_step (M=1).

    Like the engine (for every family), the reference prefills
    ``prompt[:-1]`` and re-decodes the last prompt token as its first
    decode step — recurrent state must not integrate that token twice,
    and moe capacity derives from the token count actually prefilled."""
    n = len(prompt)
    prefix = api.prefill_prefix_len(cfg)
    if n > 1:
        kw = {} if cfg.family in ("ssm", "hybrid") else {"cache_len": max_context}
        _, cache = api.prefill(cfg, pi, _mk_batch(cfg, prompt[:-1]), **kw)
    else:
        cache = api.make_cache(cfg, 1, 1, max_context)
    tok, pos = prompt[-1], prefix + n - 1
    out = []
    for _ in range(max_new):
        logits, cache = api.decode_step(
            cfg, pi, cache,
            jnp.full((1, 1, 1), tok, jnp.int32), jnp.full((1, 1), pos, jnp.int32),
        )
        tok = int(jnp.argmax(logits[0, 0]))
        out.append(tok)
        pos += 1
    return out


FAMILY_CASES = [
    # (arch, cfg overrides, max_context, prompt lengths)
    ("tinyllama-1.1b", {}, 64, (1, 3, 7, 12, 18)),
    ("olmoe-1b-7b", {}, 64, (1, 3, 7, 12, 18)),
    # prefix families need n >= 2 for the REFERENCE only (an n=1 prompt
    # would leave the reference's image/frame/meta prefix unprefilled;
    # the serving path itself handles n=1, covered in test_serving.py)
    ("internvl2-26b", {}, 64, (2, 3, 7, 12, 18)),
    ("whisper-small", {}, 64, (2, 3, 7, 12, 18)),
    ("xlstm-1.3b", {}, 64, (1, 3, 7, 12, 18)),
    # num_layers=4 so the config has real SWA layers ({0,2,3} global)
    ("hymba-1.5b", {"num_layers": 4}, 200, (2, 5, 11, 18)),
]


@pytest.mark.parametrize("arch,cfg_kw,max_context,lengths",
                         FAMILY_CASES, ids=[c[0] for c in FAMILY_CASES])
def test_family_stream_chunked_equals_exact(arch, cfg_kw, max_context, lengths):
    """Greedy token streams: chunked serving == exact-length reference,
    for mixed prompt lengths, with at most 2 compiled prefill shapes."""
    cfg = registry.get_smoke_config(arch).with_(num_instances=2, **cfg_kw)
    params = api.init(cfg, jax.random.PRNGKey(0))
    server = MultiModelServer(
        cfg, params, slots_per_instance=2, max_context=max_context,
        temperature=0.0, prefill_chunk=5, prefill_lanes=3, chunk_budget=2,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(instance=i % 2,
                prompt=rng.integers(1, cfg.vocab_size, size=l).tolist(),
                max_new_tokens=4)
        for i, l in enumerate(lengths)
    ]
    ids = [server.submit(r) for r in reqs]
    results = {r.request_id: r for r in server.run_until_drained()}
    assert set(results) == set(ids)
    # tail folding: the padded final chunk removes the single-token tail
    # shape — ONE compiled prefill shape per family, down from 2
    assert server.prefill.compiled_shapes <= 1, server.prefill.compiled_shapes

    ax = api.axes(cfg)
    for req, rid in zip(reqs, ids):
        pi = C.take_instance(params, ax, req.instance)
        want = _reference_stream(cfg, pi, req.prompt, req.max_new_tokens,
                                 max_context)
        assert results[rid].tokens == want, (arch, req.prompt, rid)


def test_hybrid_mixed_lengths_one_compile():
    """The acceptance invariant: a mixed-length hybrid workload compiles
    exactly ONE prefill shape (the folded chunk) — admission is
    O(compiled-shapes) = O(1) per family, not O(distinct lengths)."""
    from repro.serving.prefill import ChunkedPrefill

    cfg = registry.get_smoke_config("hymba-1.5b").with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    cp = ChunkedPrefill(cfg, max_context=200, chunk=16, lanes=2)
    rng = np.random.default_rng(1)
    for l in (1, 2, 4, 9, 17, 23, 31):
        cp.run(params, [Request(instance=l % 2,
                                prompt=rng.integers(1, 250, size=l).tolist())])
    assert cp.compiled_shapes == 1, cp.compiled_shapes


def test_mixed_length_batch_device_calls_exactly_ceil():
    """A mixed-length admission batch drains in exactly ceil(L_max/chunk)
    device calls — every lane rides every call, the shorter ones on
    padded final chunks; zero per-token tail calls."""
    import math

    from repro.serving.prefill import ChunkedPrefill

    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    chunk = 8
    cp = ChunkedPrefill(cfg, max_context=64, chunk=chunk, lanes=4)
    lengths = (5, 9, 20, 26)                 # totals 4, 8, 19, 25
    rng = np.random.default_rng(2)
    for l in lengths:
        cp.start(Request(instance=l % 2,
                         prompt=rng.integers(1, cfg.vocab_size, size=l).tolist()))
    done = cp.advance(params, budget=1_000_000)
    assert len(done) == len(lengths)
    want_calls = math.ceil(max(l - 1 for l in lengths) / chunk)
    assert cp.device_calls == want_calls, (cp.device_calls, want_calls)
    assert cp.compiled_shapes == 1, cp.compiled_shapes


def test_donated_paths_match_non_donated_cpu():
    """Donation (carry + grid cache updated in place) forced ON — on CPU
    the aliasing is not honored but the donated arrays ARE invalidated,
    so this proves the serving programs never read a donated buffer after
    its donation; greedy streams must equal the non-donated path."""
    import warnings

    for arch, ctx in (("tinyllama-1.1b", 64), ("xlstm-1.3b", 64)):
        cfg = registry.get_smoke_config(arch).with_(num_instances=2)
        params = api.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        reqs = [Request(instance=i % 2,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            size=int(l)).tolist(),
                        max_new_tokens=4)
                for i, l in enumerate((1, 3, 7, 12, 18))]

        def serve(donate):
            srv = MultiModelServer(
                cfg, params, slots_per_instance=2, max_context=ctx,
                temperature=0.0, prefill_chunk=5, prefill_lanes=3,
                chunk_budget=2, donate=donate,
            )
            for r in reqs:
                srv.submit(Request(r.instance, list(r.prompt),
                                   r.max_new_tokens))
            res = sorted(srv.run_until_drained(), key=lambda x: x.request_id)
            return [r.tokens for r in res], srv

        plain, _ = serve(donate=False)
        with warnings.catch_warnings():
            # XLA:CPU reports the unusable donations; semantics still hold
            warnings.simplefilter("ignore")
            donated, srv = serve(donate=True)
        assert donated == plain, (arch, donated, plain)
        assert srv.prefill.compiled_shapes == 1


@pytest.mark.parametrize("arch,ctx,total,pallas", [
    ("xlstm-1.3b", 64, 10, False),
    ("hymba-1.5b", 200, 134, False),
    # the kernel-routed paths: hybrid's chunk attention goes through the
    # Pallas chunk_prefill_attn kernel and xlstm's sLSTM through the
    # Pallas cell — the ±1e30 gate-forcing must neutralize junk steps
    # inside the kernels too (interpret mode, hence slow)
    pytest.param("xlstm-1.3b", 64, 10, True, marks=pytest.mark.slow),
    pytest.param("hymba-1.5b", 200, 134, True, marks=pytest.mark.slow),
], ids=["xlstm", "hybrid", "xlstm-pallas", "hybrid-pallas"])
def test_padded_final_chunk_recurrent_carry_matches_exact(arch, ctx, total, pallas):
    """Recurrent carries through a PADDED final chunk (junk suffix +
    validity mask) equal the exact-length chunking — per state leaf, for
    both recurrent families (xLSTM cells, hybrid mamba+ring)."""
    kw = {"num_instances": 1, "dtype": "float32", "param_dtype": "float32",
          "use_pallas_kernels": pallas}
    if arch == "hymba-1.5b":
        kw["num_layers"] = 4
    cfg = registry.get_smoke_config(arch).with_(**kw)
    params = api.init(cfg, jax.random.PRNGKey(0))
    prefix = api.prefill_prefix_len(cfg)
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, size=total - prefix).tolist()
    chunk = 4
    assert total % chunk != 0          # the final chunk is partial

    def toks_at(i, c):
        t = np.zeros((1, 1, c), np.int32)
        for j in range(c):
            p = i + j
            if prefix <= p < total:
                t[0, 0, j] = prompt[p - prefix]
        return jnp.asarray(t)

    exact = api.init_chunk_carry(cfg, 1, 1, ctx)
    i = 0
    while i < total:
        c = min(chunk, total - i)
        exact = api.prefill_chunk(cfg, params, {"tokens": toks_at(i, c)},
                                  exact, jnp.full((1, 1), i, jnp.int32))
        i += c

    padded = api.init_chunk_carry(cfg, 1, 1, ctx)
    i = 0
    while i < total:
        rem = min(chunk, total - i)
        valid = jnp.asarray((np.arange(chunk) < rem)[None, None])
        padded = api.prefill_chunk(
            cfg, params, {"tokens": toks_at(i, chunk), "valid": valid},
            padded, jnp.full((1, 1), i, jnp.int32),
        )
        i += rem

    flat_e = jax.tree_util.tree_leaves_with_path(exact)
    flat_p = jax.tree.leaves(padded)
    for (path, le), lp_ in zip(flat_e, flat_p):
        np.testing.assert_allclose(
            np.asarray(lp_, np.float32), np.asarray(le, np.float32),
            rtol=1e-5, atol=1e-5,
            err_msg=f"{arch} leaf {jax.tree_util.keystr(path)}",
        )


def test_hybrid_swa_ring_chains_across_chunk_boundaries():
    """Mid-prompt chain correctness for the SWA ring: a prompt LONGER
    than the sliding window (the ring wraps mid-prompt, evicting early
    positions) must produce the same next-token logits as one
    exact-length prefill.  This is the capability the old exact-length
    hybrid path could not provide."""
    from repro.models import hybrid as H

    cfg = registry.get_smoke_config("hymba-1.5b").with_(num_layers=4)
    params = api.init(cfg, jax.random.PRNGKey(0))
    w = H.swa_window(cfg)
    prompt = list((np.arange(w + 13) % 250 + 1).astype(int))  # wraps the ring
    r = H.NUM_META_TOKENS
    max_context = r + len(prompt) + 8
    total = r + len(prompt)

    carry = api.init_chunk_carry(cfg, 1, 1, max_context)
    i, chunk = 0, 16
    while i < total:
        c = chunk if total - i >= chunk else 1
        toks = np.zeros((1, 1, c), np.int32)
        for j in range(c):
            if i + j >= r:
                toks[0, 0, j] = prompt[i + j - r]
        carry = api.prefill_chunk(
            cfg, params, {"tokens": jnp.asarray(toks)}, carry,
            jnp.full((1, 1), i, jnp.int32),
        )
        i += c

    _, exact = api.prefill(cfg, params, _mk_batch(cfg, prompt))
    tok = jnp.full((1, 1, 1), prompt[-1], jnp.int32)
    pos = jnp.full((1, 1), total - 1, jnp.int32)
    l_exact, _ = api.decode_step(cfg, params, exact, tok, pos)
    l_chunk, _ = api.decode_step(cfg, params, carry["cache"], tok, pos)
    np.testing.assert_allclose(np.asarray(l_chunk), np.asarray(l_exact),
                               rtol=5e-4, atol=5e-4)


def test_submit_accepts_to_cache_length_and_errors_past_it():
    """Chunked admission is length-agnostic: any prompt whose positions
    (prefix + tokens) fit max_context is accepted — no bucket-derived
    limit — and one past that raises a clean ValueError."""
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=1)
    params = api.init(cfg, jax.random.PRNGKey(0))
    server = MultiModelServer(
        cfg, params, slots_per_instance=1, max_context=48,
        temperature=0.0, prefill_chunk=8,
    )
    limit = server.prefill.max_prompt_len()
    assert limit == 48
    server.submit(Request(instance=0, prompt=[1] * limit, max_new_tokens=1))
    results = server.run_until_drained()
    assert len(results) == 1 and len(results[0].tokens) >= 1
    with pytest.raises(ValueError, match="exceeds the serving context"):
        server.submit(Request(instance=0, prompt=[1] * (limit + 1)))


def test_tail_lane_not_starved_by_chunkable_lanes():
    """Chunk and tail rounds alternate: a lane one call from completion
    finishes within two budget units even while another lane still has
    many full chunks left."""
    from repro.serving.prefill import ChunkedPrefill

    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=1)
    params = api.init(cfg, jax.random.PRNGKey(0))
    cp = ChunkedPrefill(cfg, max_context=64, chunk=4, lanes=2)
    short = Request(instance=0, prompt=[1, 2])          # 1 tail call left
    long = Request(instance=0, prompt=list(range(1, 30)))  # 7 full chunks
    cp.start(long)
    cp.start(short)
    done = cp.advance(params, budget=2)
    assert any(req is short for req, _ in done), "tail lane was starved"


def test_context_smaller_than_learned_prefix_rejected_at_construction():
    """A context that can't even hold the learned prefix (vlm image
    patches) fails loudly at construction, not with a nonsensical
    negative limit at submit time."""
    from repro.serving.prefill import ChunkedPrefill

    cfg = registry.get_smoke_config("internvl2-26b")
    with pytest.raises(ValueError, match="learned prefix"):
        ChunkedPrefill(cfg, max_context=cfg.num_image_patches)


# ---------------------------------------------------------------------------
# MoE capacity masks
# ---------------------------------------------------------------------------


def _layer0(params):
    return jax.tree.map(lambda t: t[0], params["layers"])


def test_moe_chunked_routing_matches_exact():
    """Chained counts + real-length capacity make chunked routing route
    (and drop) exactly as one exact-length pass — even at a capacity
    factor low enough to force drops."""
    from repro.models import moe

    cfg = registry.get_smoke_config("olmoe-1b-7b").with_(capacity_factor=0.5)
    params = api.init(cfg, jax.random.PRNGKey(0))
    lp = _layer0(params)
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, s, cfg.d_model))
    exact, _ = moe.moe_mlp(cfg, lp, x)

    limit = jnp.full((1, 1), moe.capacity(cfg, s), jnp.int32)
    counts = jnp.zeros((1, 1, cfg.num_experts), jnp.int32)
    outs = []
    for i in range(0, s, 4):
        y, _, counts = moe.moe_mlp(cfg, lp, x[:, :, i:i + 4],
                                   counts=counts, limit=limit)
        outs.append(y)
    got = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                               rtol=1e-5, atol=1e-5)


def test_moe_validity_mask_matches_unpadded():
    """Padded tokens masked out of routing neither consume capacity nor
    shift real tokens' positions-in-expert: a padded call with a
    validity mask equals the unpadded exact pass (the old bucketed-path
    caveat, closed)."""
    from repro.models import moe

    cfg = registry.get_smoke_config("olmoe-1b-7b").with_(capacity_factor=0.5)
    params = api.init(cfg, jax.random.PRNGKey(0))
    lp = _layer0(params)
    s_real, s_pad = 8, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, s_pad, cfg.d_model))
    limit = jnp.full((1, 1), moe.capacity(cfg, s_real), jnp.int32)
    counts = jnp.zeros((1, 1, cfg.num_experts), jnp.int32)
    valid = (jnp.arange(s_pad) < s_real)[None, None]

    padded, _, new_counts = moe.moe_mlp(cfg, lp, x, valid=valid,
                                        counts=counts, limit=limit)
    exact, _, _ = moe.moe_mlp(cfg, lp, x[:, :, :s_real],
                              counts=counts, limit=limit)
    np.testing.assert_allclose(np.asarray(padded[:, :, :s_real]),
                               np.asarray(exact), rtol=1e-5, atol=1e-5)
    # masked tokens produce zero output and advance no expert counts
    np.testing.assert_array_equal(np.asarray(padded[:, :, s_real:]), 0.0)
    assert int(np.asarray(new_counts).sum()) == s_real * cfg.num_experts_per_tok


@pytest.mark.slow
def test_moe_ep_shmap_masked_chainable_routing():
    """The experts_compute='ep' shard_map variant (per-rank expert-window
    dispatch + token-space psum) now understands the masked/chainable
    routing: chunked counts+limit plus a validity mask route exactly like
    the plain path — serving no longer has to raise on the ep placement
    (ROADMAP nicety, closed)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        import repro  # installs compat shims
        from repro.configs import registry
        from repro.models import moe
        from repro.launch.shardings import serve_rules, moe_ep_shmap

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # 8 experts on a 4-way model axis -> e_local = 2 per rank; low
        # capacity factor so the keep/drop rule actually fires
        cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").with_(
            num_instances=2, num_experts=8, num_experts_per_tok=2,
            dtype="float32", param_dtype="float32", capacity_factor=0.5)
        params = moe.init(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda x: x[0], params["layers"])
        s_real, s_pad = 12, 16
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, s_pad, cfg.d_model))
        valid = (jnp.arange(s_pad) < s_real)[None, None]
        limit = jnp.full((2, 4), moe.capacity(cfg, s_real), jnp.int32)
        counts0 = jnp.zeros((2, 4, cfg.num_experts), jnp.int32)

        ref_out, _, ref_counts = moe.moe_mlp(
            cfg, lp, x, valid=valid, counts=counts0, limit=limit)

        rules = moe_ep_shmap(serve_rules(mesh))
        with jax.set_mesh(mesh), rules:
            out, _, cnts = jax.jit(lambda l, xx: moe.moe_mlp(
                cfg, l, xx, valid=valid, counts=counts0, limit=limit))(lp, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(cnts), np.asarray(ref_counts))

        # the plain (non-chunked) ep path is unchanged
        r0, _ = moe.moe_mlp(cfg, lp, x)
        with jax.set_mesh(mesh), rules:
            o0, _ = jax.jit(lambda l, xx: moe.moe_mlp(cfg, l, xx))(lp, x)
        np.testing.assert_allclose(np.asarray(o0), np.asarray(r0),
                                   rtol=2e-5, atol=2e-5)
        print("ep masked routing OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ep masked routing OK" in r.stdout


# ---------------------------------------------------------------------------
# cross-mesh stream identity (hybrid — new under the chunked runtime)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hybrid_and_moe_streams_identical_across_meshes():
    """Hybrid + moe greedy streams: no-mesh == 1-device mesh == 8-device
    mesh.  The chunked runtime is the first admission path that can
    serve hybrid at all lengths, and the moe leg runs the masked
    capacity routing through its shard_map dispatch — both must hold
    the cross-mesh contract dense/ssm already do."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        from repro import api
        from repro.configs import registry
        from repro.models import common as C
        from repro.serving import MultiModelServer, Request

        M = 2

        def build(arch):
            cfg1 = registry.get_smoke_config(arch).with_(
                num_instances=1, dtype="float32", param_dtype="float32")
            cfg = cfg1.with_(num_instances=M)
            keys = jax.random.split(jax.random.PRNGKey(0), M)
            merged = C.merge_instances(
                [api.init(cfg1, k) for k in keys], api.axes(cfg1))
            return cfg, merged

        def serve(cfg, merged, mesh, max_context):
            srv = MultiModelServer(
                cfg, merged, slots_per_instance=2, max_context=max_context,
                prefill_chunk=16, chunk_budget=2, mesh=mesh)
            rng = np.random.default_rng(0)
            for i in range(4):
                prompt = rng.integers(
                    1, cfg.vocab_size, size=int(rng.integers(2, 9))).tolist()
                srv.submit(Request(instance=i % M, prompt=prompt,
                                   max_new_tokens=3))
            res = sorted(srv.run_until_drained(), key=lambda r: r.request_id)
            assert srv.prefill.compiled_shapes == 1
            return [r.tokens for r in res]

        for arch, ctx in (("hymba-1.5b", 200), ("olmoe-1b-7b", 64)):
            cfg, merged = build(arch)
            ref = serve(cfg, merged, None, ctx)
            assert all(len(t) > 0 for t in ref), (arch, ref)
            one = serve(cfg, merged, jax.make_mesh((1, 1), ("data", "model")), ctx)
            assert one == ref, (arch, one, ref)
            eight = serve(cfg, merged, mesh, ctx)
            assert eight == ref, (arch, eight, ref)
            print(arch, "streams OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=1200,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "hymba-1.5b streams OK" in r.stdout
    assert "olmoe-1b-7b streams OK" in r.stdout
