"""Multi-step decode (DESIGN.md §6.6): K fused decode+sample steps per
device call with on-device stop handling.

The ISSUE-7 contract: greedy token streams are bit-identical for K=1
vs K ∈ {2, 4, 8} (dense + one recurrent family, through the sync loop,
the async frontend, and an 8-device CPU mesh subprocess); a lane whose
stop condition hits mid-block freezes on device — its cache rows and
position stop advancing exactly where the one-call-per-token protocol
would stop them; cancellation landing while a block is in flight keeps
its between-steps semantics (partial tokens kept, slot refilled); and
in pure-decode steady state the engine issues exactly
ceil(max_new / K) decode device calls per request wave.
"""
import asyncio
import math
import os
import subprocess
import sys
import textwrap

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import registry
from repro.serving import AsyncEngine, MultiModelServer, Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(arch, m=2):
    cfg = registry.get_smoke_config(arch).with_(num_instances=m)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("slots_per_instance", 2)
    kw.setdefault("max_context", 48)
    kw.setdefault("temperature", 0.0)
    return MultiModelServer(cfg, params, **kw)


def _reqs():
    # more requests than the 4 grid slots and mixed budgets, so the
    # waves exercise mid-block finishes, refills, AND the adaptive
    # horizon's backlog shrink while draining
    return [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=7),
        Request(instance=1, prompt=[4, 5], max_new_tokens=5),
        Request(instance=0, prompt=[7], max_new_tokens=3),
        Request(instance=1, prompt=[3, 3, 3, 3, 3], max_new_tokens=6),
        Request(instance=0, prompt=[2, 2], max_new_tokens=4),
        Request(instance=1, prompt=[9, 8, 7], max_new_tokens=8),
    ]


def _drain(server, reqs):
    for r in reqs:
        server.submit(Request(r.instance, list(r.prompt), r.max_new_tokens))
    return {r.request_id: r.tokens for r in server.run_until_drained()}


# ---------------------------------------------------------------------------
# K-parity: greedy streams bit-identical across horizons
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-1.3b"])
def test_greedy_streams_identical_across_k_sync(arch):
    """K=1 vs K ∈ {2, 4, 8}: same requests, same greedy streams, token
    for token — for a KV-cache family and a recurrent-state family."""
    cfg, params = _build(arch)
    want = _drain(_server(cfg, params, decode_steps=1), _reqs())
    assert want and all(len(t) > 0 for t in want.values())
    for K in (2, 4, 8):
        got = _drain(_server(cfg, params, decode_steps=K), _reqs())
        assert got == want, f"K={K} diverged from K=1"


def test_streams_identical_with_adaptive_horizon_off():
    """The adaptive policy only picks WHICH k each block runs — the
    on-device stop mask alone guarantees parity, proven by forcing the
    full horizon every block."""
    cfg, params = _build("tinyllama-1.1b")
    want = _drain(_server(cfg, params, decode_steps=1), _reqs())
    got = _drain(
        _server(cfg, params, decode_steps=8, adaptive_horizon=False),
        _reqs(),
    )
    assert got == want


def test_greedy_streams_identical_across_k_async():
    """The async frontend over a K=4 engine streams exactly the K=1
    sync tokens: the host unroll keeps per-token on_token semantics."""
    cfg, params = _build("tinyllama-1.1b")
    want = _drain(_server(cfg, params, decode_steps=1), _reqs())

    async def run(server, reqs):
        engine = AsyncEngine(server)

        async def client(r):
            stream = await engine.submit(
                Request(r.instance, list(r.prompt), r.max_new_tokens))
            toks = [t async for t in stream]
            res = await stream.result()
            assert res.status == "ok"
            assert toks == res.tokens
            return stream.request_id, toks

        out = await asyncio.gather(*(client(r) for r in reqs))
        await engine.aclose()
        return dict(out)

    got = asyncio.run(run(_server(cfg, params, decode_steps=4), _reqs()))
    assert got == want


# ---------------------------------------------------------------------------
# device-call accounting: one dispatch per block, ceil(tokens / K) blocks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,max_new", [(1, 10), (2, 10), (4, 10), (8, 10),
                                       (4, 8), (8, 3)])
def test_decode_device_calls_ceil_tokens_over_k(k, max_new):
    """Pure-decode steady state (no backlog, prefill done): the engine
    dispatches exactly ceil(max_new / K) fused decode blocks, each
    exactly ONE call through server._step."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, decode_steps=k)
    calls = {"n": 0}
    inner = server._step

    def counting_step(*a, **kw):
        calls["n"] += 1
        return inner(*a, **kw)

    server._step = counting_step
    # one request per instance: both admit in one wave, decode together
    reqs = [Request(instance=i, prompt=[3 + i, 4], max_new_tokens=max_new)
            for i in range(cfg.num_instances)]
    out = _drain(server, reqs)
    assert all(len(t) == max_new for t in out.values())
    want_calls = math.ceil(max_new / k)
    assert calls["n"] == want_calls == server.steps
    assert server.metrics.decode_calls == want_calls
    # scan steps: every block runs its full static length
    assert server.metrics.decode_steps == want_calls * min(
        k, server.decode_steps)
    snap = server.metrics.snapshot()
    assert snap["decode_device_calls"] == want_calls
    assert snap["decode_steps"] >= snap["decode_device_calls"]
    assert snap["tokens_per_device_call"] == pytest.approx(
        cfg.num_instances * max_new / want_calls)


# ---------------------------------------------------------------------------
# on-device stop handling: mid-block freeze of cache / tokens
# ---------------------------------------------------------------------------


def test_midblock_stop_freezes_cache_and_tokens():
    """Drive the block function directly: a lane whose budget runs out
    after 2 of 4 scan steps must leave EXACTLY the cache a 2-step block
    leaves (junk steps masked), with its tokens frozen and the emitted
    mask marking the junk rows; a live lane keeps decoding."""
    cfg, params = _build("tinyllama-1.1b")
    mk = lambda: _server(cfg, params, decode_steps=4)
    srv = mk()
    M, B = srv.m, srv.b
    tok = jnp.ones((M, B), jnp.int32)
    pos = jnp.zeros((M, B), jnp.int32)
    key = jax.random.PRNGKey(7)
    alive = jnp.ones((M, B), bool)
    # slot (0, 0) has budget for 2 steps; everyone else rides the full 4
    rem = jnp.full((M, B), 10, jnp.int32).at[0, 0].set(2)

    toks4, em4, ok4, cache4, _ = srv._step(
        srv.params, srv.cache, tok, pos, key, alive, rem, 4)
    srv2 = mk()
    toks2, em2, ok2, cache2, _ = srv2._step(
        srv2.params, srv2.cache, tok, pos, key, alive, rem, 2)

    em4 = np.asarray(em4)
    toks4, toks2 = np.asarray(toks4), np.asarray(toks2)
    # emitted = alive at entry of each scan step: 2 real rows, 2 junk
    assert em4[:, 0, 0].tolist() == [True, True, False, False]
    assert em4[:, 1, 0].all()
    # a healthy decode never trips the NaN/Inf token guard (§6.8)
    assert np.asarray(ok4).all() and np.asarray(ok2).all()
    # frozen token after the stop; real rows match the 2-step block
    assert (toks4[:2] == toks2).all()
    assert toks4[2, 0, 0] == toks4[1, 0, 0] == toks4[3, 0, 0]

    # the stopped lane's cache is bit-identical to the 2-step block's —
    # the junk steps wrote nothing
    s4 = api.take_state(cfg, cache4, 0, 0)
    s2 = api.take_state(cfg, cache2, 0, 0)
    for a, b in zip(jax.tree.leaves(s4), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # while a live lane's cache DID advance past the 2-step state
    l4 = jax.tree.leaves(api.take_state(cfg, cache4, 1, 0))
    l2 = jax.tree.leaves(api.take_state(cfg, cache2, 1, 0))
    assert any((np.asarray(a) != np.asarray(b)).any()
               for a, b in zip(l4, l2))


def test_eos_midblock_matches_k1():
    """EOS landing mid-block: pick a token the greedy stream emits at a
    non-boundary index as eos_id — K=1 and K=4 must stop at the same
    token with finish_reason='stop', other requests unaffected."""
    cfg, params = _build("tinyllama-1.1b")
    probe = _server(cfg, params)
    probe.submit(Request(instance=0, prompt=[5, 6, 7], max_new_tokens=8))
    ref = probe.run_until_drained()[0].tokens
    eos = ref[2]                      # index 2: inside a K=4 block

    def run(K):
        srv = _server(cfg, params, decode_steps=K, eos_id=eos)
        srv.submit(Request(instance=0, prompt=[5, 6, 7], max_new_tokens=8))
        srv.submit(Request(instance=1, prompt=[4, 4], max_new_tokens=6))
        res = {r.request_id: r for r in srv.run_until_drained()}
        return res

    r1, r4 = run(1), run(4)
    assert set(r1) == set(r4)
    for rid in r1:
        assert r1[rid].tokens == r4[rid].tokens
        assert r1[rid].finish_reason == r4[rid].finish_reason
    stopped = r1[0]
    assert stopped.finish_reason == "stop"
    assert stopped.tokens[-1] == eos
    assert len(stopped.tokens) < 8


# ---------------------------------------------------------------------------
# cancellation landing mid-block
# ---------------------------------------------------------------------------


def test_cancel_mid_block_async():
    """A cancel issued while K=4 blocks are landing applies at the next
    step boundary: the client keeps the partial tokens, the slot frees,
    and the freed slot serves a follow-up request correctly."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, decode_steps=4)

    async def run():
        engine = AsyncEngine(server)
        stream = await engine.submit(
            Request(instance=0, prompt=[1, 2, 3], max_new_tokens=30))
        got = []
        async for t in stream:
            got.append(t)
            if len(got) == 5:         # one token into the second block
                await engine.cancel(stream.request_id)
        res = await stream.result()
        # the freed slot must serve a fresh request end to end
        s2 = await engine.submit(
            Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4))
        toks2 = [t async for t in s2]
        res2 = await s2.result()
        await engine.aclose()
        return got, res, toks2, res2

    got, res, toks2, res2 = asyncio.run(run())
    assert res.status == "cancelled"
    # partial tokens kept; cancel applied between blocks, so the stream
    # saw at least the 5 tokens it consumed and far fewer than max_new
    assert res.tokens[:len(got)] == got
    assert 5 <= len(res.tokens) <= 12
    assert res2.status == "ok" and len(toks2) == 4
    assert not server.busy()

    # and the same follow-up stream through a K=1 engine is identical
    # (the cancelled request left no state behind)
    want = _drain(_server(cfg, params, decode_steps=1),
                  [Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4)])
    assert toks2 == list(want.values())[0]


# ---------------------------------------------------------------------------
# 8-device mesh subprocess: sharded multi-step parity
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multistep_streams_identical_on_mesh():
    """No-mesh K=1 == 8-device (2, 4) mesh K=1 == mesh K=8: the block's
    scan, stop mask and slot-select all run sharded and exact."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro import api
        from repro.configs import registry
        from repro.serving import MultiModelServer, Request

        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        M = 2
        cfg = registry.get_smoke_config("tinyllama-1.1b").with_(
            num_instances=M, dtype="float32", param_dtype="float32")
        params = api.init(cfg, jax.random.PRNGKey(0))

        def serve(mesh, K):
            srv = MultiModelServer(
                cfg, params, slots_per_instance=2, max_context=64,
                mesh=mesh, decode_steps=K)
            rng = np.random.default_rng(0)
            for i in range(6):
                prompt = rng.integers(
                    1, cfg.vocab_size, size=int(rng.integers(2, 8))).tolist()
                srv.submit(Request(instance=i % M, prompt=prompt,
                                   max_new_tokens=4 + (i % 3)))
            res = sorted(srv.run_until_drained(), key=lambda r: r.request_id)
            return [r.tokens for r in res]

        ref = serve(None, 1)
        assert all(len(t) > 0 for t in ref), ref
        assert serve(mesh, 1) == ref
        assert serve(mesh, 8) == ref
        print("multistep mesh streams OK")
        """
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "multistep mesh streams OK" in r.stdout
