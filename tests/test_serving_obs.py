"""Observability tests (ISSUE 6): step tracing, Prometheus exposition,
kernel profiling, and the HTTP debug surface.

The load-bearing contracts:

* tracing OFF is free — call sites guard on ``tracer.enabled``, so the
  disabled path runs NO tracer code at all (no event construction, no
  locks, no clock reads inside the tracer) — asserted by making every
  tracer method explode and draining a full workload,
* tracing ON is invisible to results — traced greedy streams are
  bit-identical to untraced ones (dense + recurrent, no-mesh and an
  8-device mesh subprocess),
* ``export_chrome()`` emits loadable Chrome-trace JSON: ``X`` slices
  for device calls with dispatch/gap/occupancy args, request-lifecycle
  spans correlated by request id, ``i`` instants at terminal stages,
* the Prometheus rendering parses line-by-line (format 0.0.4) and its
  label escaping round-trips,
* ``ServerMetrics.snapshot()`` carries the cumulative device-call and
  compiled-shape counters (and the latter survives ``reset_metrics``),
* the HTTP layer negotiates /metrics on Accept, exposes
  /debug/trace{,/start,/stop} + /metrics/reset, and /healthz flips to
  503 when the driver task dies,

and the §6.9 accounting/SLO/flight layer (ISSUE 10):

* accounting OFF and flight unarmed are free (bombed-methods proof,
  same as the tracer's),
* accounting ON conserves — per-tenant attributed time re-sums to
  settled device wall (under chunked prefill, K=8 multi-step decode,
  AND across a supervised driver crash with replay) — and never
  changes greedy streams,
* log-bucketed histograms bound percentile error by the bucket growth
  factor and expose valid Prometheus ``histogram`` families
  (monotone cumulative ``le`` buckets ending at +Inf == _count),
* SLO objectives evaluate ok/burning/violated from cumulative budget
  + recent burn, surfaced on /v1/slo, /healthz and /v1/models,
* crash/watchdog/quarantine incidents freeze a ``flight/v1`` JSON
  artifact that round-trips from disk.
"""
import asyncio
import json
import math
import os
import re
import subprocess
import sys
import textwrap

import pytest

import jax

from repro import api
from repro.configs import registry
from repro.serving import (
    AsyncEngine,
    FlightRecorder,
    MultiModelServer,
    Request,
    SLOConfig,
    start_http_server,
)
from repro.serving.obs import (
    LogHistogram,
    Tracer,
    evaluate_availability,
    evaluate_objective,
    profile_kernel,
    profile_serving_kernels,
    render_prometheus,
    serving_shapes,
    validate_profile,
    worst_state,
)
from repro.serving.obs.prometheus import escape_label
from repro.serving.obs.slo import HIST_GROWTH

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(arch, m=2):
    cfg = registry.get_smoke_config(arch).with_(num_instances=m)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("slots_per_instance", 2)
    kw.setdefault("max_context", 48)
    kw.setdefault("temperature", 0.0)
    kw.setdefault("prefill_chunk", 4)
    return MultiModelServer(cfg, params, **kw)


def _reqs():
    return [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4),
        Request(instance=1, prompt=[4, 5], max_new_tokens=4),
        Request(instance=0, prompt=[7], max_new_tokens=3),
        Request(instance=1, prompt=[3, 3, 3, 3, 3], max_new_tokens=3),
    ]


# ---------------------------------------------------------------------------
# tracing off: literally no tracer code on the hot path
# ---------------------------------------------------------------------------


def test_tracing_off_runs_no_tracer_code(monkeypatch):
    """With capture off, a full drain (submit, admit, prefill, scatter,
    decode, finish, cancel) must never enter the tracer: every recording
    method is replaced with a bomb."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    def boom(*a, **k):
        raise AssertionError("tracer code ran while capture was off")

    monkeypatch.setattr(server.tracer, "device_call", boom)
    monkeypatch.setattr(server.tracer, "request_event", boom)
    monkeypatch.setattr(server.tracer, "_append", boom)
    ids = [server.submit(r) for r in _reqs()]
    # exercise the cancel call sites too (queued cancel)
    extra = server.submit(Request(instance=0, prompt=[9, 9], max_new_tokens=2))
    server.cancel(extra)
    results = server.run_until_drained()
    assert {r.request_id for r in results} == set(ids)
    assert all(r.status == "ok" for r in results)
    assert len(server.tracer) == 0


# ---------------------------------------------------------------------------
# tracing on: results are bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-1.3b"])
def test_traced_greedy_identical_to_untraced(arch):
    cfg, params = _build(arch)
    server = _server(cfg, params)

    def drain():
        ids = [server.submit(r) for r in _reqs()]
        res = {r.request_id: r.tokens for r in server.run_until_drained()}
        return [res[i] for i in ids]

    want = drain()
    server.tracer.start()
    got = drain()
    server.tracer.stop()
    assert got == want
    assert len(server.tracer) > 0


def test_traced_async_streams_identical_to_untraced_sync():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    for r in _reqs():
        server.submit(r)
    want = sorted(r.tokens for r in server.run_until_drained())

    async def run():
        engine = AsyncEngine(server)
        await engine.set_tracing(True)

        async def client(r):
            s = await engine.submit(r)
            toks = [t async for t in s]
            assert (await s.result()).tokens == toks
            return toks

        out = await asyncio.gather(*(client(r) for r in _reqs()))
        stopped = await engine.set_tracing(False)
        await engine.aclose()
        return out, stopped

    got, stopped = asyncio.run(run())
    assert sorted(got) == want
    assert stopped["tracing"] is False
    assert stopped["summary"]["decode_steps"] > 0


@pytest.mark.slow
def test_traced_streams_identical_under_mesh():
    """Tracing must be result-invisible on the sharded path too: an
    8-CPU-device (data=2, model=4) mesh drain with capture on equals
    the untraced no-mesh baseline, and the capture still carries
    decode/prefill/scatter events (subprocess harness as in
    test_serving_sharded.py)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro import api
        from repro.configs import registry
        from repro.models import common as C
        from repro.serving import MultiModelServer, Request

        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        M = 2

        def build(arch):
            cfg1 = registry.get_smoke_config(arch).with_(
                num_instances=1, dtype="float32", param_dtype="float32")
            cfg = cfg1.with_(num_instances=M)
            keys = jax.random.split(jax.random.PRNGKey(0), M)
            merged = C.merge_instances(
                [api.init(cfg1, k) for k in keys], api.axes(cfg1))
            return cfg, merged

        def mk_reqs(cfg, n=5, max_new=4):
            rng = np.random.default_rng(0)
            return [Request(instance=i % M,
                            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(2, 8))).tolist(),
                            max_new_tokens=max_new) for i in range(n)]

        def drain(server, reqs, traced):
            if traced:
                server.tracer.start()
            for r in reqs:
                server.submit(r)
            out = {r.request_id: r.tokens for r in server.run_until_drained()}
            if traced:
                server.tracer.stop()
            return out

        for arch in ("tinyllama-1.1b", "xlstm-1.3b"):
            cfg, merged = build(arch)
            plain = MultiModelServer(cfg, merged, slots_per_instance=2,
                                     max_context=64, prefill_chunk=4)
            want = drain(plain, mk_reqs(cfg), traced=False)
            assert all(want.values())
            meshed = MultiModelServer(cfg, merged, slots_per_instance=2,
                                      max_context=64, prefill_chunk=4,
                                      mesh=mesh)
            got = drain(meshed, mk_reqs(cfg), traced=True)
            assert got == want, (arch, got, want)
            s = meshed.tracer.summary()
            assert s["decode_steps"] > 0 and s["prefill_chunks"] > 0
            assert s["scatters"] > 0
            print(arch, "traced mesh streams OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "xlstm-1.3b traced mesh streams OK" in r.stdout


# ---------------------------------------------------------------------------
# chrome-trace export schema
# ---------------------------------------------------------------------------


def test_export_chrome_schema_and_json_roundtrip():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    server.tracer.start()
    for r in _reqs():
        server.submit(r)
    server.run_until_drained()
    server.tracer.stop()
    trace = json.loads(json.dumps(server.tracer.export_chrome()))

    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["dropped_events"] == 0
    events = trace["traceEvents"]
    assert isinstance(events, list) and events

    device = [e for e in events if e["ph"] == "X" and e["pid"] == 0]
    spans = [e for e in events if e["ph"] == "X" and e["pid"] == 1]
    instants = [e for e in events if e["ph"] == "i"]
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in device} == {"decode", "prefill_chunk",
                                           "scatter"}
    for e in device:
        assert e["ts"] >= 0 and e["dur"] >= 0
        args = e["args"]
        for k in ("step", "dispatch_ms", "settled_ms", "gap_ms",
                  "active_slots", "slot_capacity", "occupancy"):
            assert k in args, (e["name"], k)
        assert 0.0 <= args["occupancy"] <= 1.0
    decode_args = [e["args"] for e in device if e["name"] == "decode"]
    assert any(a["active_slots"] > 0 for a in decode_args)
    assert all(a["slot_capacity"] == server.m * server.b
               for a in decode_args)

    # every request leaves spans on its own track, ending in a terminal
    # instant; the full lifecycle (multi-chunk prompt) names all three
    rids = {e["tid"] for e in spans}
    assert len(rids) == len(_reqs())
    assert {e["name"] for e in instants} == {"finish:ok"}
    by_rid = {}
    for e in spans:
        by_rid.setdefault(e["tid"], []).append(e["name"])
    assert any(set(v) == {"queued", "prefill", "decode"}
               for v in by_rid.values()), by_rid
    # process/thread naming metadata for the two trace processes
    assert {(e["name"], e.get("pid")) for e in meta} >= {
        ("process_name", 0), ("process_name", 1), ("thread_name", 0)}


def test_tracer_ring_bounds_memory_and_counts_drops():
    tr = Tracer(capacity=2, clock=lambda: 0.0)
    tr.start()
    for i in range(5):
        tr.device_call("decode", 0.0, 0.0, 0.0, step=i)
    assert len(tr) == 2
    assert tr.dropped == 3
    assert tr.export_chrome()["otherData"]["dropped_events"] == 3
    tr.start()                      # restart clears the window
    assert len(tr) == 0 and tr.dropped == 0


def test_summary_aggregates_from_synthetic_events():
    tr = Tracer(clock=lambda: 0.0)
    tr.start()                                    # epoch = 0.0
    tr.device_call("decode", 1.00, 1.01, 1.05, step=0, active=2, capacity=4)
    tr.device_call("decode", 1.10, 1.11, 1.15, step=1, active=4, capacity=4)
    tr.device_call("prefill_chunk", 1.20, 1.21, 1.25, step=2,
                   lanes_busy=1, lanes=4, valid_frac=0.5, tokens=8)
    tr.device_call("scatter", 1.30, 1.31, 1.35, step=2)
    s = tr.summary()
    assert s["device_calls"] == 4
    assert s["decode_steps"] == 2
    assert s["prefill_chunks"] == 1
    assert s["scatters"] == 1
    # gaps: 0 (first), 1.10-1.05, 1.20-1.15, 1.30-1.25 -> 0/50/50/50 ms
    assert s["dispatch_overhead_ms"]["p95"] == pytest.approx(50.0)
    assert s["mean_dispatch_gap_ms"] == pytest.approx(37.5)
    assert s["mean_grid_occupancy"] == pytest.approx(0.75)
    assert s["idle_slot_token_steps"] == 2
    assert s["mean_prefill_lane_occupancy"] == pytest.approx(0.25)
    assert s["mean_chunk_validity"] == pytest.approx(0.5)


def test_request_spans_from_synthetic_lifecycle():
    times = iter([0.0, 1.0, 2.0, 3.0, 4.0])
    tr = Tracer(clock=lambda: next(times))
    tr.start()                                    # epoch = 0.0
    tr.request_event(7, "submit", instance=1)
    tr.request_event(7, "admit", instance=1)
    tr.request_event(7, "prefill_done", instance=1)
    tr.request_event(7, "finish", instance=1, status="ok")
    ev = tr.export_chrome()["traceEvents"]
    spans = {e["name"]: e for e in ev if e["ph"] == "X"}
    assert set(spans) == {"queued", "prefill", "decode"}
    assert spans["queued"]["ts"] == pytest.approx(1e6)
    assert spans["queued"]["dur"] == pytest.approx(1e6)
    assert spans["decode"]["dur"] == pytest.approx(1e6)
    assert [e["name"] for e in ev if e["ph"] == "i"] == ["finish:ok"]


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

# one sample line: name{labels} value — label values are quoted strings
# with \\ \" \n escapes; value is a float, integer, NaN or +/-Inf
_SAMPLE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{((?:[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*",?)*)\})?'
    r' (NaN|[+-]Inf|[+-]?[0-9.eE+-]+)$')


def test_prometheus_exposition_parses_line_by_line():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    for r in _reqs():
        server.submit(r)
    server.run_until_drained()
    text = render_prometheus(server.metrics.snapshot())

    typed = {}
    samples = {}
    for line in text.strip().split("\n"):
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            _, _, name, typ = line.split(" ", 3)
            assert typ in ("counter", "gauge", "summary", "histogram"), line
            typed[name] = typ
            continue
        m = _SAMPLE.match(line)
        assert m, f"unparseable sample line: {line!r}"
        samples.setdefault(m.group(1), []).append(m.group(3))
    # every sample was declared, every declared family has samples; a
    # histogram family F exposes F_bucket/F_sum/F_count sample names
    expect = set()
    for name, typ in typed.items():
        if typ == "histogram":
            expect |= {f"{name}_bucket", f"{name}_sum", f"{name}_count"}
        else:
            expect.add(name)
    assert set(samples) == expect
    gen = sum(r.max_new_tokens for r in _reqs())
    assert samples["repro_generated_tokens_total"] == [str(gen)]
    assert samples["repro_device_calls_total"][0].isdigit()
    assert int(samples["repro_device_calls_total"][0]) > 0
    assert samples["repro_prefill_compiled_shapes"] == ["1"]
    # per-instance families carry one sample per instance; summaries
    # carry one per quantile
    assert len(samples["repro_instance_completed_total"]) == server.m
    assert len(samples["repro_ttft_milliseconds"]) == 3
    assert typed["repro_instance_ttft_seconds"] == "histogram"


def test_prometheus_histogram_le_buckets_are_valid():
    """The real-histogram exposition contract (CI's observability job
    leans on this): per-instance ``le`` bounds strictly increase,
    cumulative counts never decrease, the family ends at ``le="+Inf"``
    whose value equals ``_count``, and ``_sum``/``_count`` are
    consistent with the recorded samples."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    for r in _reqs():
        server.submit(r)
    server.run_until_drained()
    text = render_prometheus(server.metrics.snapshot())

    pat = re.compile(
        r'^repro_instance_ttft_seconds_bucket'
        r'\{instance="(\d+)",le="([^"]+)"\} (\d+)$')
    buckets = {}
    for line in text.strip().split("\n"):
        m = pat.match(line)
        if m:
            buckets.setdefault(int(m.group(1)), []).append(
                (m.group(2), int(m.group(3))))
    assert set(buckets) == set(range(server.m))
    counts = {}
    sums = {}
    for line in text.strip().split("\n"):
        m = re.match(r'^repro_instance_ttft_seconds_(count|sum)'
                     r'\{instance="(\d+)"\} (\S+)$', line)
        if m:
            (counts if m.group(1) == "count" else sums)[
                int(m.group(2))] = float(m.group(3))
    for i, rows in buckets.items():
        les = [float("inf") if le == "+Inf" else float(le)
               for le, _ in rows]
        cums = [c for _, c in rows]
        assert les == sorted(les) and len(set(les)) == len(les), i
        assert les[-1] == float("inf"), i
        assert cums == sorted(cums), i
        assert cums[-1] == counts[i], i
        assert counts[i] > 0              # every instance served a TTFT
        assert sums[i] > 0


def test_prometheus_label_escaping_roundtrips():
    assert escape_label('a\\b"c\nd') == 'a\\\\b\\"c\\nd'
    nasty = {'path': 'a\\b"c\nd', 'plain': 'ok'}
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    text = render_prometheus(server.metrics.snapshot(), extra_labels=nasty)
    line = next(l for l in text.split("\n")
                if l.startswith("repro_generated_tokens_total{"))
    m = _SAMPLE.match(line)
    assert m, line
    # unescape the label block and recover the original values
    labels = dict(re.findall(r'([a-zA-Z_]+)="((?:[^"\\]|\\.)*)"', m.group(2)))
    unescape = lambda s: (s.replace("\\n", "\n").replace('\\"', '"')
                          .replace("\\\\", "\\"))
    assert unescape(labels["path"]) == nasty["path"]
    assert labels["plain"] == "ok"


# ---------------------------------------------------------------------------
# snapshot counters + reset semantics
# ---------------------------------------------------------------------------


def test_snapshot_device_call_and_compiled_shape_counters():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)
    for r in _reqs():
        server.submit(r)
    results = server.run_until_drained()
    snap = server.metrics.snapshot()
    assert snap["scatter_calls"] == len(results)
    assert snap["device_calls"] == (snap["decode_steps"]
                                    + snap["prefill_batches"]
                                    + snap["scatter_calls"])
    assert snap["device_calls"] > 0
    assert snap["prefill_compiled_shapes"] == 1   # tail folding: one shape
    # the compiled-shape gauge reads through to the live prefill runtime,
    # so a reset window still reports the true cumulative count
    server.reset_metrics()
    snap2 = server.metrics.snapshot()
    assert snap2["generated_tokens"] == 0
    assert snap2["device_calls"] == 0
    assert snap2["prefill_compiled_shapes"] == 1


# ---------------------------------------------------------------------------
# HTTP surface
# ---------------------------------------------------------------------------


async def _req_http(port, method, path, headers=None, payload=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = b"" if payload is None else json.dumps(payload).encode()
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    writer.write(
        f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
        f"Content-Length: {len(body)}\r\n{extra}\r\n".encode() + body)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, rest = raw.partition(b"\r\n\r\n")
    head = head.decode("latin-1")
    status = int(head.split()[1])
    ctype = next((l.split(":", 1)[1].strip() for l in head.split("\r\n")
                  if l.lower().startswith("content-type")), "")
    return status, ctype, rest


def test_http_observability_routes():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    async def run():
        async with AsyncEngine(server) as engine:
            http = await start_http_server(engine, port=0)
            port = http.sockets[0].getsockname()[1]

            st, _, body = await _req_http(port, "GET", "/healthz")
            h = json.loads(body)
            assert st == 200 and h["status"] == "ok"
            assert h["driver"] == "running"
            assert h["in_flight"] == 0 and h["queue_depths"] == [0, 0]
            assert h["tracing"] is False

            st, _, body = await _req_http(port, "POST", "/debug/trace/start")
            assert st == 200 and json.loads(body) == {"tracing": True}

            st, _, body = await _req_http(
                port, "POST", "/v1/completions",
                payload={"model": 0, "prompt": [1, 2, 3], "max_tokens": 4})
            assert st == 200
            toks = json.loads(body)["choices"][0]["tokens"]
            assert len(toks) == 4

            st, ct, body = await _req_http(port, "GET", "/debug/trace")
            trace = json.loads(body)
            assert st == 200 and ct == "application/json"
            assert any(e.get("name") == "decode"
                       for e in trace["traceEvents"])

            st, _, body = await _req_http(port, "POST", "/debug/trace/stop")
            stop = json.loads(body)
            assert st == 200 and stop["tracing"] is False
            assert stop["summary"]["decode_steps"] >= 4

            # Accept negotiation: text/plain -> Prometheus, default JSON
            st, ct, body = await _req_http(port, "GET", "/metrics",
                                           headers={"Accept": "text/plain"})
            assert st == 200
            assert ct == "text/plain; version=0.0.4; charset=utf-8"
            assert b"# TYPE repro_generated_tokens_total counter" in body
            st, ct, body = await _req_http(port, "GET", "/metrics")
            assert ct == "application/json"
            snap = json.loads(body)
            assert snap["generated_tokens"] == 4

            st, _, _ = await _req_http(port, "POST", "/metrics/reset")
            assert st == 200
            _, _, body = await _req_http(port, "GET", "/metrics")
            assert json.loads(body)["generated_tokens"] == 0

            # unconfigured SLO / flight recorder still answer (empty)
            st, _, body = await _req_http(port, "GET", "/v1/slo")
            assert st == 200 and json.loads(body) == {"configured": False}
            st, _, body = await _req_http(port, "GET", "/debug/flight")
            fl = json.loads(body)
            assert st == 200 and fl["enabled"] is False
            assert fl["count"] == 0 and fl["dumps"] == []

            # wrong methods answer 405, not 404
            for method, path in (("GET", "/metrics/reset"),
                                 ("GET", "/debug/trace/start"),
                                 ("POST", "/debug/trace"),
                                 ("POST", "/healthz"),
                                 ("POST", "/v1/slo"),
                                 ("POST", "/debug/flight")):
                st, _, _ = await _req_http(port, method, path)
                assert st == 405, (method, path)

            http.close()
            await http.wait_closed()

    asyncio.run(run())


def test_healthz_503_when_driver_dies():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    async def run():
        engine = AsyncEngine(server)
        http = await start_http_server(engine, port=0)
        port = http.sockets[0].getsockname()[1]

        def explode():
            raise RuntimeError("injected step failure")

        server.step = explode
        stream = await engine.submit(
            Request(instance=0, prompt=[1, 2], max_new_tokens=2))
        res = await stream.result()
        # unsupervised driver death is a terminal engine failure, not a
        # client cancellation: the stream errors with the tokens it
        # already delivered (none here) — DESIGN.md §6.8
        assert res.status == "error"
        assert "driver failed" in res.error
        assert res.tokens == list(stream.emitted)

        st, _, body = await _req_http(port, "GET", "/healthz")
        h = json.loads(body)
        assert st == 503
        assert h["status"] == "error" and h["driver"] == "failed"
        assert h["instance_health"] == ["healthy", "healthy"]

        http.close()
        await http.wait_closed()
        # the failure already reached every waiter; aclose() returns
        # without re-raising and without hanging
        await asyncio.wait_for(engine.aclose(), 10)

    asyncio.run(run())


def test_run_in_step_gap_without_running_driver():
    """reset/tracing toggles must work before any request ever started
    the driver (direct-call fallback)."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    async def run():
        engine = AsyncEngine(server)
        on = await engine.set_tracing(True)
        off = await engine.set_tracing(False)
        await engine.reset_metrics()
        await engine.aclose()
        return on, off

    on, off = asyncio.run(run())
    assert on == {"tracing": True}
    assert off["tracing"] is False


# ---------------------------------------------------------------------------
# log-bucketed histograms + SLO evaluation (§6.9)
# ---------------------------------------------------------------------------


def test_loghistogram_percentile_error_bound_and_merge():
    """The histogram replaces the biased sliding windows: over the full
    sample set, every reported percentile is >= the exact one (bucket
    upper bound, never under-reports) and within one growth factor of
    it.  merge() is bucket-exact."""
    import random

    rng = random.Random(0)
    vals = [rng.uniform(1e-3, 2.0) for _ in range(5000)]
    h = LogHistogram()
    for v in vals:
        h.record(v)
    s = sorted(vals)
    for q in (0.5, 0.95, 0.99):
        exact = s[max(0, math.ceil(q * len(s)) - 1)]
        got = h.percentile(q)
        assert exact <= got <= exact * HIST_GROWTH * 1.0001, (q, exact, got)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(sum(vals))

    a, b = LogHistogram(), LogHistogram()
    for v in vals[:2000]:
        a.record(v)
    for v in vals[2000:]:
        b.record(v)
    a.merge(b)
    assert a.counts == h.counts
    assert a.percentile(0.99) == h.percentile(0.99)


def test_loghistogram_inf_bucket_and_frac_le():
    h = LogHistogram()
    h.record(1e-6)          # below the ladder -> first bucket
    h.record(500.0)         # above the ladder -> +Inf bucket
    assert h.counts[0] == 1 and h.counts[-1] == 1
    les, cums = zip(*h.buckets())
    assert les[-1] == math.inf and cums[-1] == 2
    assert list(cums) == sorted(cums)
    # conservative: mid-bucket thresholds credit only whole buckets,
    # and a +Inf-bucket sample is never credited to a finite threshold
    assert h.frac_le(1.0) == 0.5
    assert h.frac_le(1e3) == 0.5
    # +Inf percentile falls back to the largest finite bound
    assert h.percentile(0.99) == LogHistogram.les[-1]
    assert LogHistogram().percentiles() is None


def test_slo_objective_states_and_burn_rate():
    good = LogHistogram()
    for _ in range(1000):
        good.record(0.010)                     # 10 ms, threshold 200 ms
    ok = evaluate_objective(good, [0.010] * 50, 200.0, target=0.99)
    assert ok["state"] == "ok"
    assert ok["bad_frac"] == 0.0 and ok["burn_rate"] == 0.0
    assert ok["budget_remaining"] == pytest.approx(1.0)

    # cumulative fine, recent window failing fast -> burning
    burning = evaluate_objective(good, [0.900] * 10 + [0.010] * 90,
                                 200.0, target=0.99)
    assert burning["state"] == "burning"
    assert burning["burn_rate"] == pytest.approx(10.0)

    # cumulative budget blown -> violated regardless of recent
    bad = LogHistogram()
    for _ in range(90):
        bad.record(0.010)
    for _ in range(10):
        bad.record(0.900)
    violated = evaluate_objective(bad, [0.010] * 50, 200.0, target=0.99)
    assert violated["state"] == "violated"
    assert violated["budget_remaining"] < 0

    assert worst_state(["ok", "burning", "ok"]) == "burning"
    assert worst_state(["burning", "violated"]) == "violated"
    assert worst_state([]) == "ok"

    avail = evaluate_availability(99, 1, target=0.99)
    assert avail["state"] == "ok"
    assert evaluate_availability(50, 50)["state"] == "violated"


# ---------------------------------------------------------------------------
# tenant accounting: zero-cost off, conserved + result-invisible on
# ---------------------------------------------------------------------------


def test_accounting_and_flight_off_run_no_code(monkeypatch):
    """Accounting disabled (the default) and no flight dir: a full
    drain — submit, queue wait, chunked prefill, scatter, decode,
    finish — must never enter the ledger or the recorder (every method
    is a bomb), same proof as the tracer's."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params)

    def boom(*a, **k):
        raise AssertionError("accounting/flight code ran while disabled")

    for name in ("note_decode", "note_prefill", "note_scatter",
                 "note_queue_wait", "note_replay", "_interfere",
                 "snapshot", "conservation"):
        monkeypatch.setattr(server.accounting, name, boom)
    monkeypatch.setattr(server.flight, "dump", boom)
    ids = [server.submit(r) for r in _reqs()]
    results = server.run_until_drained()
    assert {r.request_id for r in results} == set(ids)
    assert all(r.status == "ok" for r in results)
    assert server.accounting.enabled is False
    assert len(server.flight) == 0
    # quarantine hook only wires up when the recorder is armed
    assert server.health.on_quarantine is None


def test_accounted_streams_bit_identical_and_conserved():
    """Accounting + tracing + SLO on, under chunked prefill AND K=8
    multi-step decode: greedy streams bit-identical to the plain run,
    and the ledger conserves (attributed time re-sums to settled wall
    within float error — far inside the 1% acceptance bound)."""
    cfg, params = _build("tinyllama-1.1b")

    def drain(**kw):
        server = _server(cfg, params, prefill_chunk=4, decode_steps=8, **kw)
        if kw:
            server.accounting.start()
            server.tracer.start()
        ids = [server.submit(r) for r in _reqs()]
        res = {r.request_id: r.tokens for r in server.run_until_drained()}
        return server, [res[i] for i in ids]

    _, want = drain()
    server, got = drain(slo=SLOConfig(ttft_ms=200.0, itl_ms=100.0))
    assert got == want

    cons = server.accounting.conservation()
    assert cons["settled_s"] > 0
    assert cons["rel_err"] < 1e-6, cons
    snap = server.metrics.snapshot()
    acct = snap["accounting"]
    assert acct["enabled"] is True
    assert acct["conservation_rel_err"] < 1e-6
    assert set(acct["per_tenant"]) == {"0", "1"}
    for t in acct["per_tenant"].values():
        assert t["decode_s"] > 0 and t["prefill_s"] > 0
    # every device call the metrics counted was attributed
    assert acct["device_calls"] == snap["device_calls"]
    # the SLO block rides the same snapshot
    assert snap["slo"]["configured"] is True
    assert len(snap["slo"]["instances"]) == server.m
    for inst in snap["slo"]["instances"]:
        assert set(inst["objectives"]) == {"ttft", "itl", "availability"}
        assert inst["state"] in ("ok", "burning", "violated")


def test_interference_report_under_backlog():
    """With more requests than slots, tenants queue behind each other:
    the head-of-line report must attribute each waiter's delay to the
    occupants, and queue-wait accrues."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slots_per_instance=1)
    server.accounting.start()
    for _ in range(3):                       # backlog on both instances
        for r in _reqs():
            server.submit(r)
    server.run_until_drained()
    snap = server.accounting.snapshot()
    assert snap["interference"], "no interference recorded under backlog"
    waited = {int(w) for w in snap["interference"]}
    assert waited <= {0, 1}
    for acc in snap["interference"].values():
        assert all(s > 0 for s in acc.values())
    assert sum(t["queue_wait_s"] for t in snap["per_tenant"].values()) > 0
    assert snap["conservation_rel_err"] < 1e-6


# ---------------------------------------------------------------------------
# flight recorder + conservation across a supervised crash
# ---------------------------------------------------------------------------


def test_flight_dump_and_conservation_under_driver_crash(tmp_path):
    """A supervised driver crash mid-run: the flight recorder freezes
    the incident to disk (schema round-trip), conservation holds across
    the recovery (replayed calls are attributed like any other), and
    the replay view account is charged."""
    from repro.serving import FaultInjector, FaultSpec, Supervisor

    cfg, params = _build("tinyllama-1.1b")
    inj = FaultInjector([FaultSpec(site="driver", at_call=3)])
    server = _server(cfg, params, prefill_chunk=4, faults=inj,
                     flight=FlightRecorder(str(tmp_path)),
                     slo=SLOConfig(ttft_ms=200.0))
    server.accounting.start()
    server.tracer.start()
    inj.arm()

    async def main():
        engine = AsyncEngine(server)
        sup = Supervisor(engine, backoff_base_s=0.001)
        async with sup:
            async def client(r):
                s = await engine.submit(r)
                toks = [t async for t in s]
                return toks, await s.result()

            out = await asyncio.gather(*(client(r) for r in _reqs()))
        return out, sup

    out, sup = asyncio.run(main())
    assert sup.restarts == 1
    assert all(res.status == "ok" and res.tokens == toks
               for toks, res in out)

    # conservation survives the crash + replay (acceptance: < 1%)
    snap = server.accounting.snapshot()
    assert snap["conservation_rel_err"] < 0.01, snap
    assert sum(t["replay_tokens"] for t in snap["per_tenant"].values()) > 0
    assert sum(t["replay_s"] for t in snap["per_tenant"].values()) > 0

    # the dump landed on disk and round-trips with the full schema
    assert len(server.flight) >= 1
    files = sorted(tmp_path.glob("flight-*.json"))
    assert files
    rec = json.loads(files[0].read_text())
    assert rec["schema"] == "flight/v1"
    assert rec["seq"] == 1
    assert rec["reason"].startswith("crash:")
    assert rec["extra"]["in_flight"] == len(_reqs())
    assert isinstance(rec["queue_depths"], list)
    assert rec["trace_events"], "trace tail missing from the dump"
    kinds = {ev["event"] for ev in rec["trace_events"]}
    assert kinds <= {"DeviceCallEvent", "RequestEvent"} and kinds
    m = rec["metrics"]
    assert m["slo"]["configured"] is True
    assert m["accounting"]["enabled"] is True
    # the in-memory ring serves the same record
    assert server.flight.latest()[0]["seq"] == 1


def test_quarantine_hook_fires_flight_dump(tmp_path):
    """health.py's quarantine transition is a flight trigger: the hook
    is wired only when the recorder is armed, and firing it dumps."""
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, flight=FlightRecorder(str(tmp_path)))
    assert server.health.on_quarantine is not None
    server.health.on_quarantine(1)
    assert len(server.flight) == 1
    rec = server.flight.latest()[0]
    assert rec["reason"] == "quarantine: instance 1"
    assert rec["path"] and os.path.exists(rec["path"])


# ---------------------------------------------------------------------------
# SLO on the HTTP surface
# ---------------------------------------------------------------------------


def test_http_slo_routes_and_health_integration():
    cfg, params = _build("tinyllama-1.1b")
    server = _server(cfg, params, slo=SLOConfig(ttft_ms=60_000.0,
                                                itl_ms=60_000.0))

    async def run():
        async with AsyncEngine(server) as engine:
            http = await start_http_server(engine, port=0)
            port = http.sockets[0].getsockname()[1]

            st, _, body = await _req_http(
                port, "POST", "/v1/completions",
                payload={"model": 0, "prompt": [1, 2, 3], "max_tokens": 4})
            assert st == 200

            st, _, body = await _req_http(port, "GET", "/v1/slo")
            rep = json.loads(body)
            assert st == 200 and rep["configured"] is True
            assert rep["config"]["ttft_ms"] == 60_000.0
            assert len(rep["instances"]) == server.m
            # thresholds are 60 s: a smoke drain cannot violate them
            assert rep["instances"][0]["state"] == "ok"
            assert rep["instances"][0]["objectives"]["ttft"]["count"] > 0

            st, _, body = await _req_http(port, "GET", "/healthz")
            h = json.loads(body)
            assert st == 200
            assert h["slo"] == ["ok", "ok"]
            assert h["instance_health"] == ["healthy", "healthy"]

            st, _, body = await _req_http(port, "GET", "/v1/models")
            models = json.loads(body)["data"]
            assert [mm["slo"] for mm in models] == ["ok", "ok"]
            assert [mm["health"] for mm in models] == ["healthy", "healthy"]

            http.close()
            await http.wait_closed()

    asyncio.run(run())


# ---------------------------------------------------------------------------
# kernel profiling
# ---------------------------------------------------------------------------


def test_profile_serving_kernels_smoke():
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=2)
    rows = profile_serving_kernels(cfg, slots=2, max_context=32, chunk=8,
                                   prefill_lanes=2, repeats=1)
    validate_profile(rows)
    assert [r["kernel"] for r in rows] == [
        "fused_matmul", "decode_attn", "chunk_prefill_attn",
        "mlstm_chunk", "slstm_cell", "decode_layer", "logits_sample"]
    for r in rows:
        assert r["bound"] in ("compute", "memory")
        assert r["backend"] == jax.default_backend()
        if r["backend"] != "tpu":
            assert r["interpret"] is True


def test_serving_shapes_handle_zero_dff_configs():
    """xlstm smoke configs carry d_ff=0 (no MLP): shape derivation must
    fall back, not divide by zero (the bug the first profiling run
    hit)."""
    cfg = registry.get_smoke_config("xlstm-1.3b").with_(num_instances=2)
    shapes = serving_shapes(cfg, slots=2, max_context=32, chunk=8,
                            prefill_lanes=2)
    assert shapes["fused_matmul"]["f"] > 0
    assert shapes["mlstm_chunk"]["hd"] > 0
    assert shapes["slstm_cell"]["d"] > 0
    row = profile_kernel("fused_matmul", dtype=cfg.dtype, repeats=1,
                         **shapes["fused_matmul"])
    validate_profile([row])
