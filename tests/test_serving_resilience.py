"""Deterministic chaos suite for the fault-tolerant serving core
(DESIGN.md §6.8).

The ISSUE-9 contract:

* the fault injector is seedable and deterministic — same plan + seed
  ⇒ same fault schedule ⇒ same recovered streams — and ZERO injector
  code runs when disarmed (bombed-methods proof, same discipline as the
  PR-6 tracer guard);
* a greedy stream interrupted by a mid-decode driver crash and
  recovered by the Supervisor is **bit-identical** to the uninterrupted
  run — no token lost, none duplicated — sync engine, async frontend,
  and 8-device mesh (subprocess);
* an injected NaN on instance i quarantines ONLY row i (its requests
  503 at submit) while the other M−1 instances' streams stay
  byte-identical to the fault-free run; probation un-quarantines;
* the watchdog fires on an injected stall and recovery still yields
  bit-identical streams;
* driver death without a Supervisor propagates: streams end with
  terminal ``status="error"`` Results (keeping delivered tokens),
  pending submits get ``EngineClosed``, ``drain()``/``aclose()``
  return instead of hanging (satellite 1);
* an exception mid-``step()`` never leaks a busy slot or prefill lane
  (satellite 2);
* overload brownout sheds by queue age and caps ``max_new`` in
  degraded mode.

Every test pins its fault schedule with ``at_call``/``every`` triggers
or a fixed ``seed``, so the suite is reproducible run-to-run.
"""
import asyncio
import json
import os
import subprocess
import sys
import textwrap
import time

import pytest

import jax

from repro import api
from repro.configs import registry
from repro.serving import (
    AsyncEngine,
    BrownoutPolicy,
    EngineClosed,
    FaultInjected,
    FaultInjector,
    FaultSpec,
    HealthMonitor,
    MultiModelServer,
    Request,
    Result,
    Supervisor,
    start_http_server,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARCH = "tinyllama-1.1b"


def _build(m=2):
    cfg = registry.get_smoke_config(ARCH).with_(num_instances=m)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("slots_per_instance", 2)
    kw.setdefault("max_context", 48)
    kw.setdefault("temperature", 0.0)
    return MultiModelServer(cfg, params, **kw)


def _reqs(m=2):
    base = [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4),
        Request(instance=1, prompt=[4, 5], max_new_tokens=4),
        Request(instance=0, prompt=[7], max_new_tokens=3),
        Request(instance=1, prompt=[3, 3, 3, 3, 3], max_new_tokens=3),
    ]
    if m > 2:
        base.append(Request(instance=2, prompt=[9, 8], max_new_tokens=4))
    return base


def _clean_streams(cfg, params, m=2, **kw):
    """The fault-free greedy reference: {request_id: (tokens, status)}."""
    srv = _server(cfg, params, **kw)
    for r in _reqs(m):
        srv.try_submit(r)
    return {r.request_id: (r.tokens, r.status)
            for r in srv.run_until_drained()}


async def _stream_all(engine, reqs):
    async def client(r):
        stream = await engine.submit(r)
        toks = [t async for t in stream]
        return stream.request_id, toks, await stream.result()

    return await asyncio.gather(*(client(r) for r in reqs))


# ---------------------------------------------------------------------------
# fault injector: zero-cost when disarmed, deterministic when armed
# ---------------------------------------------------------------------------


def test_disarmed_injector_runs_no_code(monkeypatch):
    """Every fault site is guarded by ``if faults.armed:`` — with the
    injector disarmed, a workload must complete even when every
    injector method is replaced with a bomb (the PR-6 tracer
    discipline: disabled means no code, not cheap code)."""
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site="decode", at_call=1)])

    def boom(*a, **k):
        raise AssertionError("injector code ran while disarmed")

    monkeypatch.setattr(inj, "on_call", boom)
    monkeypatch.setattr(inj, "arm", boom)
    monkeypatch.setattr(inj, "reset", boom)
    server = _server(cfg, params, faults=inj)
    for r in _reqs():
        server.try_submit(r)
    out = server.run_until_drained()
    assert all(r.status == "ok" for r in out)
    assert inj.calls == {} and inj.fired == []


def test_fault_schedule_is_deterministic():
    """Probabilistic plans replay identically for a fixed seed: the
    ``fired`` fingerprint (site, call index, kind) matches across runs,
    and a different seed produces a different schedule."""

    def schedule(seed):
        inj = FaultInjector(
            [FaultSpec(site="decode", kind="nan", prob=0.3, times=None)],
            seed=seed).arm()
        for _ in range(64):
            inj.on_call("decode")
        return list(inj.fired)

    a, b = schedule(7), schedule(7)
    assert a == b and a            # identical, and the plan does fire
    assert schedule(8) != a
    # reset() rewinds counters AND the rng: the schedule replays
    inj = FaultInjector(
        [FaultSpec(site="decode", kind="nan", prob=0.3, times=None)],
        seed=7).arm()
    for _ in range(64):
        inj.on_call("decode")
    first = list(inj.fired)
    inj.reset()
    for _ in range(64):
        inj.on_call("decode")
    assert inj.fired == first == a


def test_fault_plan_json_roundtrip(tmp_path):
    plan = {"seed": 3, "faults": [
        {"site": "driver", "at_call": 2},
        {"site": "decode", "kind": "nan", "instance": 1, "every": 5,
         "times": 2},
    ]}
    inline = FaultInjector.from_json(json.dumps(plan))
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan))
    from_file = FaultInjector.from_json(str(p))
    for inj in (inline, from_file):
        assert inj.seed == 3 and len(inj.plan) == 2
        assert inj.plan[1].kind == "nan" and inj.plan[1].instance == 1
    with pytest.raises(ValueError):
        FaultSpec(site="nowhere", at_call=1)
    with pytest.raises(ValueError):
        FaultSpec(site="decode")           # no trigger


def test_checkpoint_fault_site(tmp_path):
    from repro.checkpoint import store

    tree = {"w": jax.numpy.ones((2, 2))}
    store.save(tmp_path / "ckpt", tree)
    inj = FaultInjector([FaultSpec(site="checkpoint", at_call=1)]).arm()
    with pytest.raises(FaultInjected):
        store.restore(tmp_path / "ckpt", tree, faults=inj)
    # the spec fired once (times=1): the retry succeeds
    back = store.restore(tmp_path / "ckpt", tree, faults=inj)
    assert back["w"].shape == (2, 2)


# ---------------------------------------------------------------------------
# crash recovery: bit-identical greedy streams
# ---------------------------------------------------------------------------


def test_sync_crash_recovery_bit_identical():
    """Mid-decode engine crash, recovered by reset + requeue with the
    delivered prefix: terminal streams AND the on_token hook stream are
    bit-identical to the uninterrupted run."""
    cfg, params = _build()
    want = _clean_streams(cfg, params)

    inj = FaultInjector([FaultSpec(site="decode", at_call=3)])
    srv = _server(cfg, params, faults=inj)
    emitted = {}
    srv.on_token = lambda rid, tok, fin: emitted.setdefault(rid, []).append(tok)
    for r in _reqs():
        srv.try_submit(r)
    inj.arm()
    done, crashes = [], 0
    while srv.busy() or srv._pending_failures:
        try:
            done.extend(srv.step())
        except FaultInjected:
            crashes += 1
            live = srv.reset_serving_state()
            for req, _gen in live:
                srv.requeue(req, emitted=list(emitted.get(req.request_id, [])))
    assert crashes == 1
    got = {r.request_id: (r.tokens, r.status) for r in done}
    assert got == want
    # the client-visible hook stream carries each token exactly once
    assert emitted == {rid: toks for rid, (toks, _s) in want.items()}
    assert srv.metrics.replay_mismatches == 0
    assert srv.metrics.replayed_tokens > 0


def test_supervised_async_crash_bit_identical():
    """Driver-site crash under a Supervisor: one restart, streams (both
    iterated tokens and terminal Results) bit-identical to the clean
    run, zero token duplication across the requeue."""
    cfg, params = _build()
    want = _clean_streams(cfg, params)

    inj = FaultInjector([FaultSpec(site="driver", at_call=2)])
    srv = _server(cfg, params, faults=inj)
    inj.arm()

    async def main():
        engine = AsyncEngine(srv)
        sup = Supervisor(engine, backoff_base_s=0.001)
        async with sup:
            out = await _stream_all(engine, _reqs())
        return out, sup

    out, sup = asyncio.run(main())
    got = {rid: (toks, res.status) for rid, toks, res in out}
    assert got == want
    assert all(list(res.tokens) == toks for _rid, toks, res in out)
    assert sup.restarts == 1
    snap = sup.snapshot()
    assert snap["driver_restarts"] == 1
    assert snap["request_retries"] == len(_reqs())
    assert snap["last_recovery_s"] is not None
    # the engine's metrics carry the supervision block
    assert srv.metrics.snapshot()["resilience"]["driver_restarts"] == 1
    assert srv.metrics.replay_mismatches == 0


def test_watchdog_fires_on_injected_stall():
    """A decode step stalled past the watchdog deadline is detected,
    the stalled step is waited out (soft path: executor threads cannot
    be killed), and recovery still yields bit-identical streams."""
    cfg, params = _build()

    def warm(s):
        s.try_submit(Request(instance=0, prompt=[1, 2], max_new_tokens=2))
        s.run_until_drained()

    srv0 = _server(cfg, params)
    warm(srv0)                 # align request-id ranges with the faulted run
    for r in _reqs():
        srv0.try_submit(r)
    want = {r.request_id: (r.tokens, r.status)
            for r in srv0.run_until_drained()}

    inj = FaultInjector([FaultSpec(site="decode", kind="stall",
                                   stall_s=1.0, at_call=2)])
    srv = _server(cfg, params, faults=inj)
    warm(srv)                  # compiles must not trip the watchdog
    inj.arm()

    async def main():
        engine = AsyncEngine(srv)
        sup = Supervisor(engine, watchdog_s=0.25, backoff_base_s=0.001)
        async with sup:
            out = await _stream_all(engine, _reqs())
        return out, sup

    out, sup = asyncio.run(main())
    got = {rid: (toks, res.status) for rid, toks, res in out}
    assert got == want
    assert sup.watchdog_timeouts == 1 and sup.restarts == 1


def test_retry_budget_exhaustion_gives_up_cleanly():
    """A driver that crashes on EVERY step exhausts max_restarts: every
    stream ends with a terminal error Result (no hang), the engine
    refuses new work, and the counters record the give-up."""
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site="driver", every=1, times=None)])
    srv = _server(cfg, params, faults=inj)
    inj.arm()

    async def main():
        engine = AsyncEngine(srv)
        sup = Supervisor(engine, max_restarts=2, backoff_base_s=0.001,
                         max_retries=100)   # restart budget trips first
        sup.start()
        out = await asyncio.wait_for(_stream_all(engine, _reqs()), 60)
        with pytest.raises(EngineClosed):
            await engine.submit(Request(instance=0, prompt=[1],
                                        max_new_tokens=1))
        await asyncio.wait_for(engine.aclose(), 10)
        return out, sup

    out, sup = asyncio.run(main())
    assert all(res.status == "error" for _rid, _t, res in out)
    assert all("permanently" in res.error for _rid, _t, res in out)
    assert sup.restarts == 2


# ---------------------------------------------------------------------------
# satellite 1: unsupervised driver death propagates, nothing hangs
# ---------------------------------------------------------------------------


def test_unsupervised_driver_death_propagates():
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site="decode", at_call=2)])
    srv = _server(cfg, params, faults=inj)
    inj.arm()

    async def main():
        engine = AsyncEngine(srv)
        s1 = await engine.submit(Request(instance=0, prompt=[1, 2, 3],
                                         max_new_tokens=6))
        s2 = await engine.submit(Request(instance=1, prompt=[4, 5],
                                         max_new_tokens=6))
        r1 = await asyncio.wait_for(s1.result(), 120)
        r2 = await asyncio.wait_for(s2.result(), 120)
        # terminal error Results carrying the already-delivered tokens
        # (decode call 1 landed before the crash)
        assert r1.status == "error" and "driver failed" in r1.error
        assert r2.status == "error"
        assert r1.tokens == list(s1.emitted) and len(r1.tokens) >= 1
        assert engine.driver_status() == "failed"
        with pytest.raises(EngineClosed):
            await engine.submit(Request(instance=0, prompt=[1],
                                        max_new_tokens=1))
        # neither drain nor aclose hangs or re-raises
        await asyncio.wait_for(engine.drain(), 10)
        await asyncio.wait_for(engine.aclose(), 10)

    asyncio.run(main())


# ---------------------------------------------------------------------------
# satellite 2: exception mid-step never leaks a slot
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["scatter", "prefill"])
def test_step_exception_leaks_no_slot(site):
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site=site, at_call=1)])
    srv = _server(cfg, params, faults=inj)
    for r in _reqs():
        srv.try_submit(r)
    inj.arm()
    out = srv.run_until_drained()
    # the hit request(s) failed terminally; nothing hangs, nothing leaks
    assert any(r.status == "error" for r in out)
    assert not srv.slot_busy.any() and not srv.slot_prefilling.any()
    assert srv.prefill.in_flight() == 0 and not srv._reserved
    assert srv.scheduler.total_pending() == 0
    # ...and the engine still serves: the failed slot is reusable
    srv.try_submit(Request(instance=0, prompt=[7], max_new_tokens=3))
    again = srv.run_until_drained()
    assert [r.status for r in again] == ["ok"]


# ---------------------------------------------------------------------------
# NaN guard -> quarantine: one instance 503s, the rest are untouched
# ---------------------------------------------------------------------------


def test_nan_quarantines_only_poisoned_instance():
    cfg, params = _build(m=3)
    want = _clean_streams(cfg, params, m=3)

    inj = FaultInjector([FaultSpec(site="decode", kind="nan", instance=1,
                                   at_call=2)])
    hm = HealthMonitor(3, quarantine_steps=4)
    srv = _server(cfg, params, faults=inj, health=hm)
    for r in _reqs(3):
        srv.try_submit(r)
    inj.arm()
    got = {r.request_id: (r.tokens, r.status) for r in srv.run_until_drained()}

    # instance 1's request died on the token guard; every other stream
    # is byte-identical to the fault-free run
    assert got[1][1] == "error" and got[3][1] == "error"
    for rid in want:
        if rid not in (1, 3):
            assert got[rid] == want[rid], (rid, got[rid], want[rid])
    assert hm.states() == ["healthy", "quarantined", "healthy"]

    # submit to row 1 -> born-terminal "unavailable"; rows 0/2 unaffected
    rej = srv.try_submit(Request(instance=1, prompt=[1], max_new_tokens=2))
    assert isinstance(rej, Result) and rej.status == "unavailable"
    srv.try_submit(Request(instance=0, prompt=[1, 2, 3], max_new_tokens=4))
    ok = srv.run_until_drained()
    assert ok[0].status == "ok" and ok[0].tokens == want[0][0]

    # quarantine ages into probation; a served success restores healthy
    rounds = 0
    while hm.state(1) == "quarantined" and rounds < 50:
        srv.try_submit(Request(instance=0, prompt=[9], max_new_tokens=1))
        srv.run_until_drained()
        rounds += 1
    assert hm.state(1) == "probation"
    srv.try_submit(Request(instance=1, prompt=[4, 5], max_new_tokens=4))
    back = srv.run_until_drained()
    assert back[-1].status == "ok" and back[-1].tokens == want[1][0]
    assert hm.state(1) == "healthy"
    snap = hm.snapshot()
    assert snap["quarantine_events"] == 1 and snap["poisoned_tokens"] >= 1


# ---------------------------------------------------------------------------
# overload brownout: shed by age, degrade caps max_new
# ---------------------------------------------------------------------------


def test_brownout_sheds_by_queue_age():
    cfg, params = _build()
    pol = BrownoutPolicy(shed_age_s=0.05)
    srv = _server(cfg, params, policy=pol, slots_per_instance=1)
    # more work than slots: the tail queues
    old = [Request(instance=0, prompt=[1, 2], max_new_tokens=2)
           for _ in range(4)]
    for r in old:
        srv.try_submit(r)
    time.sleep(0.1)            # everything queued is now over-age
    out = srv.step()           # policy pass sheds before admission
    shed = [r for r in out if r.status == "shed"]
    assert shed and all("overload" in r.error for r in shed)
    assert pol.shed_total == len(shed)
    out = srv.run_until_drained()
    # whatever was admitted before aging still completes
    assert all(r.status == "ok" for r in out)


def test_brownout_degraded_mode_caps_max_new():
    cfg, params = _build()
    pol = BrownoutPolicy(degrade_depth=2, degrade_steps=2,
                         degraded_max_new=2)
    srv = _server(cfg, params, policy=pol, slots_per_instance=1)
    # sustained backpressure: pending >= degrade_depth for degrade_steps
    for _ in range(6):
        srv.try_submit(Request(instance=0, prompt=[1, 2],
                               max_new_tokens=8))
        srv.try_submit(Request(instance=1, prompt=[3, 4],
                               max_new_tokens=8))
    steps = 0
    while not pol.degraded and steps < 50:
        srv.step()
        steps += 1
    assert pol.degraded
    # a submission under degraded mode is capped at admission
    late = Request(instance=0, prompt=[5], max_new_tokens=16)
    srv.try_submit(late)
    assert late.max_new_tokens == 2 and pol.capped_total >= 1
    out = srv.run_until_drained()
    capped = [r for r in out if r.request_id == late.request_id]
    assert capped and capped[0].status == "ok"
    assert len(capped[0].tokens) == 2


# ---------------------------------------------------------------------------
# HTTP surface: 503 + Retry-After, /healthz, Prometheus rows
# ---------------------------------------------------------------------------


async def _raw_http(port, method, path, payload=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = b"" if payload is None else json.dumps(payload).encode()
    writer.write(
        f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, rest


def test_http_quarantine_503_healthz_and_prometheus():
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site="decode", kind="nan", instance=0,
                                   at_call=1)])
    srv = _server(cfg, params, faults=inj,
                  health=HealthMonitor(2, quarantine_steps=1024))

    async def run():
        engine = AsyncEngine(srv)
        sup = Supervisor(engine, backoff_base_s=0.001)
        sup.start()
        http = await start_http_server(engine, port=0)
        port = http.sockets[0].getsockname()[1]
        inj.arm()
        # poison instance 0's first decode call -> its request errors
        # and row 0 quarantines; instance 1 serves normally throughout
        st, _h, body = await _raw_http(
            port, "POST", "/v1/completions",
            {"model": 0, "prompt": [1, 2, 3], "max_tokens": 4})
        assert st == 200
        assert json.loads(body)["status"] == "error"

        st, headers, body = await _raw_http(
            port, "POST", "/v1/completions",
            {"model": 0, "prompt": [1], "max_tokens": 2})
        assert st == 503
        assert "retry-after" in headers
        err = json.loads(body)["error"]
        assert err["reason"] == "unavailable"

        st, _h, body = await _raw_http(
            port, "POST", "/v1/completions",
            {"model": 1, "prompt": [4, 5], "max_tokens": 3})
        assert st == 200 and json.loads(body)["status"] == "ok"

        st, _h, body = await _raw_http(port, "GET", "/healthz")
        h = json.loads(body)
        assert st == 200
        assert h["instance_health"] == ["quarantined", "healthy"]
        assert h["resilience"]["driver_restarts"] == 0

        # Prometheus exposition carries the §6.8 rows
        snap = srv.metrics.snapshot()
        from repro.serving.obs import render_prometheus
        text = render_prometheus(snap)
        assert "repro_driver_restarts_total 0" in text
        assert "repro_request_retries_total 0" in text
        assert "repro_watchdog_timeouts_total 0" in text
        assert "repro_instances_quarantined 1" in text
        assert ('repro_instance_health_state{instance="0",'
                'state="quarantined"} 1') in text
        assert ('repro_instance_health_state{instance="1",'
                'state="healthy"} 1') in text

        http.close()
        await http.wait_closed()
        await engine.aclose()

    asyncio.run(run())


# ---------------------------------------------------------------------------
# recovery trace + requeue metrics surface
# ---------------------------------------------------------------------------


def test_recovery_events_land_in_trace_and_metrics():
    cfg, params = _build()
    inj = FaultInjector([FaultSpec(site="driver", at_call=2)])
    srv = _server(cfg, params, faults=inj)
    srv.tracer.start()
    inj.arm()

    async def main():
        engine = AsyncEngine(srv)
        sup = Supervisor(engine, backoff_base_s=0.001)
        async with sup:
            out = await _stream_all(engine, _reqs())
        return out

    out = asyncio.run(main())
    assert all(res.status == "ok" for _rid, _t, res in out)
    srv.tracer.stop()
    chrome = srv.tracer.export_chrome()
    names = {e["name"] for e in chrome["traceEvents"]}
    assert any(n.startswith("restart") for n in names)
    assert "requeue" in names
    snap = srv.metrics.snapshot()
    assert snap["requeued"] == len(_reqs())
    assert snap["replayed_tokens"] == snap["resilience"]["tokens_replayed"]
    assert snap["replay_mismatches"] == 0


# ---------------------------------------------------------------------------
# 8-device mesh: crash recovery stays bit-identical when sharded
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_supervised_crash_bit_identical_mesh():
    """The recovery invariant on a forced 8-CPU-device (2, 4) mesh:
    reset_serving_state rebuilds the sharded cache/key in place and the
    requeued greedy streams match the no-fault mesh run byte-for-byte
    (subprocess harness as in test_serving_async.py)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import asyncio
        import jax
        import numpy as np
        from repro import api
        from repro.configs import registry
        from repro.models import common as C
        from repro.serving import (AsyncEngine, FaultInjector, FaultSpec,
                                   MultiModelServer, Request, Supervisor)

        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        M = 2
        cfg1 = registry.get_smoke_config("tinyllama-1.1b").with_(
            num_instances=1, dtype="float32", param_dtype="float32")
        cfg = cfg1.with_(num_instances=M)
        keys = jax.random.split(jax.random.PRNGKey(0), M)
        merged = C.merge_instances(
            [api.init(cfg1, k) for k in keys], api.axes(cfg1))

        def mk_reqs(n=5, max_new=4):
            rng = np.random.default_rng(0)
            return [Request(instance=i % M,
                            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(2, 8))).tolist(),
                            max_new_tokens=max_new) for i in range(n)]

        clean = MultiModelServer(cfg, merged, slots_per_instance=2,
                                 max_context=64, mesh=mesh)
        for r in mk_reqs():
            clean.submit(r)
        want = {r.request_id: (r.tokens, r.status)
                for r in clean.run_until_drained()}
        assert all(t for t, _s in want.values())

        inj = FaultInjector([FaultSpec(site="driver", at_call=2)])
        srv = MultiModelServer(cfg, merged, slots_per_instance=2,
                               max_context=64, mesh=mesh, faults=inj)
        inj.arm()

        async def main():
            engine = AsyncEngine(srv)
            sup = Supervisor(engine, backoff_base_s=0.001)
            sup.start()
            async def client(r):
                s = await engine.submit(r)
                toks = [t async for t in s]
                res = await s.result()
                return s.request_id, toks, res
            out = await asyncio.gather(*(client(r) for r in mk_reqs()))
            await engine.aclose()
            return out, sup

        out, sup = asyncio.run(main())
        got = {rid: (toks, res.status) for rid, toks, res in out}
        assert sup.restarts == 1, sup.snapshot()
        assert got == want, (got, want)
        print("mesh crash recovery bit-identical OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "mesh crash recovery bit-identical OK" in r.stdout
