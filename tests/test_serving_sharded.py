"""Mesh-parametric serving: the engine must serve the fused (M, B) grid
identically on any mesh.

The ISSUE-2 contract: ``MultiModelServer(mesh=...)`` produces the SAME
greedy token streams on a 1-device mesh as today's single-device code
(bit-for-bit — the mesh only adds trivial sharding annotations) and on a
forced 8-CPU-device (data=2, model=4) mesh, where decode, sampling, slot
surgery and chunked prefill all actually run sharded.  Slot surgery
must preserve every cache leaf's NamedSharding across admissions.  The
main test process keeps the spec-mandated single CPU device, so the
multi-device checks run in a subprocess with
``xla_force_host_platform_device_count=8`` (same harness as
test_sharded_paths.py).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SERVE_HEADER = textwrap.dedent("""
    from repro import api
    from repro.configs import registry
    from repro.models import common as C
    from repro.serving import MultiModelServer, Request

    M = 2

    def build(arch):
        cfg1 = registry.get_smoke_config(arch).with_(
            num_instances=1, dtype="float32", param_dtype="float32")
        cfg = cfg1.with_(num_instances=M)
        keys = jax.random.split(jax.random.PRNGKey(0), M)
        merged = C.merge_instances(
            [api.init(cfg1, k) for k in keys], api.axes(cfg1))
        return cfg, merged

    def serve(cfg, merged, mesh, n_req=6, max_new=5):
        srv = MultiModelServer(
            cfg, merged, slots_per_instance=2, max_context=64, mesh=mesh)
        rng = np.random.default_rng(0)
        for i in range(n_req):
            prompt = rng.integers(
                1, cfg.vocab_size, size=int(rng.integers(2, 8))).tolist()
            srv.submit(Request(instance=i % M, prompt=prompt,
                               max_new_tokens=max_new))
        res = sorted(srv.run_until_drained(), key=lambda r: r.request_id)
        return [r.tokens for r in res], srv
""")


def _run_subprocess(body: str, *, header: str = ""):
    # header and body are dedented SEPARATELY (their literal indents
    # differ), then concatenated at column 0 — a shared dedent would
    # leave the body nested inside the header's last function.
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        """
    ) + header + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_engine_streams_identical_across_meshes():
    """Greedy token streams: no-mesh == 1-device mesh == 8-device mesh,
    for a KV-cache family (dense tinyllama).  The 1-device comparison
    guards the refactor (mesh=None path untouched); the 8-device one
    proves the sharded decode+sample+surgery pipeline is exact."""
    out = _run_subprocess(
        """
        cfg, merged = build("tinyllama-1.1b")
        ref, _ = serve(cfg, merged, None)
        assert all(len(t) > 0 for t in ref), ref
        one, _ = serve(cfg, merged, jax.make_mesh((1, 1), ("data", "model")))
        assert one == ref, (one, ref)
        eight, _ = serve(cfg, merged, mesh)
        assert eight == ref, (eight, ref)
        print("dense streams OK")
        """,
        header=_SERVE_HEADER,
    )
    assert "dense streams OK" in out


@pytest.mark.slow
def test_engine_streams_identical_recurrent_family():
    """Same contract for a recurrent-state family (xlstm): the chunked
    state-carrying prefill and nested-state slot surgery run sharded."""
    out = _run_subprocess(
        """
        cfg, merged = build("xlstm-1.3b")
        ref, _ = serve(cfg, merged, None, n_req=4, max_new=4)
        assert all(len(t) > 0 for t in ref), ref
        eight, _ = serve(cfg, merged, mesh, n_req=4, max_new=4)
        assert eight == ref, (eight, ref)
        print("ssm streams OK")
        """,
        header=_SERVE_HEADER,
    )
    assert "ssm streams OK" in out


@pytest.mark.slow
def test_slot_surgery_preserves_leaf_shardings():
    """After admissions + decode steps + slot refills, every grid-cache
    leaf must still carry the init-time NamedSharding (surgery is
    on-device scatter, never a host round-trip that drops placement)."""
    out = _run_subprocess(
        """
        from repro.launch.shardings import serve_rules, tree_shardings

        cfg, merged = build("tinyllama-1.1b")
        _, srv = serve(cfg, merged, mesh, n_req=8, max_new=4)
        rules = serve_rules(mesh)
        want = tree_shardings(rules, api.cache_axes(cfg), srv.cache)
        leaves = jax.tree.leaves(srv.cache)
        wants = jax.tree.leaves(want)
        assert leaves and len(leaves) == len(wants)

        def norm(spec):  # actual array specs strip trailing Nones
            p = list(spec)
            while p and p[-1] is None:
                p.pop()
            return tuple(p)

        for leaf, w in zip(leaves, wants):
            assert isinstance(leaf.sharding, NamedSharding), leaf.sharding
            assert norm(leaf.sharding.spec) == norm(w.spec), (
                leaf.sharding.spec, w.spec)
        # params too: device_put at init, untouched by the step loop
        for leaf in jax.tree.leaves(srv.params):
            assert isinstance(leaf.sharding, NamedSharding), leaf.sharding
        print("surgery shardings OK")
        """,
        header=_SERVE_HEADER,
    )
    assert "surgery shardings OK" in out


@pytest.mark.slow
def test_kernels_under_shard_map_match_plain():
    """fused_matmul / decode_attention shard_map wrappers == the plain
    kernels (interpret mode inside each rank), including the GQA
    fallback when KVH doesn't divide the model axis."""
    out = _run_subprocess(
        """
        from repro.launch.shardings import serve_rules
        from repro.kernels.fused_matmul import fused_matmul, fused_matmul_sharded
        from repro.kernels.decode_attn import (
            decode_attention, decode_attention_sharded)

        rules = serve_rules(mesh)

        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 256))
        b = jax.random.normal(jax.random.PRNGKey(2), (2, 256))
        ref = fused_matmul(x, w, b, interpret=True)
        out = fused_matmul_sharded(x, w, b, rules=rules, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

        q = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 8, 16))
        k = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 32, 4, 16))
        v = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 32, 4, 16))
        kv_len = jnp.full((2, 4), 17, jnp.int32)
        ref = decode_attention(q, k, v, kv_len, interpret=True)
        out = decode_attention_sharded(q, k, v, kv_len, rules=rules,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

        # kvh=2 on a 4-way model axis -> GSPMD fallback path
        ref = decode_attention(q, k[:, :, :, :2], v[:, :, :, :2], kv_len,
                               interpret=True)
        out = decode_attention_sharded(q, k[:, :, :, :2], v[:, :, :, :2],
                                       kv_len, rules=rules, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

        # chunk-prefill flash attention (q-len C over [cache, chunk]),
        # kv-head groups over "model", lane offsets replicated per rank
        from repro.kernels.chunk_prefill_attn import (
            chunk_prefill_attention, chunk_prefill_attention_sharded)
        c, sc = 6, 26
        qc = jax.random.normal(jax.random.PRNGKey(6), (2, 4, c, 8, 16))
        kc = jax.random.normal(jax.random.PRNGKey(7), (2, 4, sc + c, 4, 16))
        vc = jax.random.normal(jax.random.PRNGKey(8), (2, 4, sc + c, 4, 16))
        off = jax.random.randint(jax.random.PRNGKey(9), (2, 4), 0, sc)
        ref = chunk_prefill_attention(qc, kc, vc, off, s_cache=sc, window=8,
                                      interpret=True)
        out = chunk_prefill_attention_sharded(qc, kc, vc, off, rules=rules,
                                              s_cache=sc, window=8,
                                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("sharded kernels OK")
        """
    )
    assert "sharded kernels OK" in out


# ---------------------------------------------------------------------------
# fast in-process checks (single device, no subprocess)
# ---------------------------------------------------------------------------


class _FakeMesh:
    shape = {"data": 2, "model": 4}
    size = 8


def test_compat_polyfills_jax_set_mesh():
    """Importing repro installs jax.set_mesh / jax.shard_map on JAX
    versions that lack them (the test-suite and model zoo use the modern
    spellings)."""
    import jax

    import repro  # noqa: F401  (import installs the shim)

    assert callable(getattr(jax, "set_mesh"))
    assert callable(getattr(jax, "shard_map"))


def test_scheduler_data_shard_mapping():
    from repro.serving.scheduler import TokenBudgetScheduler, make_scheduler

    s = make_scheduler("token-budget", 4, mesh=_FakeMesh())
    assert [s.data_shard_of(i) for i in range(4)] == [0, 0, 1, 1]
    assert s.num_data_shards == 2
    # no mesh / non-divisible M: everything collapses to shard 0
    assert make_scheduler("fifo", 4).data_shard_of(3) == 0
    assert TokenBudgetScheduler(3, mesh=_FakeMesh()).data_shard_of(2) == 0

    # multi-axis batch meshes follow Rules.spec's suffix-drop: M=2 on
    # ("pod", "data") = (2, 4) shards 2-way over "pod" alone
    class _PodMesh:
        shape = {"pod": 2, "data": 4, "model": 2}
        size = 16

    s = make_scheduler("token-budget", 2, mesh=_PodMesh())
    assert [s.data_shard_of(i) for i in range(2)] == [0, 1]
    assert s.num_data_shards == 2


def test_token_budget_tie_breaks_toward_idle_data_shard():
    """Instances 0/1 live on data shard 0, 2/3 on shard 1.  With equal
    per-instance served counts but shard 0 busier overall, the tie must
    break toward shard 1 (mesh-aware); without a mesh it breaks by
    index."""
    from repro.serving.scheduler import Request, TokenBudgetScheduler

    def prep(sched):
        for i in (0, 2):
            sched.submit(Request(instance=i, prompt=[1]))
        # equal served for the two pending instances; their shard-mates
        # differ: instance 1 (shard 0) served a lot, instance 3 none
        sched.served = [5, 90, 5, 0]

    meshy = TokenBudgetScheduler(4, mesh=_FakeMesh())
    prep(meshy)
    assert [r.instance for r in meshy.select({0: 1, 2: 1})] == [2, 0]

    plain = TokenBudgetScheduler(4)
    prep(plain)
    assert [r.instance for r in plain.select({0: 1, 2: 1})] == [0, 2]


def test_metrics_snapshot_carries_mesh_geometry():
    from repro.serving.metrics import ServerMetrics

    snap = ServerMetrics(2, mesh=_FakeMesh()).snapshot()
    assert snap["mesh"] == {"shape": {"data": 2, "model": 4}, "devices": 8}
    assert snap["tok_per_s_per_device"] == snap["tok_per_s"] / 8
    assert "mesh" not in ServerMetrics(2).snapshot()
